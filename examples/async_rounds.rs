//! Sync / deadline / buffered rounds on the synthetic fleet: the
//! vtime-to-accuracy tradeoff of the engine's three [`SyncMode`]s.
//!
//! Latency draws are seed-deterministic and identical across modes
//! (detection profiles full-model-normalized latencies), so per round:
//!
//! * `FullBarrier` waits for the slowest client — the straggler tax.
//! * `Deadline` ends at `1.25 · T_target`; anything later is discarded.
//! * `Buffered` ends at the k-th arrival; stragglers' updates fold into
//!   a later round with a staleness-discounted weight.
//!
//! Both relaxed modes are therefore guaranteed to finish in no more
//! virtual time than the full barrier; the question the table answers is
//! what each pays in accuracy for the speedup.
//!
//! Run: `make artifacts && cargo run --release --example async_rounds`

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::engine::SyncMode;
use fluid::runtime::Session;

fn main() -> fluid::Result<()> {
    let sess = Session::new(Session::default_dir())?;

    let clients = 12;
    let mut base = ExperimentConfig::scale("femnist_cnn", PolicyKind::Invariant, clients);
    base.rounds = 12;
    base.samples_per_client = 30;
    base.local_steps = 2;
    base.eval_every = base.rounds; // final-only eval
    base.recalibrate_every = 2;

    let k = (clients as f64 * 0.75).ceil() as usize;
    let modes = [
        ("full-barrier", SyncMode::FullBarrier),
        ("deadline x1.25", SyncMode::Deadline { multiple_of_t_target: 1.25 }),
        (
            "buffered k=75%",
            SyncMode::Buffered { k },
        ),
    ];

    println!(
        "== async rounds: {} synthetic clients, invariant dropout, {} rounds ==\n",
        clients, base.rounds
    );
    let mut rows = Vec::new();
    let mut barrier_vtime = None;
    for (label, mode) in modes {
        let mut cfg = base.clone();
        cfg.sync_mode = mode;
        let res = coordinator::run(&sess, &cfg)?;
        let dropped: usize = res.records.iter().map(|r| r.dropped_updates).sum();
        let stale: usize = res.records.iter().map(|r| r.stale_folded).sum();
        let speedup = match barrier_vtime {
            None => {
                barrier_vtime = Some(res.total_vtime);
                "—".to_string()
            }
            Some(base_vt) => format!("{:+.1}%", (1.0 - res.total_vtime / base_vt) * 100.0),
        };
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", res.total_vtime),
            speedup,
            format!("{:.2}", res.final_test_acc * 100.0),
            dropped.to_string(),
            stale.to_string(),
        ]);
    }
    println!(
        "{}",
        report::text_table(
            &["sync mode", "vtime s", "vs barrier", "test acc %", "dropped", "stale folded"],
            &rows
        )
    );
    println!(
        "Expected shape: both relaxed modes cut vtime (deadline most aggressively);\n\
         buffered recovers straggler information late instead of discarding it."
    );
    Ok(())
}
