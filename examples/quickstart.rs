//! Quickstart: the paper's headline setup in ~30 lines.
//!
//! Five mobile clients (Table 1), one natural straggler (Pixel 3),
//! FEMNIST CNN, Invariant Dropout. FLuID detects the straggler from
//! end-to-end latencies, sizes a sub-model from the required speedup and
//! extracts it by dropping invariant neurons.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use fluid::coordinator::{self, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;

fn main() -> fluid::Result<()> {
    let sess = Session::new(Session::default_dir())?;
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
    cfg.rounds = 12;
    cfg.samples_per_client = 40;
    cfg.local_steps = 3;
    cfg.eval_every = 4;

    println!("== FLuID quickstart: femnist_cnn, 5 mobile clients, invariant dropout ==");
    let res = coordinator::run(&sess, &cfg)?;

    for r in &res.records {
        println!(
            "round {:>2}  time {:>6.2}s  loss {:.3}  stragglers {:?} rates {:?}  invariant {:>5.1}%",
            r.round,
            r.round_time,
            r.train_loss,
            r.straggler_ids,
            r.straggler_rates,
            r.invariant_fraction * 100.0,
        );
    }
    println!(
        "\nfinal test accuracy: {:.2}%   total virtual time: {:.1}s   calibration overhead: {:.2}%",
        res.final_test_acc * 100.0,
        res.total_vtime,
        res.calibration_overhead() * 100.0
    );

    // compare against vanilla FL on the identical setup
    let mut base = cfg.clone();
    base.policy = PolicyKind::None;
    let baseline = coordinator::run(&sess, &base)?;
    println!(
        "vanilla FL virtual time: {:.1}s  ->  FLuID speedup: {:.1}%",
        baseline.total_vtime,
        (1.0 - res.total_vtime / baseline.total_vtime) * 100.0
    );
    Ok(())
}
