//! End-to-end driver (the EXPERIMENTS.md §E2E run).
//!
//! Federated training of the FEMNIST CNN (~410k parameters) across a
//! heterogeneous 10-client fleet for a few hundred rounds, with FLuID's
//! invariant dropout active the whole time. Proves all three layers
//! compose: rust coordinator -> AOT HLO artifacts -> Pallas masked-dense
//! kernel, with the loss curve and straggler timeline logged.
//!
//! Run: `make artifacts && cargo run --release --example e2e_femnist`
//! Flags: --rounds N (default 200), --out results/e2e_femnist.json

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;
use fluid::util::cli::Args;

fn main() -> fluid::Result<()> {
    let a = Args::new("e2e_femnist", "end-to-end federated training driver")
        .opt("rounds", "200", "federated rounds")
        .opt("clients", "10", "clients")
        .opt("spc", "120", "samples per client")
        .opt("out", "results/e2e_femnist.json", "result JSON path")
        .opt("threads", "0", "worker threads (0 = auto)")
        .parse();

    let sess = Session::new(Session::default_dir())?;
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
    cfg.rounds = a.get_usize("rounds");
    cfg.clients = a.get_usize("clients");
    cfg.samples_per_client = a.get_usize("spc");
    cfg.local_steps = 4;
    cfg.lr = 0.01; // synthetic FEMNIST trains comfortably at CIFAR's lr
    cfg.eval_every = 10;
    cfg.recalibrate_every = 2;
    if a.get_usize("threads") > 0 {
        cfg.threads = a.get_usize("threads");
    }

    println!(
        "== e2e: femnist_cnn, {} clients, {} rounds, invariant dropout ==",
        cfg.clients, cfg.rounds
    );
    let t0 = std::time::Instant::now();
    let res = coordinator::run(&sess, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // loss curve (eval rounds only)
    println!("\nloss curve (test evals):");
    let rows: Vec<Vec<String>> = res
        .records
        .iter()
        .filter(|r| !r.test_acc.is_nan())
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.1}", r.vtime),
                format!("{:.4}", r.train_loss),
                format!("{:.4}", r.test_loss),
                format!("{:.2}", r.test_acc * 100.0),
                format!("{:.1}", r.invariant_fraction * 100.0),
            ]
        })
        .collect();
    println!(
        "{}",
        report::text_table(
            &["round", "vtime s", "train loss", "test loss", "test acc %", "invariant %"],
            &rows
        )
    );

    // straggler timeline summary
    let with_straggler = res
        .records
        .iter()
        .filter(|r| !r.straggler_ids.is_empty())
        .count();
    println!(
        "straggler present in {}/{} rounds; mean sub-model size of straggler rounds: {:.3}",
        with_straggler,
        res.records.len(),
        fluid::util::stats::mean(
            &res.records
                .iter()
                .flat_map(|r| r.straggler_rates.iter().copied())
                .collect::<Vec<_>>()
        )
    );
    println!(
        "\nfinal test acc {:.2}%  |  total virtual time {:.1}s  |  wall {:.1}s  |  calib overhead {:.2}%",
        res.final_test_acc * 100.0,
        res.total_vtime,
        wall,
        res.calibration_overhead() * 100.0
    );

    let out = a.get("out");
    if let Some(dir) = std::path::Path::new(&out).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out, res.to_json().to_string_pretty())?;
    println!("wrote {out}");
    Ok(())
}
