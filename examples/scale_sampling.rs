//! A.6 scalability: 1000 clients with 10% client sampling.
//!
//! FL servers at scale sample a subset of clients per round; FLuID
//! re-detects stragglers within every sampled cohort (the paper's point:
//! recalibration is cheap enough to run per-round). Defaults are scaled
//! down for a quick demo; pass --clients 1000 --rounds 100 for the
//! paper-shaped run.
//!
//! Run: `make artifacts && cargo run --release --example scale_sampling`

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;
use fluid::util::cli::Args;

fn main() -> fluid::Result<()> {
    let a = Args::new("scale_sampling", "client-sampling scalability (A.6)")
        .opt("clients", "200", "fleet size")
        .opt("sample-frac", "0.1", "per-round sampling fraction")
        .opt("rounds", "20", "federated rounds")
        .opt("spc", "20", "samples per client")
        .parse();
    let sess = Session::new(Session::default_dir())?;

    let mut cfg = ExperimentConfig::scale(
        "femnist_cnn",
        PolicyKind::Invariant,
        a.get_usize("clients"),
    );
    cfg.rounds = a.get_usize("rounds");
    cfg.sample_fraction = a.get_f64("sample-frac");
    cfg.samples_per_client = a.get_usize("spc");
    cfg.local_steps = 2;
    cfg.lr = 0.01;
    cfg.eval_every = 5;
    cfg.recalibrate_every = 1; // re-detect within every sampled cohort

    println!(
        "== scale: {} clients, {:.0}% sampled per round, invariant dropout ==",
        cfg.clients,
        cfg.sample_fraction * 100.0
    );
    let res = coordinator::run(&sess, &cfg)?;

    let rows: Vec<Vec<String>> = res
        .records
        .iter()
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{}", r.straggler_ids.len()),
                format!("{:.2}", r.round_time),
                format!("{:.4}", r.train_loss),
                if r.test_acc.is_nan() {
                    "-".into()
                } else {
                    format!("{:.2}", r.test_acc * 100.0)
                },
            ]
        })
        .collect();
    println!(
        "{}",
        report::text_table(
            &["round", "#stragglers (sampled)", "round time s", "loss", "test acc %"],
            &rows
        )
    );
    println!(
        "final acc {:.2}%  vtime {:.1}s  calib overhead {:.2}%",
        res.final_test_acc * 100.0,
        res.total_vtime,
        res.calibration_overhead() * 100.0
    );
    Ok(())
}
