//! The Fig-4b scenario: stragglers change at runtime.
//!
//! Five phones; background load lands on random (non-Pixel-3) clients at
//! the 25%/50%/75% marks of training. Three systems race on identical
//! data and jitter:
//!   * vanilla FL (no dropout)            — pays full straggler latency
//!   * FLuID, static straggler            — calibrates once, misses churn
//!   * FLuID, dynamic recalibration       — tracks the shifting straggler
//!
//! Run: `make artifacts && cargo run --release --example mobile_fleet`

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::runtime::Session;
use fluid::util::cli::Args;

fn main() -> fluid::Result<()> {
    let a = Args::new("mobile_fleet", "runtime straggler-churn comparison (Fig 4b)")
        .opt("rounds", "24", "federated rounds")
        .opt("model", "femnist_cnn", "model")
        .parse();
    let sess = Session::new(Session::default_dir())?;

    let mut base = ExperimentConfig::mobile(&a.get("model"), PolicyKind::Invariant);
    base.rounds = a.get_usize("rounds");
    base.samples_per_client = 40;
    base.local_steps = 2;
    base.fluctuation = true;
    base.eval_every = base.rounds; // final-only eval; this is a timing study

    let mut rows = Vec::new();
    let mut results = Vec::new();
    for (label, policy, static_s) in [
        ("vanilla FL", PolicyKind::None, false),
        ("FLuID (static straggler)", PolicyKind::Invariant, true),
        ("FLuID (dynamic)", PolicyKind::Invariant, false),
    ] {
        let mut cfg = base.clone();
        cfg.policy = policy;
        cfg.static_stragglers = static_s;
        let res = coordinator::run(&sess, &cfg)?;
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", res.total_vtime),
            format!("{:.2}", res.final_test_acc * 100.0),
        ]);
        results.push((label, res));
    }
    println!(
        "{}",
        report::text_table(&["system", "training time (virtual s)", "final acc %"], &rows)
    );

    let base_t = results[0].1.total_vtime;
    for (label, res) in &results[1..] {
        println!(
            "{label}: {:.1}% faster than vanilla",
            (1.0 - res.total_vtime / base_t) * 100.0
        );
    }

    // show who the straggler was over time under the dynamic system
    println!("\ndynamic FLuID straggler timeline:");
    for r in &results[2].1.records {
        if !r.straggler_ids.is_empty() {
            println!(
                "  round {:>2}: straggler {:?} at r={:?} (t_target {:.2}s, straggler {:.2}s)",
                r.round, r.straggler_ids, r.straggler_rates, r.t_target, r.straggler_time
            );
        }
    }
    Ok(())
}
