//! Fleet-scale churn demo: a 10k-client population, 128 sampled per
//! round, clients joining and leaving under the scripted `churn`
//! scenario, stragglers mitigated by invariant dropout.
//!
//! Runs through the runtime-free simulation backend, so it needs no
//! artifacts and works in every build configuration:
//!
//! `cargo run --release --no-default-features --example fleet_churn`
//!
//! Equivalent CLI: `fluid train --sim --fleet-size 10000 --sample-k 128
//! --sampler available --scenario churn`

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::engine::ScenarioConfig;
use fluid::fl::SamplerKind;
use fluid::util::cli::Args;

fn main() -> fluid::Result<()> {
    let a = Args::new("fleet_churn", "fleet-scale churn scenario (sim backend)")
        .opt("fleet-size", "10000", "population size")
        .opt("sample-k", "128", "cohort size per round")
        .opt("rounds", "20", "federated rounds")
        .opt("scenario", "churn", "none|churn|drift|flux|storm[:rate]")
        .opt("sampler", "available", "uniform|weighted|available")
        .opt("seed", "42", "PRNG seed")
        .parse();

    let mut cfg = ExperimentConfig::fleet(
        "femnist_cnn",
        PolicyKind::Invariant,
        a.get_usize("fleet-size"),
        a.get_usize("sample-k"),
    );
    cfg.rounds = a.get_usize("rounds");
    cfg.samples_per_client = 8;
    cfg.local_steps = 2;
    cfg.eval_every = cfg.rounds;
    cfg.seed = a.get_u64("seed");
    cfg.sampler = SamplerKind::parse(&a.get("sampler")).expect("known sampler");
    cfg.scenario = ScenarioConfig::parse(&a.get("scenario")).map_err(anyhow::Error::msg)?;

    println!(
        "== fleet: {} clients, {}/round, sampler={}, scenario={} ==",
        cfg.fleet_size.unwrap(),
        cfg.sample_k,
        cfg.sampler.name(),
        a.get("scenario"),
    );
    let res = coordinator::run_sim(&cfg)?;

    let rows: Vec<Vec<String>> = res
        .records
        .iter()
        .map(|r| {
            vec![
                r.round.to_string(),
                r.cohort.len().to_string(),
                r.straggler_ids.len().to_string(),
                format!("{:.1}", r.round_time),
                format!("{}", r.aggregated),
                format!("{:.3}", r.invariant_fraction),
            ]
        })
        .collect();
    println!(
        "{}",
        report::text_table(
            &["round", "cohort", "stragglers", "t_round s", "aggregated", "inv%"],
            &rows
        )
    );
    println!(
        "total vtime {:.0}s over {} rounds (replay with the same --seed for an \
         identical trajectory)",
        res.total_vtime,
        res.records.len()
    );
    Ok(())
}
