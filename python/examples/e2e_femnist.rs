fn main() {}
