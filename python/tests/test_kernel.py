"""Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

Hypothesis sweeps shapes/masks/seeds; explicit cases pin the block-tiling
edge cases (ragged dims that fall back to smaller blocks, single-block,
multi-block grids).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_dense, neuron_delta, ref
from compile.kernels.masked_dense import _cap, vmem_footprint_bytes, \
    mxu_utilization_estimate


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


def make_mask(key, n, keep_prob):
    return (jax.random.uniform(key, (n,)) < keep_prob).astype(jnp.float32)


# ---------------------------------------------------------------- masked_dense

@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1),
    (4, 8, 6),
    (10, 3136, 120),      # femnist fc1 actual shape
    (16, 512, 256),       # vgg9 fc2 actual shape
    (7, 13, 11),          # all prime: no clean divisor but _cap falls back
    (128, 128, 128),      # exactly one MXU tile
    (256, 384, 256),      # multi-block grid in every dimension
])
def test_masked_dense_matches_ref(m, k, n):
    key = jax.random.PRNGKey(m * 10007 + k * 101 + n)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x, w, b = rand(k1, (m, k)), rand(k2, (k, n)), rand(k3, (n,))
    mask = make_mask(k4, n, 0.7)
    got = masked_dense(x, w, b, mask)
    want = ref.masked_dense_ref(x, w, b, mask)
    # K-blocked accumulation reorders float adds vs the single-dot oracle
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_masked_dense_all_ones_mask_is_plain_dense():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rand(k1, (8, 32)), rand(k2, (32, 16)), rand(k3, (16,))
    got = masked_dense(x, w, b, jnp.ones((16,)))
    np.testing.assert_allclose(got, x @ w + b[None, :], rtol=1e-5, atol=1e-5)


def test_masked_dense_zero_mask_kills_columns():
    key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    x, w, b = rand(k1, (8, 32)), rand(k2, (32, 16)), rand(k3, (16,))
    mask = jnp.zeros((16,)).at[3].set(1.0)
    got = masked_dense(x, w, b, mask)
    assert jnp.all(got[:, :3] == 0) and jnp.all(got[:, 4:] == 0)
    np.testing.assert_allclose(got[:, 3], (x @ w + b)[:, 3], rtol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 192),
    n=st.integers(1, 96),
    keep=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
    bm=st.sampled_from([8, 32, 128]),
    bk=st.sampled_from([16, 64, 128]),
    bn=st.sampled_from([8, 64, 128]),
)
def test_masked_dense_hypothesis(m, k, n, keep, seed, bm, bk, bn):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x, w, b = rand(k1, (m, k)), rand(k2, (k, n)), rand(k3, (n,))
    mask = make_mask(k4, n, keep)
    got = masked_dense(x, w, b, mask, bm=bm, bk=bk, bn=bn)
    want = ref.masked_dense_ref(x, w, b, mask)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------- neuron_delta

@pytest.mark.parametrize("k,n", [
    (1, 1), (8, 8), (3136, 120), (400, 16), (100, 62), (512, 256), (13, 7),
])
def test_neuron_delta_matches_ref(k, n):
    key = jax.random.PRNGKey(k * 31 + n)
    k1, k2 = jax.random.split(key)
    old = rand(k1, (k, n))
    new = old + rand(k2, (k, n), -0.1, 0.1)
    got = neuron_delta(old, new)
    want = ref.neuron_delta_ref(old, new)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_neuron_delta_identical_weights_is_zero():
    w = rand(jax.random.PRNGKey(7), (64, 32))
    np.testing.assert_allclose(neuron_delta(w, w), jnp.zeros((32,)), atol=0)


def test_neuron_delta_detects_single_moved_neuron():
    w = jnp.ones((16, 8))
    w2 = w.at[:, 5].set(2.0)          # neuron 5 doubled: rel change ~1.0
    d = neuron_delta(w, w2)
    assert d[5] == pytest.approx(1.0, rel=1e-5)
    assert jnp.all(d[jnp.arange(8) != 5] == 0.0)


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 300),
    n=st.integers(1, 150),
    scale=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_neuron_delta_hypothesis(k, n, scale, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    old = rand(k1, (k, n))
    new = old * (1.0 + scale * rand(k2, (k, n), -1.0, 1.0))
    got = neuron_delta(old, new)
    want = ref.neuron_delta_ref(old, new)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------- tiling utils

@pytest.mark.parametrize("block,dim,expect", [
    (128, 64, 64),     # dim smaller than block
    (128, 128, 128),   # exact
    (128, 256, 128),   # divisor
    (128, 120, 120),   # 120 < 128 -> itself
    (64, 96, 48),      # largest divisor <= 64
    (128, 3136, 112),  # femnist fc1 fan-in
])
def test_cap_block(block, dim, expect):
    got = _cap(block, dim)
    assert got == expect
    assert dim % got == 0


def test_vmem_footprint_within_budget():
    # every model layer must fit the ~16 MiB VMEM budget with default blocks
    for (k, n) in [(3136, 120), (2048, 512), (512, 256), (256, 128), (120, 62)]:
        assert vmem_footprint_bytes(128, k, n) < 16 * 2**20


def test_mxu_utilization_bounds():
    u = mxu_utilization_estimate(128, 128, 128)
    assert u == pytest.approx(1.0)
    assert 0 < mxu_utilization_estimate(10, 120, 62) <= 1.0
