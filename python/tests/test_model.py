"""L2 model semantics: the mask-equals-sub-model equivalence FLuID rests on.

The critical invariant (DESIGN.md §1): masking a neuron must zero BOTH its
forward contribution AND every gradient of its incident weights, so that
training with a mask is numerically identical to training the paper's
physically-extracted sub-model.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def small_models():
    # small batch sizes keep the test fast; same code paths as aot defaults
    return [
        M.build("femnist_cnn", batch_size=4),
        M.build("cifar_vgg9", batch_size=2),
        M.build("shakespeare_lstm", batch_size=2, seq_len=8),
        M.build("cifar_resnet18", batch_size=2, width_mult=0.25),
    ]


def make_batch(md, key):
    if md.x_dtype == "i32":
        x = jax.random.randint(key, md.x_shape, 0, M.VOCAB, jnp.int32)
    else:
        x = jax.random.uniform(key, md.x_shape, jnp.float32)
    y = jax.random.randint(key, (md.batch_size,), 0, md.num_classes, jnp.int32)
    return x, y


def ones_masks(md):
    return [jnp.ones((n,), jnp.float32) for _, n in md.masks]


@pytest.mark.parametrize("md", small_models(), ids=lambda m: m.name)
def test_forward_shapes(md):
    key = jax.random.PRNGKey(0)
    params = md.init_params(key)
    masks = md.unflatten_masks(ones_masks(md))
    x, _ = make_batch(md, key)
    logits = md.forward(params, masks, x)
    assert logits.shape == (md.batch_size, md.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("md", small_models(), ids=lambda m: m.name)
def test_train_step_decreases_loss(md):
    key = jax.random.PRNGKey(1)
    params = list(md.init_params(key).values())
    # NB: dict preserves insertion order == md.params order
    masks = ones_masks(md)
    x, y = make_batch(md, key)
    lr = jnp.float32(0.01)
    step = jax.jit(md.train_step)
    out = step(*params, *masks, x, y, lr)
    loss0 = out[-2]
    # take 10 more steps on the same batch: loss must drop (early steps may
    # spike while He-init logits settle, so compare start vs end)
    for _ in range(10):
        params = list(out[: len(md.params)])
        out = step(*params, *masks, x, y, lr)
    assert float(out[-2]) < float(loss0), (float(loss0), float(out[-2]))


@pytest.mark.parametrize("md", small_models(), ids=lambda m: m.name)
def test_masked_neurons_receive_zero_gradient(md):
    """THE invariant: a masked neuron's weights are untouched by training."""
    key = jax.random.PRNGKey(2)
    params = md.init_params(key)
    flat_params = [params[n] for n, _ in md.params]
    # drop ~half the neurons in every maskable group
    masks = []
    for i, (_, n) in enumerate(md.masks):
        m = jnp.ones((n,)).at[: n // 2].set(0.0)
        masks.append(m)
    x, y = make_batch(md, key)
    out = md.train_step(*flat_params, *masks, x, y, jnp.float32(0.1))
    new_params = md.unflatten_params(out[: len(md.params)])

    for (mask_name, pname, view), m in zip(md.delta_views, masks):
        old2d = view(params[pname])       # [fan_in, neurons]
        new2d = view(new_params[pname])
        dropped = np.where(np.asarray(m) == 0.0)[0]
        # all incident weights of dropped neurons unchanged
        np.testing.assert_array_equal(
            np.asarray(old2d[:, dropped]), np.asarray(new2d[:, dropped]),
            err_msg=f"{md.name}/{mask_name}: dropped neurons were updated",
        )
        # sanity: kept neurons did move
        kept = np.where(np.asarray(m) == 1.0)[0]
        assert not np.allclose(
            np.asarray(old2d[:, kept]), np.asarray(new2d[:, kept])
        ), f"{md.name}/{mask_name}: kept neurons did not train"


@pytest.mark.parametrize("md", small_models(), ids=lambda m: m.name)
def test_eval_step_counts(md):
    key = jax.random.PRNGKey(3)
    params = [md.init_params(key)[n] for n, _ in md.params]
    masks = ones_masks(md)
    x, y = make_batch(md, key)
    loss, correct = md.eval_step(*params, *masks, x, y)
    assert jnp.isfinite(loss)
    assert 0 <= float(correct) <= md.batch_size


def delta_args(md, params):
    return [params[p] for p in md.delta_param_names()]


@pytest.mark.parametrize("md", small_models(), ids=lambda m: m.name)
def test_delta_step_shapes_and_zero(md):
    key = jax.random.PRNGKey(4)
    params = md.init_params(key)
    ws = delta_args(md, params)
    outs = md.delta_step(*ws, *ws)
    assert len(outs) == len(md.delta_views)
    for d, (_, n) in zip(outs, md.masks):
        assert d.shape == (n,)
        np.testing.assert_allclose(d, np.zeros((n,)), atol=0)


def test_delta_step_flags_trained_neurons():
    md = M.build("femnist_cnn", batch_size=4)
    key = jax.random.PRNGKey(5)
    params = md.init_params(key)
    flat = [params[n] for n, _ in md.params]
    masks = ones_masks(md)
    x, y = make_batch(md, key)
    out = md.train_step(*flat, *masks, x, y, jnp.float32(0.5))
    new_params = md.unflatten_params(out[: len(md.params)])
    deltas = md.delta_step(*delta_args(md, params), *delta_args(md, new_params))
    # with a large lr, some neuron in each group must have moved
    for d in deltas:
        assert float(jnp.max(d)) > 0.0


def test_mask_equals_submodel_loss():
    """Masked full model == physically smaller model on the kept slice.

    For the FC layer this is exact: logits depend only on kept neurons.
    """
    md = M.build("femnist_cnn", batch_size=4)
    key = jax.random.PRNGKey(6)
    params = md.init_params(key)
    x, _ = make_batch(md, key)
    keep = 60  # keep first half of fc1
    masks = md.unflatten_masks(ones_masks(md))
    masks["fc1"] = jnp.ones((120,)).at[keep:].set(0.0)
    logits_masked = md.forward(params, masks, x)

    # physically sliced fc1
    p2 = dict(params)
    p2["fc1_w"] = params["fc1_w"][:, :keep]
    p2["fc1_b"] = params["fc1_b"][:keep]
    p2["out_w"] = params["out_w"][:keep, :]

    def fwd_sliced(p, x):
        h = M.masked_conv(x, p["conv1_w"], p["conv1_b"], jnp.ones((16,)))
        h = jax.nn.relu(M.maxpool2(h))
        h = M.masked_conv(h, p["conv2_w"], p["conv2_b"], jnp.ones((64,)))
        h = jax.nn.relu(M.maxpool2(h))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(h @ p["fc1_w"] + p["fc1_b"])
        return h @ p["out_w"] + p["out_b"]

    np.testing.assert_allclose(
        logits_masked, fwd_sliced(p2, x), rtol=1e-4, atol=1e-4
    )


def test_train_multi_matches_sequential_steps():
    """The fused k-step scan must equal k sequential single steps."""
    md = M.build("femnist_cnn", batch_size=4)
    key = jax.random.PRNGKey(8)
    params = [md.init_params(key)[n] for n, _ in md.params]
    masks = ones_masks(md)
    k = 3
    keys = jax.random.split(key, k)
    xs = jnp.stack([jax.random.uniform(kk, md.x_shape) for kk in keys])
    ys = jnp.stack(
        [jax.random.randint(kk, (md.batch_size,), 0, 62, jnp.int32) for kk in keys]
    )
    lr = jnp.float32(0.01)

    multi = md.train_multi(k)
    out_multi = multi(*params, *masks, xs, ys, lr)

    cur = params
    losses = []
    for i in range(k):
        out = md.train_step(*cur, *masks, xs[i], ys[i], lr)
        cur = list(out[: len(md.params)])
        losses.append(float(out[-2]))

    for a, b in zip(out_multi[: len(md.params)], cur):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    assert float(out_multi[-2]) == pytest.approx(
        sum(losses) / k, rel=1e-5
    )


def test_manifest_contract():
    """aot manifests must mirror ModelDef exactly (ordering contract)."""
    import json, os, subprocess, tempfile
    md = M.build("femnist_cnn")
    from compile import aot
    with tempfile.TemporaryDirectory() as d:
        # lower eval only? full lower is slow; reuse lower_model but smallest model
        man = aot.lower_model(md, d, verbose=False)
    assert man["params"] == [
        {"name": n, "shape": list(s)} for n, s in md.params
    ]
    assert [m["name"] for m in man["masks"]] == [n for n, _ in md.masks]
    assert man["delta_groups"] == [n for n, _, _ in md.delta_views]
    assert man["delta_inputs"] == md.delta_param_names()
    assert man["train_outputs"][-2:] == ["loss", "acc"]
