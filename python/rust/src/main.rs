fn main() { println!("fluid"); }
