//! placeholder
