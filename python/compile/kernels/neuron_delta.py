"""L1 Pallas kernel: per-neuron maximum relative weight update.

FLuID's server identifies *invariant* neurons from the updates of the
non-straggler clients (paper §5): a neuron whose weights all moved less
than the drop-threshold ``th`` relative to their previous value is a
drop candidate.  The per-neuron statistic this kernel computes is

    delta[j] = max_i |w_new[i, j] - w_old[i, j]| / (|w_old[i, j]| + eps)

for a weight matrix laid out as [fan_in, neurons] (CONV kernels are
reshaped to [kh*kw*cin, cout] by model.py — "neurons" are filters there,
matching the paper's definition).

TPU mapping: 2-D grid (N-blocks, K-blocks) with K sequential; a VMEM
scratch row keeps the running per-neuron max, so each step streams one
(bk, bn) tile from HBM and performs a row-reduction on the VPU. The
epilogue on the last K step writes the finished (bn,) row out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .masked_dense import _cap

EPS = 1e-8


def _neuron_delta_kernel(old_ref, new_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rel = jnp.abs(new_ref[...] - old_ref[...]) / (jnp.abs(old_ref[...]) + EPS)
    acc_ref[...] = jnp.maximum(acc_ref[...], jnp.max(rel, axis=0))

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...]


def neuron_delta(w_old, w_new, *, bk: int = 256, bn: int = 256):
    """``delta[N] = max_K |w_new-w_old| / (|w_old|+eps)`` — Pallas-tiled.

    Both inputs are [K, N] = [fan_in, neurons].
    """
    k, n = w_old.shape
    assert w_new.shape == (k, n), (w_old.shape, w_new.shape)
    bk, bn = _cap(bk, k), _cap(bn, n)
    nk, nn = k // bk, n // bn

    return pl.pallas_call(
        functools.partial(_neuron_delta_kernel, nk=nk),
        grid=(nn, nk),
        in_specs=[
            pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, kk: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)],
        interpret=True,
    )(w_old, w_new)
