"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest checks the Pallas kernels
against these implementations across hypothesis-generated shapes, masks
and seeds (python/tests/test_kernel.py). They are also what model.py
falls back to when a layer is too ragged to tile profitably.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def masked_dense_ref(x, w, b, mask):
    """y[M,N] = (x[M,K] @ w[K,N] + b[N]) * mask[N]."""
    return (jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]) * mask[None, :]


def neuron_delta_ref(w_old, w_new):
    """delta[N] = max_K |w_new - w_old| / (|w_old| + eps)."""
    rel = jnp.abs(w_new - w_old) / (jnp.abs(w_old) + EPS)
    return jnp.max(rel, axis=0)
