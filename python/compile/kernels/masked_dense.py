"""L1 Pallas kernel: masked dense layer  y = (x @ w + b) * mask.

This is the compute hot-spot of FLuID's sub-model training: every
fully-connected layer (and every LSTM gate projection) multiplies its
output by a per-neuron 0/1 mask so that dropped ("invariant") neurons
produce no output and — by chain rule — receive exactly zero gradient.
One compiled artifact therefore serves *every* sub-model size.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * 3-D grid (M-blocks, N-blocks, K-blocks); K is the innermost,
    sequential dimension accumulating into a VMEM scratch block, the
    canonical Pallas matmul schedule.
  * each (bm, bk) x (bk, bn) working set fits VMEM; the inner `jnp.dot`
    targets the 128x128 MXU systolic array with f32 accumulation.
  * the neuron mask is applied as an epilogue on the output block while
    it is still resident in VMEM — invariant dropout's sparsity costs
    nothing extra on the systolic array. On a real TPU the grid could
    additionally skip all-zero N-blocks; with interpret=True we keep the
    dense grid and let the device performance model account for the
    compute saving (DESIGN.md §2).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; the interpreted lowering emits plain HLO that the rust
runtime executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: default block sizes. bm/bn target one MXU tile; bk covers 4 K-tiles
#: per grid step (§Perf L1 iteration 1): the VMEM working set stays far
#: under budget (~0.7 MiB at 128x512) while the sequential K loop — the
#: dominant cost of the interpret-mode lowering and a pipeline-latency
#: serialization on real TPU — shrinks 4x.
DEFAULT_BM = 128
DEFAULT_BK = 512
DEFAULT_BN = 128


def _cap(block: int, dim: int) -> int:
    """Largest block size <= `block` that divides `dim` exactly.

    Exact divisors avoid remainder-block masking; model layer widths are
    chosen to be friendly (multiples of 8) so this rarely degrades far.
    """
    if dim <= block:
        return dim
    for b in range(block, 0, -1):
        if dim % b == 0:
            return b
    return dim


def _masked_dense_kernel(x_ref, w_ref, b_ref, m_ref, o_ref, acc_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate x_blk @ w_blk into VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        # bias + neuron mask fused while the output block is in VMEM
        o_ref[...] = (acc_ref[...] + b_ref[...][None, :]) * m_ref[...][None, :]


def masked_dense(x, w, b, mask, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """``y[M,N] = (x[M,K] @ w[K,N] + b[N]) * mask[N]`` — Pallas-tiled.

    ``mask`` is an f32 0/1 vector over output neurons (the paper's unit of
    dropout: filters for CONV layers, activations for FC layers, hidden
    units for LSTM layers; CONV is lowered onto this kernel via im2col in
    model.py so every maskable layer shares one code path).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,) and mask.shape == (n,), (b.shape, mask.shape)
    bm, bk, bn = _cap(bm, m), _cap(bk, k), _cap(bn, n)
    nm, nk, nn = m // bm, k // bk, n // bn

    return pl.pallas_call(
        functools.partial(_masked_dense_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w, b, mask)


def vmem_footprint_bytes(m, k, n, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Analytic VMEM working-set estimate for one grid step (f32).

    Used by the §Perf analysis in EXPERIMENTS.md: x-block + w-block +
    bias/mask blocks + output block + accumulator scratch.
    """
    bm, bk, bn = _cap(bm, m), _cap(bk, k), _cap(bn, n)
    return 4 * (bm * bk + bk * bn + 2 * bn + 2 * bm * bn)


def mxu_utilization_estimate(m, k, n, *, bm=DEFAULT_BM, bk=DEFAULT_BK, bn=DEFAULT_BN):
    """Fraction of each 128x128x128 MXU issue that does useful work."""
    bm, bk, bn = _cap(bm, m), _cap(bk, k), _cap(bn, n)
    return (min(bm, 128) * min(bk, 128) * min(bn, 128)) / float(128 ** 3)
