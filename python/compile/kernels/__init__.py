"""L1: Pallas kernels for FLuID's compute hot-spots.

`masked_dense` — tiled masked matmul used by every maskable layer.
`neuron_delta` — per-neuron max relative weight update (invariant scan).
`ref` — pure-jnp oracles for both.
"""

from .masked_dense import masked_dense, vmem_footprint_bytes, mxu_utilization_estimate
from .neuron_delta import neuron_delta
from . import ref

__all__ = [
    "masked_dense",
    "neuron_delta",
    "ref",
    "vmem_footprint_bytes",
    "mxu_utilization_estimate",
]
