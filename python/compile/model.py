"""L2: FLuID's model zoo as masked JAX step functions (build-time only).

Four models matching the paper's evaluation (§6):
  * ``femnist_cnn``      — 2x conv5x5 (16, 64) + maxpool, FC-120, softmax-62
  * ``cifar_vgg9``       — VGG-9: conv 32,32,64,64,128,128 + FC-512, FC-256
  * ``shakespeare_lstm`` — 2-layer LSTM, 128 hidden units, char-level
  * ``cifar_resnet18``   — ResNet-18 (width-configurable) for the
                           scalability study (Fig 4c/5)

Every maskable layer (CONV filters, FC activations, LSTM hidden units —
the paper's definition of "neuron") takes a per-neuron f32 0/1 mask.
Masking an activation zeroes both its contribution *and all gradients of
its incident weights* (tested in tests/test_model.py), so a mask is
numerically identical to the paper's physical sub-model extraction while
keeping XLA shapes static — one AOT artifact serves every sub-model size.

FC layers and LSTM gate projections run on the L1 Pallas kernel
(`kernels.masked_dense`) through a custom VJP whose backward pass reuses
the same kernel; CONV layers use XLA's native convolution with the mask
applied on output channels (identical gradient semantics, see DESIGN.md).

Exported step functions (all lowered by aot.py):
  * train_step: (params..., masks..., x, y, lr) -> (params'..., loss, acc)
  * eval_step:  (params..., masks..., x, y)     -> (loss, correct_count)
  * delta_step: (old_params..., new_params...)  -> (delta_vec per group...)
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.masked_dense import masked_dense
from .kernels.neuron_delta import neuron_delta
from .kernels import ref

Params = Dict[str, jnp.ndarray]
Masks = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# masked dense with custom VJP — backward pass reuses the Pallas kernel
# --------------------------------------------------------------------------

@jax.custom_vjp
def masked_dense_op(x, w, b, mask):
    return masked_dense(x, w, b, mask)


def _md_fwd(x, w, b, mask):
    y = masked_dense(x, w, b, mask)
    return y, (x, w, mask)


def _md_bwd(res, g):
    x, w, mask = res
    k = x.shape[1]
    gm = g * mask[None, :]
    ones_k = jnp.ones((k,), jnp.float32)
    zeros_k = jnp.zeros((k,), jnp.float32)
    # dx = gm @ w.T  and  dw = x.T @ gm — both on the same Pallas kernel
    dx = masked_dense(gm, w.T, zeros_k, ones_k)
    dw = masked_dense(x.T, gm, jnp.zeros((g.shape[1],), jnp.float32), mask)
    db = jnp.sum(gm, axis=0)
    return dx, dw, db, jnp.zeros_like(mask)


masked_dense_op.defvjp(_md_fwd, _md_bwd)


def masked_conv(x, w, b, mask, *, stride=1, padding="SAME"):
    """NHWC conv with per-filter mask on output channels.

    "Neurons" in CONV layers are filters (paper §3.2); masking the output
    channel zeroes the filter's contribution and all its weight gradients.
    """
    y = lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return (y + b[None, None, None, :]) * mask[None, None, None, :]


def maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


# --------------------------------------------------------------------------
# model definition container
# --------------------------------------------------------------------------

@dataclass
class ModelDef:
    """Everything aot.py needs to lower one model."""

    name: str
    batch_size: int
    params: List[Tuple[str, Tuple[int, ...]]]           # (name, shape)
    masks: List[Tuple[str, int]]                        # (mask name, #neurons)
    x_shape: Tuple[int, ...]
    x_dtype: str                                        # "f32" | "i32"
    forward: Callable[[Params, Masks, jnp.ndarray], jnp.ndarray] = None
    # per maskable group: (mask_name, weight param name,
    #   transform(tensor) -> [fan_in, neurons] 2-D view). The delta
    # artifact takes ONLY these weight tensors (old..., new...) so the
    # lowered HLO signature is explicit — jax DCEs unused jit args.
    delta_views: List[Tuple[str, str, Callable[[jnp.ndarray], jnp.ndarray]]] = field(
        default_factory=list
    )
    num_classes: int = 0

    # ---- helpers -----------------------------------------------------------
    def param_names(self):
        return [n for n, _ in self.params]

    def mask_names(self):
        return [n for n, _ in self.masks]

    def unflatten_params(self, flat):
        return {n: t for (n, _), t in zip(self.params, flat)}

    def unflatten_masks(self, flat):
        return {n: t for (n, _), t in zip(self.masks, flat)}

    def init_params(self, key) -> Params:
        """He-uniform init — used by python tests; rust has its own mirror."""
        out = {}
        for name, shape in self.params:
            key, sub = jax.random.split(key)
            if name.endswith("_b"):
                out[name] = jnp.zeros(shape, jnp.float32)
            elif len(shape) >= 2:
                fan_in = 1
                for d in shape[:-1]:
                    fan_in *= d
                bound = (6.0 / fan_in) ** 0.5
                out[name] = jax.random.uniform(
                    sub, shape, jnp.float32, -bound, bound
                )
            else:
                out[name] = jax.random.normal(sub, shape, jnp.float32) * 0.05
        return out

    # ---- step functions ----------------------------------------------------
    def train_step(self, *flat):
        np_, nm = len(self.params), len(self.masks)
        params = self.unflatten_params(flat[:np_])
        masks = self.unflatten_masks(flat[np_:np_ + nm])
        x, y, lr = flat[np_ + nm], flat[np_ + nm + 1], flat[np_ + nm + 2]

        def loss_fn(p):
            logits = self.forward(p, masks, x)
            loss = cross_entropy(logits, y)
            return loss, accuracy(logits, y)

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params = [params[n] - lr * grads[n] for n in self.param_names()]
        return (*new_params, loss, acc)

    def train_multi(self, k: int):
        """Build a k-step train function: runs k SGD steps over k stacked
        batches inside one XLA program (lax.scan over the L2 step).

        §Perf L2 optimization: one host<->device round trip per ROUND
        instead of per local step — the coordinator's dominant conversion
        cost at small batch sizes. Outputs mean loss/acc over the k steps.
        """
        np_, nm = len(self.params), len(self.masks)

        def fn(*flat):
            params = list(flat[:np_])
            masks = flat[np_:np_ + nm]
            xs, ys, lr = flat[np_ + nm], flat[np_ + nm + 1], flat[np_ + nm + 2]

            def body(carry, xy):
                ps = carry
                x, y = xy
                out = self.train_step(*ps, *masks, x, y, lr)
                new_ps = list(out[:np_])
                return new_ps, jnp.stack([out[-2], out[-1]])

            final_ps, stats = jax.lax.scan(body, params, (xs, ys), length=k)
            mean = jnp.mean(stats, axis=0)
            return (*final_ps, mean[0], mean[1])

        return fn

    def eval_step(self, *flat):
        np_, nm = len(self.params), len(self.masks)
        params = self.unflatten_params(flat[:np_])
        masks = self.unflatten_masks(flat[np_:np_ + nm])
        x, y = flat[np_ + nm], flat[np_ + nm + 1]
        logits = self.forward(params, masks, x)
        loss = cross_entropy(logits, y)
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, correct

    def delta_param_names(self):
        return [p for _, p, _ in self.delta_views]

    def delta_step(self, *flat):
        """flat = (old weight per group..., new weight per group...)."""
        ng = len(self.delta_views)
        outs = []
        for i, (_, _, view) in enumerate(self.delta_views):
            outs.append(neuron_delta(view(flat[i]), view(flat[ng + i])))
        return tuple(outs)

    # example args for lowering -------------------------------------------
    def example_args(self, mode: str):
        def zeros(shape, dt=jnp.float32):
            return jax.ShapeDtypeStruct(tuple(shape), dt)

        ps = [zeros(s) for _, s in self.params]
        ms = [zeros((n,)) for _, n in self.masks]
        xd = jnp.int32 if self.x_dtype == "i32" else jnp.float32
        x = zeros(self.x_shape, xd)
        y = zeros((self.batch_size,), jnp.int32)
        if mode == "train":
            return (*ps, *ms, x, y, zeros((), jnp.float32))
        if mode == "eval":
            return (*ps, *ms, x, y)
        if mode == "delta":
            shapes = dict(self.params)
            ds = [zeros(shapes[p]) for p in self.delta_param_names()]
            return (*ds, *ds)
        if mode.startswith("train_multi"):
            k = int(mode.split(":")[1])
            xs = zeros((k, *self.x_shape), xd)
            ys = zeros((k, self.batch_size), jnp.int32)
            return (*ps, *ms, xs, ys, zeros((), jnp.float32))
        raise ValueError(mode)


# --------------------------------------------------------------------------
# delta-view helpers: reshape any weight tensor to [fan_in, neurons]
# --------------------------------------------------------------------------

def conv_view(w):
    """[KH,KW,Cin,Cout] -> [KH*KW*Cin, Cout] (neurons = filters)."""
    kh, kw, ci, co = w.shape
    return w.reshape(kh * kw * ci, co)


def dense_view(w):
    return w


def lstm_view(w):
    """[(in+H), 4H] -> [4*(in+H), H]: neuron j owns gate columns j,H+j,…"""
    parts = jnp.split(w, 4, axis=1)          # 4 x [(in+H), H]
    return jnp.concatenate(parts, axis=0)    # [4*(in+H), H]


# --------------------------------------------------------------------------
# FEMNIST CNN (paper §6: 2x conv5x5 16/64 + 2x2 maxpool, FC-120, out-62)
# --------------------------------------------------------------------------

def build_femnist_cnn(batch_size: int = 10) -> ModelDef:
    C = 62

    def forward(p, m, x):
        h = masked_conv(x, p["conv1_w"], p["conv1_b"], m["conv1"])
        h = jax.nn.relu(maxpool2(h))
        h = masked_conv(h, p["conv2_w"], p["conv2_b"], m["conv2"])
        h = jax.nn.relu(maxpool2(h))
        h = h.reshape(h.shape[0], -1)                       # [B, 7*7*64]
        h = jax.nn.relu(masked_dense_op(h, p["fc1_w"], p["fc1_b"], m["fc1"]))
        ones = jnp.ones((C,), jnp.float32)
        return masked_dense_op(h, p["out_w"], p["out_b"], ones)

    md = ModelDef(
        name="femnist_cnn",
        batch_size=batch_size,
        params=[
            ("conv1_w", (5, 5, 1, 16)), ("conv1_b", (16,)),
            ("conv2_w", (5, 5, 16, 64)), ("conv2_b", (64,)),
            ("fc1_w", (7 * 7 * 64, 120)), ("fc1_b", (120,)),
            ("out_w", (120, C)), ("out_b", (C,)),
        ],
        masks=[("conv1", 16), ("conv2", 64), ("fc1", 120)],
        x_shape=(batch_size, 28, 28, 1),
        x_dtype="f32",
        num_classes=C,
    )
    md.forward = forward
    md.delta_views = [
        ("conv1", "conv1_w", conv_view),
        ("conv2", "conv2_w", conv_view),
        ("fc1", "fc1_w", dense_view),
    ]
    return md


# --------------------------------------------------------------------------
# CIFAR10 VGG-9 (paper §6: conv 32,32,64,64,128,128 + FC-512, FC-256)
# --------------------------------------------------------------------------

def build_cifar_vgg9(batch_size: int = 16) -> ModelDef:
    C = 10
    widths = [32, 32, 64, 64, 128, 128]

    def forward(p, m, x):
        h = x
        for i in range(6):
            h = masked_conv(h, p[f"conv{i+1}_w"], p[f"conv{i+1}_b"], m[f"conv{i+1}"])
            h = jax.nn.relu(h)
            if i % 2 == 1:
                h = maxpool2(h)
        h = h.reshape(h.shape[0], -1)                       # [B, 4*4*128]
        h = jax.nn.relu(masked_dense_op(h, p["fc1_w"], p["fc1_b"], m["fc1"]))
        h = jax.nn.relu(masked_dense_op(h, p["fc2_w"], p["fc2_b"], m["fc2"]))
        ones = jnp.ones((C,), jnp.float32)
        return masked_dense_op(h, p["out_w"], p["out_b"], ones)

    params = []
    cin = 3
    for i, w in enumerate(widths):
        params += [(f"conv{i+1}_w", (3, 3, cin, w)), (f"conv{i+1}_b", (w,))]
        cin = w
    params += [
        ("fc1_w", (4 * 4 * 128, 512)), ("fc1_b", (512,)),
        ("fc2_w", (512, 256)), ("fc2_b", (256,)),
        ("out_w", (256, C)), ("out_b", (C,)),
    ]
    md = ModelDef(
        name="cifar_vgg9",
        batch_size=batch_size,
        params=params,
        masks=[(f"conv{i+1}", w) for i, w in enumerate(widths)]
        + [("fc1", 512), ("fc2", 256)],
        x_shape=(batch_size, 32, 32, 3),
        x_dtype="f32",
        num_classes=C,
    )
    md.forward = forward
    md.delta_views = [(f"conv{i+1}", f"conv{i+1}_w", conv_view) for i in range(6)] + [
        ("fc1", "fc1_w", dense_view),
        ("fc2", "fc2_w", dense_view),
    ]
    return md


# --------------------------------------------------------------------------
# Shakespeare LSTM (paper §6: 2-layer LSTM, 128 hidden, char-level)
# --------------------------------------------------------------------------

VOCAB = 80          # LEAF Shakespeare character vocabulary size
EMBED = 8


def lstm_layer(x_seq, w, b, mask, hidden):
    """Scan one LSTM layer over time. x_seq: [T, B, D] -> [T, B, H].

    Gate projections run on the Pallas kernel; the hidden-unit mask is
    applied to both h and c every step so dropped units contribute
    nothing and receive zero gradient.
    """
    B = x_seq.shape[1]
    ones4h = jnp.ones((4 * hidden,), jnp.float32)

    def step(carry, x_t):
        h, c = carry
        z = masked_dense_op(jnp.concatenate([x_t, h], axis=1), w, b, ones4h)
        i, f, g, o = jnp.split(z, 4, axis=1)
        c = (jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g))
        c = c * mask[None, :]
        h = jax.nn.sigmoid(o) * jnp.tanh(c) * mask[None, :]
        return (h, c), h

    h0 = jnp.zeros((B, hidden), jnp.float32)
    (_, _), hs = lax.scan(step, (h0, h0), x_seq)
    return hs


def build_shakespeare_lstm(batch_size: int = 16, seq_len: int = 48,
                           hidden: int = 128) -> ModelDef:
    def forward(p, m, x):
        emb = p["emb"][x]                       # [B, T, E]
        xs = jnp.transpose(emb, (1, 0, 2))      # [T, B, E]
        h1 = lstm_layer(xs, p["lstm1_w"], p["lstm1_b"], m["lstm1"], hidden)
        h2 = lstm_layer(h1, p["lstm2_w"], p["lstm2_b"], m["lstm2"], hidden)
        last = h2[-1]                           # [B, H]
        ones = jnp.ones((VOCAB,), jnp.float32)
        return masked_dense_op(last, p["out_w"], p["out_b"], ones)

    md = ModelDef(
        name="shakespeare_lstm",
        batch_size=batch_size,
        params=[
            ("emb", (VOCAB, EMBED)),
            ("lstm1_w", (EMBED + hidden, 4 * hidden)), ("lstm1_b", (4 * hidden,)),
            ("lstm2_w", (hidden + hidden, 4 * hidden)), ("lstm2_b", (4 * hidden,)),
            ("out_w", (hidden, VOCAB)), ("out_b", (VOCAB,)),
        ],
        masks=[("lstm1", hidden), ("lstm2", hidden)],
        x_shape=(batch_size, seq_len),
        x_dtype="i32",
        num_classes=VOCAB,
    )
    md.forward = forward
    md.delta_views = [
        ("lstm1", "lstm1_w", lstm_view),
        ("lstm2", "lstm2_w", lstm_view),
    ]
    return md


# --------------------------------------------------------------------------
# CIFAR10 ResNet-18 (scalability study, Fig 4c / Fig 5)
# --------------------------------------------------------------------------

def build_cifar_resnet18(batch_size: int = 8, width_mult: float = 0.5) -> ModelDef:
    """ResNet-18 (CIFAR stem). Maskable neurons: the *inner* conv of each
    basic block (standard structured-pruning practice — the residual sum
    forces the block-output channels to stay aligned with the identity
    shortcut, so only the block-internal width is free to shrink).
    """
    C = 10
    w64 = max(8, int(64 * width_mult))
    stage_widths = [w64, w64 * 2, w64 * 4, w64 * 8]
    blocks_per_stage = 2

    def bn_free_conv(x, w, b, stride=1):
        ones = jnp.ones((w.shape[-1],), jnp.float32)
        return masked_conv(x, w, b, ones, stride=stride)

    def forward(p, m, x):
        h = jax.nn.relu(bn_free_conv(x, p["stem_w"], p["stem_b"]))
        for s in range(4):
            for bi in range(blocks_per_stage):
                name = f"s{s}b{bi}"
                stride = 2 if (s > 0 and bi == 0) else 1
                ident = h
                h1 = masked_conv(h, p[f"{name}_c1_w"], p[f"{name}_c1_b"],
                                 m[name], stride=stride)
                h1 = jax.nn.relu(h1)
                h2 = bn_free_conv(h1, p[f"{name}_c2_w"], p[f"{name}_c2_b"])
                if stride != 1 or ident.shape[-1] != h2.shape[-1]:
                    ident = bn_free_conv(ident, p[f"{name}_sc_w"],
                                         p[f"{name}_sc_b"], stride=stride)
                h = jax.nn.relu(h2 + ident)
        h = avgpool_global(h)
        ones = jnp.ones((C,), jnp.float32)
        return masked_dense_op(h, p["out_w"], p["out_b"], ones)

    params = [("stem_w", (3, 3, 3, stage_widths[0])), ("stem_b", (stage_widths[0],))]
    masks, views = [], []
    cin = stage_widths[0]
    for s in range(4):
        w = stage_widths[s]
        for bi in range(blocks_per_stage):
            name = f"s{s}b{bi}"
            stride = 2 if (s > 0 and bi == 0) else 1
            params += [
                (f"{name}_c1_w", (3, 3, cin, w)), (f"{name}_c1_b", (w,)),
                (f"{name}_c2_w", (3, 3, w, w)), (f"{name}_c2_b", (w,)),
            ]
            if stride != 1 or cin != w:
                params += [(f"{name}_sc_w", (1, 1, cin, w)), (f"{name}_sc_b", (w,))]
            masks.append((name, w))
            views.append((name, f"{name}_c1_w", conv_view))
            cin = w
    params += [("out_w", (stage_widths[3], C)), ("out_b", (C,))]

    md = ModelDef(
        name="cifar_resnet18",
        batch_size=batch_size,
        params=params,
        masks=masks,
        x_shape=(batch_size, 32, 32, 3),
        x_dtype="f32",
        num_classes=C,
    )
    md.forward = forward
    md.delta_views = views
    return md


# --------------------------------------------------------------------------

BUILDERS = {
    "femnist_cnn": build_femnist_cnn,
    "cifar_vgg9": build_cifar_vgg9,
    "shakespeare_lstm": build_shakespeare_lstm,
    "cifar_resnet18": build_cifar_resnet18,
}


def build(name: str, **kw) -> ModelDef:
    return BUILDERS[name](**kw)
