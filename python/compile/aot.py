"""AOT lowering driver: JAX step functions -> HLO text + JSON manifest.

Interchange format is HLO *text*, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via `make artifacts`:
    cd python && python -m compile.aot --out-dir ../artifacts

Per model this emits
    <model>_train.hlo.txt   (params..., masks..., x, y, lr) ->
                            (params'..., loss, acc)
    <model>_eval.hlo.txt    (params..., masks..., x, y) -> (loss, correct)
    <model>_delta.hlo.txt   (old params..., new params...) ->
                            (per-group neuron delta vectors...)
    <model>_manifest.json   shapes + ordering contract for the rust runtime

plus a tiny `smoke.hlo.txt` used by rust runtime unit tests.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.masked_dense import vmem_footprint_bytes, mxu_utilization_estimate


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


#: local-steps variant baked into the train_multi artifact (§Perf L2);
#: the rust coordinator uses it whenever cfg.local_steps == this value
TRAIN_MULTI_K = 4


def lower_model(md: M.ModelDef, out_dir: str, *, verbose: bool = True) -> dict:
    files = {}
    for mode, fn in (
        ("train", md.train_step),
        ("eval", md.eval_step),
        ("delta", md.delta_step),
        (f"train_multi:{TRAIN_MULTI_K}", md.train_multi(TRAIN_MULTI_K)),
    ):
        args = md.example_args(mode)
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{md.name}_{mode.replace(':', '')}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[mode.split(":")[0]] = fname
        if verbose:
            print(f"  {fname}: {len(text)} chars, {len(args)} inputs")

    # §Perf analytics for the largest FC layer (DESIGN.md §Hardware-Adaptation)
    fc_shapes = [s for n, s in md.params if len(s) == 2 and not n.endswith("_b")]
    big = max(fc_shapes, key=lambda s: s[0] * s[1]) if fc_shapes else (1, 1)
    perf = {
        "largest_dense": list(big),
        "vmem_bytes_per_step": vmem_footprint_bytes(md.batch_size, big[0], big[1]),
        "mxu_utilization": mxu_utilization_estimate(md.batch_size, big[0], big[1]),
    }

    manifest = {
        "model": md.name,
        "batch_size": md.batch_size,
        "x_shape": list(md.x_shape),
        "x_dtype": md.x_dtype,
        "num_classes": md.num_classes,
        "params": [{"name": n, "shape": list(s)} for n, s in md.params],
        "masks": [{"name": n, "size": s} for n, s in md.masks],
        "delta_groups": [n for n, _, _ in md.delta_views],
        "delta_inputs": md.delta_param_names(),
        "artifacts": files,
        "train_multi_k": TRAIN_MULTI_K,
        "train_outputs": [n for n, _ in md.params] + ["loss", "acc"],
        "eval_outputs": ["loss", "correct"],
        "pallas_perf": perf,
    }
    mpath = os.path.join(out_dir, f"{md.name}_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        nparams = sum(
            int(jnp.prod(jnp.array(s))) for _, s in md.params
        )
        print(f"  {md.name}: {nparams} parameters, manifest -> {mpath}")
    return manifest


def lower_smoke(out_dir: str):
    """fn(x, y) = (x @ y + 2,) over f32[2,2] — rust runtime smoke test."""
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    with open(os.path.join(out_dir, "smoke.hlo.txt"), "w") as f:
        f.write(text)
    print(f"  smoke.hlo.txt: {len(text)} chars")


DEFAULT_MODELS = ["femnist_cnn", "cifar_vgg9", "shakespeare_lstm", "cifar_resnet18"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=DEFAULT_MODELS)
    ap.add_argument("--out", default=None, help="Makefile stamp file (compat)")
    args = ap.parse_args(argv)

    out_dir = args.out_dir
    if args.out:  # `make artifacts` passes the stamp target path
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    lower_smoke(out_dir)
    for name in args.models:
        print(f"lowering {name} ...")
        lower_model(M.build(name), out_dir)

    if args.out:
        with open(args.out, "w") as f:
            f.write("ok\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
