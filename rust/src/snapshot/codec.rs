//! Hand-rolled binary codec for snapshot files (serde is unavailable
//! offline, and the format must stay dependency-free anyway).
//!
//! Conventions, used uniformly by every section encoder in
//! [`crate::snapshot`]:
//!
//! * everything is **little-endian**;
//! * every variable-length value (bytes, strings, element vectors) is
//!   preceded by its length as a `u64`;
//! * floats are stored as raw IEEE-754 bit patterns, so NaN payloads and
//!   signed zeros round-trip *exactly* — bit-identical resume depends on
//!   this;
//! * the [`Reader`] never panics on malformed input: every read is
//!   bounds-checked first and lengths are validated **before** any
//!   allocation, so a truncated or corrupted snapshot surfaces as a clean
//!   `Err`, never an OOM or a slice panic.

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};

/// FNV-1a 64-bit hash — the snapshot checksum. Not cryptographic; it
/// guards against truncation and bit-rot, not adversaries.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0001_B3);
    }
    h
}

/// Append-only little-endian byte sink.
#[derive(Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap (and clear) an existing buffer so its capacity is reused —
    /// the snapshot encoder's arena path. Pair with
    /// [`Writer::into_bytes`] to hand the buffer back.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.put_u8(v as u8);
    }

    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed raw bytes.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_usize(v.len());
        self.buf.extend_from_slice(v);
    }

    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    pub fn put_u32s(&mut self, v: &[u32]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_u32(x);
        }
    }

    pub fn put_usizes(&mut self, v: &[usize]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_usize(x);
        }
    }

    pub fn put_f32s(&mut self, v: &[f32]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_f32(x);
        }
    }

    /// Length-prefixed f32 slice stored as one raw little-endian byte
    /// blob — the bulk twin of [`Writer::put_f32s`] for the wire hot
    /// path. Same bit-exactness guarantee (raw IEEE-754 bit patterns),
    /// but the prefix counts *bytes*, so the reader can validate and
    /// copy straight into an existing tensor buffer without a per-element
    /// length walk. Read back with [`Reader::take_f32_bytes_into`].
    pub fn put_f32_bytes(&mut self, v: &[f32]) {
        self.put_usize(v.len() * 4);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    pub fn put_f64s(&mut self, v: &[f64]) {
        self.put_usize(v.len());
        for &x in v {
            self.put_f64(x);
        }
    }

    /// Length-prefixed i8 slice stored as one raw byte blob (two's
    /// complement, so the cast is value-preserving both ways). The
    /// quantized-payload twin of [`Writer::put_f32_bytes`]; the prefix
    /// counts bytes. Read back with [`Reader::take_i8_bytes`].
    pub fn put_i8_bytes(&mut self, v: &[i8]) {
        self.put_usize(v.len());
        self.buf.extend(v.iter().map(|&x| x as u8));
    }
}

/// Bounds-checked little-endian byte source.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_done(&self) -> bool {
        self.remaining() == 0
    }

    fn need(&self, n: usize) -> Result<()> {
        if self.remaining() < n {
            bail!(
                "truncated snapshot data: need {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            );
        }
        Ok(())
    }

    pub fn skip(&mut self, n: usize) -> Result<()> {
        self.need(n)?;
        self.pos += n;
        Ok(())
    }

    pub fn take_u8(&mut self) -> Result<u8> {
        self.need(1)?;
        let v = self.buf[self.pos];
        self.pos += 1;
        Ok(v)
    }

    pub fn take_u32(&mut self) -> Result<u32> {
        self.need(4)?;
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        self.pos += 4;
        Ok(u32::from_le_bytes(b))
    }

    pub fn take_u64(&mut self) -> Result<u64> {
        self.need(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    pub fn take_usize(&mut self) -> Result<usize> {
        let v = self.take_u64()?;
        usize::try_from(v).with_context(|| format!("length {v} overflows usize"))
    }

    pub fn take_bool(&mut self) -> Result<bool> {
        match self.take_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("invalid bool byte {other:#04x}"),
        }
    }

    pub fn take_f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.take_u32()?))
    }

    pub fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    /// A length usize that must be payable by the remaining bytes at
    /// `elem_size` bytes per element — validated *before* any allocation
    /// so corrupted lengths cannot trigger huge reservations.
    fn take_len(&mut self, elem_size: usize) -> Result<usize> {
        let n = self.take_usize()?;
        let bytes = n
            .checked_mul(elem_size)
            .with_context(|| format!("length {n} x {elem_size} overflows"))?;
        self.need(bytes)?;
        Ok(n)
    }

    pub fn take_bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.take_len(1)?;
        let v = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(v)
    }

    pub fn take_str(&mut self) -> Result<String> {
        let raw = self.take_bytes()?;
        String::from_utf8(raw.to_vec()).context("snapshot string is not valid UTF-8")
    }

    pub fn take_u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.take_len(4)?;
        (0..n).map(|_| self.take_u32()).collect()
    }

    pub fn take_usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.take_len(8)?;
        (0..n).map(|_| self.take_usize()).collect()
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.take_len(4)?;
        (0..n).map(|_| self.take_f32()).collect()
    }

    pub fn take_f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.take_len(8)?;
        (0..n).map(|_| self.take_f64()).collect()
    }

    /// Read a [`Writer::put_f32_bytes`] blob into `out`, overwriting
    /// every element. Like every other take, the byte length is
    /// validated — against the remaining input *and* against `out` —
    /// before anything is copied.
    pub fn take_f32_bytes_into(&mut self, out: &mut [f32]) -> Result<()> {
        let raw = self.take_bytes()?;
        if raw.len() != out.len() * 4 {
            bail!(
                "f32 blob holds {} bytes, destination needs {}",
                raw.len(),
                out.len() * 4
            );
        }
        for (dst, src) in out.iter_mut().zip(raw.chunks_exact(4)) {
            *dst = f32::from_bits(u32::from_le_bytes([src[0], src[1], src[2], src[3]]));
        }
        Ok(())
    }

    /// Read a [`Writer::put_f32_bytes`] blob into a fresh `Vec` when the
    /// element count is part of the message (packed payload values) —
    /// the byte length is validated against the remaining input before
    /// the allocation.
    pub fn take_f32_bytes(&mut self) -> Result<Vec<f32>> {
        let raw = self.take_bytes()?;
        if raw.len() % 4 != 0 {
            bail!("f32 blob holds {} bytes, not a multiple of 4", raw.len());
        }
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])))
            .collect())
    }

    /// Read a [`Writer::put_i8_bytes`] blob into a fresh `Vec`.
    pub fn take_i8_bytes(&mut self) -> Result<Vec<i8>> {
        let raw = self.take_bytes()?;
        Ok(raw.iter().map(|&b| b as i8).collect())
    }

    /// Read a length-prefixed string into an existing `String`, reusing
    /// its capacity — the pooled twin of [`Reader::take_str`].
    pub fn take_str_into(&mut self, out: &mut String) -> Result<()> {
        let raw = self.take_bytes()?;
        let s = std::str::from_utf8(raw).context("snapshot string is not valid UTF-8")?;
        out.clear();
        out.push_str(s);
        Ok(())
    }
}

/// Shape + bulk data of one tensor: `put_usizes(shape)` then
/// `put_f32_bytes(data)`. The single tensor framing shared by the
/// snapshot sections, the shard wire, and `DeltaPayload` dense framing.
pub fn put_tensor_bulk(w: &mut Writer, t: &Tensor) {
    w.put_usizes(t.shape());
    w.put_f32_bytes(t.data());
}

/// Decode a [`put_tensor_bulk`] framing, allocating the destination via
/// `alloc` (pass a pool, e.g. `|s| scratch.take_out(s)`) only after the
/// claimed element count has been validated against the remaining input.
pub fn take_tensor_bulk(
    r: &mut Reader<'_>,
    mut alloc: impl FnMut(&[usize]) -> Tensor,
) -> Result<Tensor> {
    let rank = r.take_usize()?;
    if rank > 8 {
        bail!("tensor rank {rank} exceeds the supported 8");
    }
    let mut shape = [0usize; 8];
    let mut elems = 1usize;
    for s in shape.iter_mut().take(rank) {
        *s = r.take_usize()?;
        elems = elems
            .checked_mul(*s)
            .with_context(|| format!("tensor shape {:?} overflows", &shape[..rank]))?;
    }
    let need = elems
        .checked_mul(4)
        .with_context(|| format!("tensor byte size for {elems} elements overflows"))?;
    if need > r.remaining() {
        bail!(
            "tensor claims {elems} elements ({need} bytes), only {} bytes left",
            r.remaining()
        );
    }
    let mut t = alloc(&shape[..rank]);
    debug_assert_eq!(t.len(), elems);
    r.take_f32_bytes_into(t.data_mut())?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitives_round_trip() {
        let mut w = Writer::new();
        w.put_u8(7);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX);
        w.put_usize(12345);
        w.put_bool(true);
        w.put_bool(false);
        w.put_f32(-0.0);
        w.put_f64(f64::NAN);
        w.put_str("héllo");
        w.put_f64s(&[1.5, -2.25]);
        w.put_u32s(&[1, 2, 3]);
        w.put_usizes(&[9, 8]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.take_u8().unwrap(), 7);
        assert_eq!(r.take_u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.take_u64().unwrap(), u64::MAX);
        assert_eq!(r.take_usize().unwrap(), 12345);
        assert!(r.take_bool().unwrap());
        assert!(!r.take_bool().unwrap());
        assert_eq!(r.take_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.take_f64().unwrap().is_nan());
        assert_eq!(r.take_str().unwrap(), "héllo");
        assert_eq!(r.take_f64s().unwrap(), vec![1.5, -2.25]);
        assert_eq!(r.take_u32s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.take_usizes().unwrap(), vec![9, 8]);
        assert!(r.is_done());
    }

    #[test]
    fn f32_byte_blob_round_trips_bit_exactly() {
        let src = [1.5f32, -0.0, f32::NAN, f32::MIN_POSITIVE, 3.25e9];
        let mut w = Writer::new();
        w.put_f32_bytes(&src);
        let bytes = w.into_bytes();
        let mut out = [0.0f32; 5];
        Reader::new(&bytes).take_f32_bytes_into(&mut out).unwrap();
        for (a, b) in src.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // destination length mismatch is a clean error
        let mut short = [0.0f32; 4];
        assert!(Reader::new(&bytes).take_f32_bytes_into(&mut short).is_err());
        // truncation at every cut is a clean error
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(r.take_f32_bytes_into(&mut out).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn truncation_is_a_clean_error() {
        let mut w = Writer::new();
        w.put_f64s(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(r.take_f64s().is_err(), "cut at {cut} did not error");
        }
    }

    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut w = Writer::new();
        w.put_u64(u64::MAX); // absurd element count
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(r.take_f64s().is_err());
        let mut r2 = Reader::new(&bytes);
        assert!(r2.take_bytes().is_err());
    }

    #[test]
    fn invalid_bool_rejected() {
        let bytes = [2u8];
        assert!(Reader::new(&bytes).take_bool().is_err());
    }

    #[test]
    fn i8_byte_blob_round_trips_full_range() {
        let src: Vec<i8> = vec![-128, -127, -1, 0, 1, 63, 127];
        let mut w = Writer::new();
        w.put_i8_bytes(&src);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert_eq!(r.take_i8_bytes().unwrap(), src);
        assert!(r.is_done());
        for cut in 0..bytes.len() {
            assert!(Reader::new(&bytes[..cut]).take_i8_bytes().is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn f32_byte_blob_reads_into_fresh_vec() {
        let src = [f32::NAN, -0.0, 2.5];
        let mut w = Writer::new();
        w.put_f32_bytes(&src);
        let bytes = w.into_bytes();
        let out = Reader::new(&bytes).take_f32_bytes().unwrap();
        assert_eq!(out.len(), 3);
        for (a, b) in src.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // a blob whose byte count is not a multiple of 4 is rejected
        let mut w = Writer::new();
        w.put_bytes(&[1, 2, 3]);
        let bytes = w.into_bytes();
        assert!(Reader::new(&bytes).take_f32_bytes().is_err());
    }

    #[test]
    fn take_str_into_reuses_capacity() {
        let mut w = Writer::new();
        w.put_str("first message");
        w.put_str("second");
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let mut s = String::with_capacity(64);
        let cap = s.capacity();
        r.take_str_into(&mut s).unwrap();
        assert_eq!(s, "first message");
        r.take_str_into(&mut s).unwrap();
        assert_eq!(s, "second");
        assert_eq!(s.capacity(), cap, "short strings reuse the pooled capacity");
        // invalid UTF-8 is a clean error
        let mut w = Writer::new();
        w.put_bytes(&[0xFF, 0xFE]);
        let bytes = w.into_bytes();
        let mut junk = String::new();
        assert!(Reader::new(&bytes).take_str_into(&mut junk).is_err());
    }

    #[test]
    fn tensor_bulk_round_trips_and_rejects_corruption() {
        let t = Tensor::full(&[3, 4], 1.25);
        let mut w = Writer::new();
        put_tensor_bulk(&mut w, &t);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        let back = take_tensor_bulk(&mut r, Tensor::zeros).unwrap();
        assert!(r.is_done());
        assert_eq!(back.shape(), t.shape());
        for (a, b) in back.data().iter().zip(t.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // truncation at every cut is a clean error, never a panic
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(take_tensor_bulk(&mut r, Tensor::zeros).is_err(), "cut at {cut}");
        }
        // an absurd element count is rejected before allocation
        let mut w = Writer::new();
        w.put_usizes(&[usize::MAX, 2]);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes);
        assert!(take_tensor_bulk(&mut r, Tensor::zeros).is_err());
    }

    #[test]
    fn fnv1a_is_stable_and_sensitive() {
        let a = fnv1a(b"fluid snapshot");
        assert_eq!(a, fnv1a(b"fluid snapshot"));
        assert_ne!(a, fnv1a(b"fluid snapshos"));
        assert_ne!(fnv1a(b""), fnv1a(b"\0"));
    }
}
