//! Checkpoint/resume snapshot persistence.
//!
//! A production FL server cannot hold a thousand-round experiment hostage
//! to one process lifetime: stragglers drift, devices churn, and a crash
//! at round 900 must not discard rounds 0–899. This module captures the
//! **full resumable state** of a [`crate::engine::RoundEngine`] at a
//! round boundary — global model weights, round cursor and virtual clock,
//! straggler detection, per-client latency tables, the semi-async stale
//! buffer, fleet availability, evolving policy state (invariant
//! thresholds/streaks/scores, the random-dropout PRNG stream), and the
//! complete `RoundRecord` history — such that a resumed run produces
//! **bit-identical** remaining rounds versus the uninterrupted run
//! (pinned by `tests/determinism.rs`).
//!
//! ## File format (version 1)
//!
//! ```text
//! magic "FLSN" | version u32 | payload_len u64 | payload | fnv1a-64 checksum
//! payload := section_count u32
//!          | section table: (id u32, offset u64, len u64) x count
//!          | section blob (offsets relative to blob start)
//! ```
//!
//! Little-endian throughout; floats as raw IEEE-754 bit patterns (see
//! [`codec`]). Unknown section ids are *skipped*, so newer writers can add
//! sections without breaking older readers; a file whose `version` is
//! newer than this build refuses to load. The checksum covers everything
//! before it, so truncation and bit-rot both surface as clean errors.
//!
//! What is **not** captured: anything derivable from the experiment
//! config + seed (device profiles, shard partitions, scenario scripts,
//! per-round sampling streams — see DESIGN.md §5's RNG-stream layout) and
//! host wall-clock measurements (`calibration_secs` totals are carried
//! for reporting but excluded from determinism comparisons). A
//! configuration fingerprint is embedded and validated on resume so a
//! snapshot can never silently continue a *different* experiment.

pub mod codec;

pub use codec::{fnv1a, Reader, Writer};

use crate::coordinator::{ExperimentConfig, RoundRecord};
use crate::engine::QuarEntry;
use crate::straggler::{CtrlState, Detection};
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// File magic: "FLSN" (FLuid SNapshot).
pub const MAGIC: [u8; 4] = *b"FLSN";
/// Current format version.
pub const VERSION: u32 = 1;
/// Snapshot file extension (also what directory resume scans for).
pub const EXTENSION: &str = "fluidsnap";

mod section {
    pub const META: u32 = 1;
    pub const ENGINE: u32 = 2;
    pub const MODEL: u32 = 3;
    pub const POLICY: u32 = 4;
    pub const FLEET: u32 = 5;
    pub const SCHED: u32 = 6;
    pub const HISTORY: u32 = 7;
    /// adaptive rate-controller state (added with `straggler/adapt.rs`);
    /// optional — readers treat an absent CTRL section as "no controller
    /// state", so pre-controller snapshots still resume
    pub const CTRL: u32 = 8;
    /// per-client q8 error-feedback residuals (added with `fl/codec.rs`);
    /// optional — absent means no client has encoded under q8 yet, so
    /// dense/sparse runs and pre-codec snapshots carry no RESID section
    pub const RESID: u32 = 9;
    /// quarantine ledger (added with `engine/chaos.rs`); optional —
    /// absent means no client is quarantined (every zero-chaos run and
    /// every pre-chaos snapshot), so readers rebuild an empty ledger
    pub const QUAR: u32 = 10;
    /// zoo mitigation-policy state (added with `policy/zoo.rs`);
    /// optional — absent means the mitigation carries no zoo state
    /// (every fluid run and every pre-zoo snapshot), so readers start
    /// the per-policy ledger fresh
    pub const ZOO: u32 = 11;
}

/// Evolving dropout-policy state. `Stateless` covers the policies whose
/// masks are pure functions of (spec, rate): none / ordered / exclude.
#[derive(Clone, Debug)]
pub enum PolicyState {
    Stateless,
    /// Federated-Dropout baseline: the mask PRNG stream position.
    Random { state: u64, inc: u64 },
    /// Invariant dropout: per-group thresholds, per-neuron streaks and
    /// mean update scores, plus the observation counter.
    Invariant {
        th: Vec<f32>,
        streak: Vec<Vec<u32>>,
        score: Vec<Vec<f32>>,
        observations: usize,
    },
}

/// Evolving state of a zoo mitigation policy (`--policy safa|helios`).
/// FedProx is stateless beyond the shared detection/controller state and
/// fluid runs carry their dropout state in [`PolicyState`], so neither
/// writes a ZOO section.
#[derive(Clone, Debug, PartialEq)]
pub enum ZooState {
    /// SAFA: last global round whose aggregate included each client.
    Safa { version: Vec<usize> },
    /// Helios: per-client soft-training fraction (1.0 = full epoch).
    Helios { frac: Vec<f64> },
}

impl ZooState {
    /// Stable name of the variant, for mismatch diagnostics.
    pub fn tag_name(&self) -> &'static str {
        match self {
            ZooState::Safa { .. } => "safa",
            ZooState::Helios { .. } => "helios",
        }
    }
}

/// One buffered semi-async update awaiting a future aggregation
/// (`SyncMode::Buffered` late arrivals).
#[derive(Clone, Debug)]
pub struct StaleEntry {
    pub params: Vec<Tensor>,
    pub weight: f64,
    pub mean_loss: f64,
    pub mean_acc: f64,
    pub steps: usize,
    /// the sub-model mask the update trained under, as per-group tensors
    pub mask: Vec<Tensor>,
    pub arrives_at: f64,
    pub born_round: usize,
    /// the client that produced the update (staleness admission under
    /// `--policy safa` is per-client)
    pub client: usize,
}

/// The full resumable state of a run at a round boundary.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// canonical config fingerprint ([`config_fingerprint`]) — validated
    /// on resume
    pub fingerprint: String,
    /// the next round the resumed run executes (== completed rounds)
    pub next_round: usize,
    pub vtime: f64,
    pub calib_total: f64,
    pub train_wall: f64,
    /// global model weights
    pub params: Vec<Tensor>,
    pub policy: PolicyState,
    /// per-client availability (scenario churn is incremental state)
    pub availability: Vec<bool>,
    pub detection: Option<Detection>,
    /// adaptive rate-controller state (`--adapt ewma` runs; `None` for
    /// paper mode and for snapshots written before the controller)
    pub ctrl: Option<CtrlState>,
    /// zoo mitigation-policy state (`--policy safa|helios`; `None` for
    /// fluid/fedprox runs and for snapshots written before the zoo)
    pub zoo: Option<ZooState>,
    pub last_latencies: Vec<f64>,
    pub last_full_latencies: Vec<f64>,
    pub free_at: Vec<f64>,
    pub stale: Vec<StaleEntry>,
    /// q8 error-feedback residuals, one dense per-param f32 set per
    /// client that has encoded under q8, sorted by client id — carried so
    /// a compressed run resumes bit-identically (empty outside q8 mode)
    pub resid: Vec<(u64, Vec<Vec<f32>>)>,
    /// quarantine ledger entries, sorted by client id — carried so a
    /// chaos run's bar list survives kill/resume (empty when no client
    /// is quarantined, which is every zero-chaos run)
    pub quarantine: Vec<QuarEntry>,
    /// per-round history so a resumed run reports the full trajectory
    pub records: Vec<RoundRecord>,
}

/// Canonical fingerprint of everything that shapes a run's trajectory.
///
/// Floats enter as exact bit patterns. Deliberately excluded: `threads`
/// (thread-count invariance is a pinned determinism contract) and the
/// checkpoint/resume/fault-injection knobs themselves (a resumed run
/// necessarily differs in those). The chaos script *is* semantic — it
/// shapes the trajectory (which clients vanish, which updates are
/// poisoned) — while `quorum` (an abort floor: rounds that pass are
/// bit-identical at any value, so a failed run may resume under a
/// relaxed floor) and `shard_retry_max` (pure recovery topology) stay
/// out, like `shards` itself.
pub fn config_fingerprint(cfg: &ExperimentConfig) -> String {
    fn bits64(xs: &[f64]) -> Vec<u64> {
        xs.iter().map(|x| x.to_bits()).collect()
    }
    format!(
        "v1|model={}|policy={}|rounds={}|clients={}|spc={}|steps={}|lr={:08x}\
         |sfrac={:016x}|fixed={:?}|menu={:?}|clusters={:?}|recal={}|fluct={}\
         |static={}|sample={:016x}|eval={}|agg={:?}|fused={}|th={:?}|mobile={}\
         |sync={:?}|fleet={:?}|k={}|sampler={}|scenario={:?}|seed={}\
         |adapt={}|again={:016x}|adb={:016x}|rmin={:016x}|compress={}\
         |chaos={:?}|mit={}|mtto={:016x}|slag={}",
        cfg.model,
        cfg.policy.name(),
        cfg.rounds,
        cfg.clients,
        cfg.samples_per_client,
        cfg.local_steps,
        cfg.lr.to_bits(),
        cfg.straggler_fraction.to_bits(),
        cfg.fixed_rate.map(f64::to_bits),
        bits64(&cfg.rates_menu),
        cfg.cluster_rates.as_deref().map(bits64),
        cfg.recalibrate_every,
        cfg.fluctuation,
        cfg.static_stragglers,
        cfg.sample_fraction.to_bits(),
        cfg.eval_every,
        cfg.aggregate,
        cfg.use_fused_steps,
        cfg.invariant_th_override.map(f32::to_bits),
        cfg.mobile_fleet,
        cfg.sync_mode,
        cfg.fleet_size,
        cfg.sample_k,
        cfg.sampler.name(),
        cfg.scenario,
        cfg.seed,
        cfg.adapt.name(),
        cfg.adapt_gain.to_bits(),
        cfg.adapt_deadband.to_bits(),
        cfg.rate_min.to_bits(),
        cfg.compress.name(),
        cfg.chaos,
        cfg.mitigation.name(),
        cfg.mitigation_trade_off.to_bits(),
        cfg.safa_lag,
    )
}

// ---- tensor / record codecs -----------------------------------------------

fn put_tensor(w: &mut Writer, t: &Tensor) {
    w.put_usizes(t.shape());
    w.put_f32s(t.data());
}

fn take_tensor(r: &mut Reader) -> Result<Tensor> {
    let shape = r.take_usizes().context("tensor shape")?;
    ensure!(shape.len() <= 8, "tensor rank {} is implausible", shape.len());
    let data = r.take_f32s().context("tensor data")?;
    let want: usize = shape.iter().try_fold(1usize, |a, &d| {
        a.checked_mul(d).context("tensor shape overflows")
    })?;
    ensure!(
        want == data.len(),
        "tensor shape {shape:?} wants {want} elements, payload has {}",
        data.len()
    );
    Ok(Tensor::from_vec(&shape, data))
}

fn put_tensors(w: &mut Writer, ts: &[Tensor]) {
    w.put_usize(ts.len());
    for t in ts {
        put_tensor(w, t);
    }
}

fn take_tensors(r: &mut Reader) -> Result<Vec<Tensor>> {
    // 2 words is the smallest possible tensor encoding
    let n = {
        let n = r.take_usize()?;
        ensure!(n <= r.remaining() / 16 + 1, "tensor count {n} exceeds payload");
        n
    };
    (0..n).map(|i| take_tensor(r).with_context(|| format!("tensor {i}"))).collect()
}

fn put_record(w: &mut Writer, rec: &RoundRecord) {
    w.put_usize(rec.round);
    w.put_f64(rec.round_time);
    w.put_f64(rec.vtime);
    w.put_usizes(&rec.cohort);
    w.put_usizes(&rec.straggler_ids);
    w.put_f64s(&rec.straggler_rates);
    w.put_f64(rec.t_target);
    w.put_f64(rec.straggler_time);
    w.put_f64(rec.train_loss);
    w.put_f64(rec.train_acc);
    w.put_f64(rec.test_loss);
    w.put_f64(rec.test_acc);
    w.put_f64(rec.invariant_fraction);
    w.put_f64(rec.calibration_secs);
    w.put_usize(rec.aggregated);
    w.put_usize(rec.dropped_updates);
    w.put_usize(rec.stale_folded);
    w.put_usize(rec.update_bytes);
    w.put_usize(rec.vanished);
    w.put_usize(rec.quarantined);
    w.put_usize(rec.shard_retries);
    w.put_f64(rec.quorum_fraction);
    w.put_f64(rec.straggler_wait);
    w.put_usize(rec.admitted_stale);
    w.put_f64(rec.soft_fraction);
}

fn take_record(r: &mut Reader) -> Result<RoundRecord> {
    Ok(RoundRecord {
        round: r.take_usize()?,
        round_time: r.take_f64()?,
        vtime: r.take_f64()?,
        cohort: r.take_usizes()?,
        straggler_ids: r.take_usizes()?,
        straggler_rates: r.take_f64s()?,
        t_target: r.take_f64()?,
        straggler_time: r.take_f64()?,
        train_loss: r.take_f64()?,
        train_acc: r.take_f64()?,
        test_loss: r.take_f64()?,
        test_acc: r.take_f64()?,
        invariant_fraction: r.take_f64()?,
        calibration_secs: r.take_f64()?,
        aggregated: r.take_usize()?,
        dropped_updates: r.take_usize()?,
        stale_folded: r.take_usize()?,
        update_bytes: r.take_usize()?,
        vanished: r.take_usize()?,
        quarantined: r.take_usize()?,
        shard_retries: r.take_usize()?,
        quorum_fraction: r.take_f64()?,
        straggler_wait: r.take_f64()?,
        admitted_stale: r.take_usize()?,
        soft_fraction: r.take_f64()?,
    })
}

// ---- section encoders ------------------------------------------------------

impl Snapshot {
    fn enc_meta(&self, w: &mut Writer) {
        w.put_str(&self.fingerprint);
    }

    fn enc_engine(&self, w: &mut Writer) {
        w.put_usize(self.next_round);
        w.put_f64(self.vtime);
        w.put_f64(self.calib_total);
        w.put_f64(self.train_wall);
    }

    fn enc_model(&self, w: &mut Writer) {
        put_tensors(w, &self.params);
    }

    fn enc_policy(&self, w: &mut Writer) {
        match &self.policy {
            PolicyState::Stateless => w.put_u8(0),
            PolicyState::Random { state, inc } => {
                w.put_u8(1);
                w.put_u64(*state);
                w.put_u64(*inc);
            }
            PolicyState::Invariant { th, streak, score, observations } => {
                w.put_u8(2);
                w.put_f32s(th);
                w.put_usize(streak.len());
                for s in streak {
                    w.put_u32s(s);
                }
                w.put_usize(score.len());
                for s in score {
                    w.put_f32s(s);
                }
                w.put_usize(*observations);
            }
        }
    }

    fn enc_fleet(&self, w: &mut Writer) {
        // availability as a packed bitmap: 100k clients cost ~12.5 KB
        w.put_usize(self.availability.len());
        let mut packed = vec![0u8; self.availability.len().div_ceil(8)];
        for (i, &a) in self.availability.iter().enumerate() {
            if a {
                packed[i / 8] |= 1 << (i % 8);
            }
        }
        w.put_bytes(&packed);
    }

    fn enc_sched(&self, w: &mut Writer) {
        match &self.detection {
            None => w.put_bool(false),
            Some(d) => {
                w.put_bool(true);
                w.put_usizes(&d.stragglers);
                w.put_f64(d.t_target);
                w.put_f64s(&d.speedups);
                w.put_f64s(&d.rates);
            }
        }
        w.put_f64s(&self.last_latencies);
        w.put_f64s(&self.last_full_latencies);
        w.put_f64s(&self.free_at);
        w.put_usize(self.stale.len());
        for s in &self.stale {
            put_tensors(w, &s.params);
            w.put_f64(s.weight);
            w.put_f64(s.mean_loss);
            w.put_f64(s.mean_acc);
            w.put_usize(s.steps);
            put_tensors(w, &s.mask);
            w.put_f64(s.arrives_at);
            w.put_usize(s.born_round);
            w.put_usize(s.client);
        }
    }

    fn enc_history(&self, w: &mut Writer) {
        w.put_usize(self.records.len());
        for rec in &self.records {
            put_record(w, rec);
        }
    }

    fn enc_ctrl(&self, w: &mut Writer) {
        match &self.ctrl {
            None => w.put_bool(false),
            Some(c) => {
                w.put_bool(true);
                w.put_f64s(&c.profile);
                w.put_f64s(&c.measured);
                w.put_f64s(&c.rates);
                w.put_f64(c.t_target);
            }
        }
    }

    fn enc_resid(&self, w: &mut Writer) {
        w.put_usize(self.resid.len());
        for (client, params) in &self.resid {
            w.put_u64(*client);
            w.put_usize(params.len());
            for p in params {
                w.put_f32_bytes(p);
            }
        }
    }

    fn enc_zoo(&self, w: &mut Writer) {
        match &self.zoo {
            None => w.put_bool(false),
            Some(ZooState::Safa { version }) => {
                w.put_bool(true);
                w.put_u8(1);
                w.put_usizes(version);
            }
            Some(ZooState::Helios { frac }) => {
                w.put_bool(true);
                w.put_u8(2);
                w.put_f64s(frac);
            }
        }
    }

    fn enc_quar(&self, w: &mut Writer) {
        w.put_usize(self.quarantine.len());
        for e in &self.quarantine {
            w.put_usize(e.client);
            w.put_u32(e.strikes);
            w.put_usize(e.barred_until);
            w.put_usize(e.last_strike);
        }
    }

    /// Encode every section into `w` in container order, returning the
    /// `(id, offset, len)` table (offsets relative to where `w` started).
    /// Shared by both encode paths so section order can never drift.
    fn write_sections(&self, w: &mut Writer) -> Vec<(u32, usize, usize)> {
        type Enc = fn(&Snapshot, &mut Writer);
        let sections: [(u32, Enc); 11] = [
            (section::META, Snapshot::enc_meta),
            (section::ENGINE, Snapshot::enc_engine),
            (section::MODEL, Snapshot::enc_model),
            (section::POLICY, Snapshot::enc_policy),
            (section::FLEET, Snapshot::enc_fleet),
            (section::SCHED, Snapshot::enc_sched),
            (section::HISTORY, Snapshot::enc_history),
            (section::CTRL, Snapshot::enc_ctrl),
            (section::RESID, Snapshot::enc_resid),
            (section::QUAR, Snapshot::enc_quar),
            (section::ZOO, Snapshot::enc_zoo),
        ];
        let base = w.len();
        let mut table = Vec::with_capacity(sections.len());
        for (id, enc) in sections {
            let start = w.len() - base;
            enc(self, w);
            table.push((id, start, w.len() - base - start));
        }
        table
    }

    /// Serialize to the versioned, checksummed container format.
    pub fn encode(&self) -> Vec<u8> {
        let mut blob = Vec::new();
        let mut out = Vec::new();
        self.encode_into(&mut blob, &mut out);
        out
    }

    /// [`Snapshot::encode`] into caller-owned buffers whose capacity is
    /// reused across calls — the engine's checkpoint path hands its
    /// scratch arena here so steady-state snapshot writes stop
    /// allocating fresh megabyte buffers every boundary. `blob` holds
    /// the section payloads, `out` the finished container; both are
    /// cleared first and the output is byte-identical to
    /// [`Snapshot::encode`] (pinned by a unit test below).
    pub fn encode_into(&self, blob: &mut Vec<u8>, out: &mut Vec<u8>) {
        let mut w = Writer::from_vec(std::mem::take(blob));
        let table = self.write_sections(&mut w);
        *blob = w.into_bytes();

        out.clear();
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        let payload_len = 4 + table.len() * 20 + blob.len();
        out.extend_from_slice(&(payload_len as u64).to_le_bytes());
        out.extend_from_slice(&(table.len() as u32).to_le_bytes());
        for (id, start, len) in &table {
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(*start as u64).to_le_bytes());
            out.extend_from_slice(&(*len as u64).to_le_bytes());
        }
        out.extend_from_slice(blob);
        let sum = fnv1a(out);
        out.extend_from_slice(&sum.to_le_bytes());
    }

    /// Parse and validate a snapshot. Every failure mode — wrong magic,
    /// newer version, truncation, checksum mismatch, malformed section —
    /// is a clean `Err`, never a panic.
    pub fn decode(bytes: &[u8]) -> Result<Snapshot> {
        const HEADER: usize = 4 + 4 + 8;
        ensure!(
            bytes.len() >= HEADER + 8,
            "snapshot file too small ({} bytes)",
            bytes.len()
        );
        ensure!(
            bytes[..4] == MAGIC,
            "not a fluid snapshot (bad magic {:02x?})",
            &bytes[..4]
        );
        let mut hdr = Reader::new(&bytes[4..HEADER]);
        let version = hdr.take_u32()?;
        ensure!(
            version <= VERSION,
            "snapshot format v{version} is newer than this build (v{VERSION})"
        );
        let payload_len = hdr.take_usize()?;
        let want = HEADER
            .checked_add(payload_len)
            .and_then(|n| n.checked_add(8))
            .context("snapshot payload length overflows")?;
        ensure!(
            bytes.len() == want,
            "snapshot is {} bytes but the header promises {want} (truncated or padded)",
            bytes.len()
        );
        let stored = u64::from_le_bytes(bytes[want - 8..].try_into().unwrap());
        let actual = fnv1a(&bytes[..want - 8]);
        ensure!(
            stored == actual,
            "snapshot checksum mismatch (stored {stored:#018x}, computed {actual:#018x}) — \
             the file is corrupted"
        );

        let payload = &bytes[HEADER..want - 8];
        let mut r = Reader::new(payload);
        let count = r.take_u32()? as usize;
        ensure!(count <= 64, "section count {count} is implausible");
        let mut table = Vec::with_capacity(count);
        for _ in 0..count {
            let id = r.take_u32()?;
            let off = r.take_usize()?;
            let len = r.take_usize()?;
            table.push((id, off, len));
        }
        let blob_start = 4 + count * 20;
        let blob = &payload[blob_start..];
        fn get_section<'b>(
            table: &[(u32, usize, usize)],
            blob: &'b [u8],
            id: u32,
        ) -> Result<&'b [u8]> {
            let (_, off, len) = table
                .iter()
                .find(|(sid, _, _)| *sid == id)
                .with_context(|| format!("snapshot is missing section {id}"))?;
            let end = off.checked_add(*len).context("section bounds overflow")?;
            ensure!(
                end <= blob.len(),
                "section {id} [{off}, {end}) exceeds blob of {} bytes",
                blob.len()
            );
            Ok(&blob[*off..end])
        }
        let get = |id: u32| get_section(&table, blob, id);

        // META
        let mut r = Reader::new(get(section::META)?);
        let fingerprint = r.take_str().context("META section")?;

        // ENGINE
        let mut r = Reader::new(get(section::ENGINE)?);
        let next_round = r.take_usize()?;
        let vtime = r.take_f64()?;
        let calib_total = r.take_f64()?;
        let train_wall = r.take_f64()?;

        // MODEL
        let mut r = Reader::new(get(section::MODEL)?);
        let params = take_tensors(&mut r).context("MODEL section")?;

        // POLICY
        let mut r = Reader::new(get(section::POLICY)?);
        let policy = match r.take_u8()? {
            0 => PolicyState::Stateless,
            1 => PolicyState::Random {
                state: r.take_u64()?,
                inc: r.take_u64()?,
            },
            2 => {
                let th = r.take_f32s()?;
                let ns = r.take_usize()?;
                ensure!(ns <= 4096, "streak group count {ns} implausible");
                let streak = (0..ns).map(|_| r.take_u32s()).collect::<Result<Vec<_>>>()?;
                let nc = r.take_usize()?;
                ensure!(nc <= 4096, "score group count {nc} implausible");
                let score = (0..nc).map(|_| r.take_f32s()).collect::<Result<Vec<_>>>()?;
                let observations = r.take_usize()?;
                PolicyState::Invariant { th, streak, score, observations }
            }
            other => bail!("unknown policy state tag {other}"),
        };

        // FLEET
        let mut r = Reader::new(get(section::FLEET)?);
        let n_avail = r.take_usize()?;
        let packed = r.take_bytes()?;
        ensure!(
            packed.len() == n_avail.div_ceil(8),
            "availability bitmap is {} bytes for {n_avail} clients",
            packed.len()
        );
        let availability: Vec<bool> = (0..n_avail)
            .map(|i| packed[i / 8] & (1 << (i % 8)) != 0)
            .collect();

        // SCHED
        let mut r = Reader::new(get(section::SCHED)?);
        let detection = if r.take_bool()? {
            Some(Detection {
                stragglers: r.take_usizes()?,
                t_target: r.take_f64()?,
                speedups: r.take_f64s()?,
                rates: r.take_f64s()?,
            })
        } else {
            None
        };
        let last_latencies = r.take_f64s()?;
        let last_full_latencies = r.take_f64s()?;
        let free_at = r.take_f64s()?;
        let n_stale = r.take_usize()?;
        ensure!(n_stale <= 1 << 20, "stale count {n_stale} implausible");
        let mut stale = Vec::with_capacity(n_stale);
        for i in 0..n_stale {
            stale.push(StaleEntry {
                params: take_tensors(&mut r)
                    .with_context(|| format!("stale update {i} params"))?,
                weight: r.take_f64()?,
                mean_loss: r.take_f64()?,
                mean_acc: r.take_f64()?,
                steps: r.take_usize()?,
                mask: take_tensors(&mut r)
                    .with_context(|| format!("stale update {i} mask"))?,
                arrives_at: r.take_f64()?,
                born_round: r.take_usize()?,
                client: r.take_usize()?,
            });
        }

        // HISTORY
        let mut r = Reader::new(get(section::HISTORY)?);
        let n_rec = r.take_usize()?;
        ensure!(n_rec <= 1 << 24, "record count {n_rec} implausible");
        let records = (0..n_rec)
            .map(|i| take_record(&mut r).with_context(|| format!("round record {i}")))
            .collect::<Result<Vec<_>>>()?;

        // CTRL — optional: absent in snapshots from pre-controller
        // writers (the resumed run then starts its controller fresh)
        let ctrl = if table.iter().any(|(id, _, _)| *id == section::CTRL) {
            let mut r = Reader::new(get(section::CTRL)?);
            if r.take_bool().context("CTRL section")? {
                Some(CtrlState {
                    profile: r.take_f64s().context("CTRL profile")?,
                    measured: r.take_f64s().context("CTRL measured")?,
                    rates: r.take_f64s().context("CTRL rates")?,
                    t_target: r.take_f64().context("CTRL t_target")?,
                })
            } else {
                None
            }
        } else {
            None
        };

        // RESID — optional: absent means no q8 residual state (dense and
        // sparse runs, plus every pre-codec snapshot)
        let resid = if table.iter().any(|(id, _, _)| *id == section::RESID) {
            let mut r = Reader::new(get(section::RESID)?);
            let n_clients = r.take_usize().context("RESID section")?;
            ensure!(n_clients <= 1 << 24, "residual client count {n_clients} implausible");
            let mut resid = Vec::with_capacity(n_clients);
            for i in 0..n_clients {
                let client = r.take_u64().with_context(|| format!("residual {i} client"))?;
                let np = r.take_usize()?;
                ensure!(np <= 4096, "residual {i} param count {np} implausible");
                let params = (0..np)
                    .map(|_| r.take_f32_bytes())
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("residuals for client {client}"))?;
                resid.push((client, params));
            }
            resid
        } else {
            Vec::new()
        };

        // ZOO — optional: absent means no zoo mitigation state (fluid
        // and fedprox runs, plus every pre-zoo snapshot)
        let zoo = if table.iter().any(|(id, _, _)| *id == section::ZOO) {
            let mut r = Reader::new(get(section::ZOO)?);
            if r.take_bool().context("ZOO section")? {
                match r.take_u8().context("ZOO tag")? {
                    1 => Some(ZooState::Safa {
                        version: r.take_usizes().context("ZOO safa versions")?,
                    }),
                    2 => Some(ZooState::Helios {
                        frac: r.take_f64s().context("ZOO helios fractions")?,
                    }),
                    other => bail!("unknown zoo state tag {other}"),
                }
            } else {
                None
            }
        } else {
            None
        };

        // QUAR — optional: absent means an empty quarantine ledger
        // (zero-chaos runs and every pre-chaos snapshot)
        let quarantine = if table.iter().any(|(id, _, _)| *id == section::QUAR) {
            let mut r = Reader::new(get(section::QUAR)?);
            let n = r.take_usize().context("QUAR section")?;
            ensure!(n <= 1 << 24, "quarantine entry count {n} implausible");
            let mut quarantine = Vec::with_capacity(n);
            for i in 0..n {
                quarantine.push(QuarEntry {
                    client: r
                        .take_usize()
                        .with_context(|| format!("quarantine entry {i}"))?,
                    strikes: r.take_u32()?,
                    barred_until: r.take_usize()?,
                    last_strike: r.take_usize()?,
                });
            }
            quarantine
        } else {
            Vec::new()
        };

        Ok(Snapshot {
            fingerprint,
            next_round,
            vtime,
            calib_total,
            train_wall,
            params,
            policy,
            availability,
            detection,
            ctrl,
            zoo,
            last_latencies,
            last_full_latencies,
            free_at,
            stale,
            resid,
            quarantine,
            records,
        })
    }
}

/// Frame encoded sections into the container format:
/// `magic | version | payload_len | (count | table | blob) | checksum`.
/// Kept for the format-compat tests (splicing unknown sections); the
/// production encoder is [`Snapshot::encode_into`], whose framing is
/// pinned byte-identical to this one by `encode_matches_container_framing`.
#[cfg_attr(not(test), allow(dead_code))]
fn encode_container(sections: &[(u32, Vec<u8>)]) -> Vec<u8> {
    // payload: count | table (id, offset, len) | blob
    let mut payload = Writer::new();
    payload.put_u32(sections.len() as u32);
    let mut offset = 0u64;
    for (id, bytes) in sections {
        payload.put_u32(*id);
        payload.put_u64(offset);
        payload.put_u64(bytes.len() as u64);
        offset += bytes.len() as u64;
    }
    let mut payload = payload.into_bytes();
    for (_, bytes) in sections {
        payload.extend_from_slice(bytes);
    }

    let mut out = Writer::new();
    out.put_u8(MAGIC[0]);
    out.put_u8(MAGIC[1]);
    out.put_u8(MAGIC[2]);
    out.put_u8(MAGIC[3]);
    out.put_u32(VERSION);
    out.put_u64(payload.len() as u64);
    let mut out = out.into_bytes();
    out.extend_from_slice(&payload);
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

// ---- on-disk store ---------------------------------------------------------

/// Directory of rotating snapshot files with atomic writes.
///
/// Files are named `snap-NNNNNN.fluidsnap` by round cursor. Writes go to
/// a dot-tmp sibling, `sync_all`, then `rename` — a crash mid-write can
/// never leave a half-written file under a valid snapshot name. After
/// each save, all but the newest `keep` snapshots are deleted.
pub struct SnapshotStore {
    dir: PathBuf,
    keep: usize,
}

impl SnapshotStore {
    pub fn new(dir: impl Into<PathBuf>, keep: usize) -> Result<Self> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(Self { dir, keep: keep.max(1) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(round: usize) -> String {
        format!("snap-{round:06}.{EXTENSION}")
    }

    fn parse_round(name: &str) -> Option<usize> {
        let rest = name.strip_prefix("snap-")?;
        let digits = rest.strip_suffix(&format!(".{EXTENSION}"))?;
        digits.parse().ok()
    }

    /// Snapshot files in the store, sorted by ascending round cursor.
    pub fn list(&self) -> Result<Vec<(usize, PathBuf)>> {
        let mut out = Vec::new();
        let entries = fs::read_dir(&self.dir)
            .with_context(|| format!("reading checkpoint dir {}", self.dir.display()))?;
        for e in entries {
            let e = e?;
            if let Some(round) = e.file_name().to_str().and_then(Self::parse_round) {
                out.push((round, e.path()));
            }
        }
        out.sort_unstable_by_key(|(r, _)| *r);
        Ok(out)
    }

    /// Path of the newest snapshot, if any.
    pub fn latest(&self) -> Result<Option<PathBuf>> {
        Ok(self.list()?.pop().map(|(_, p)| p))
    }

    /// Atomically persist a snapshot and rotate old files away.
    pub fn save(&self, snap: &Snapshot) -> Result<PathBuf> {
        let (mut blob, mut bytes) = (Vec::new(), Vec::new());
        self.save_with(snap, &mut blob, &mut bytes)
    }

    /// [`SnapshotStore::save`] through caller-owned encode buffers (the
    /// engine passes its scratch arena, so periodic checkpoints reuse
    /// the same allocations round after round).
    pub fn save_with(
        &self,
        snap: &Snapshot,
        blob: &mut Vec<u8>,
        bytes: &mut Vec<u8>,
    ) -> Result<PathBuf> {
        snap.encode_into(blob, bytes);
        let name = Self::file_name(snap.next_round);
        let path = self.dir.join(&name);
        let tmp = self.dir.join(format!(".{name}.tmp"));
        {
            let mut f = fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        // Make the rename durable before rotation deletes older
        // snapshots — otherwise a power loss could persist the unlink
        // but not the rename, leaving fewer recovery points than
        // `keep` promises. Best-effort: not every platform lets a
        // directory be opened and synced.
        if let Ok(d) = fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.rotate()?;
        Ok(path)
    }

    fn rotate(&self) -> Result<()> {
        let files = self.list()?;
        if files.len() > self.keep {
            for (_, path) in &files[..files.len() - self.keep] {
                fs::remove_file(path)
                    .with_context(|| format!("rotating {}", path.display()))?;
            }
        }
        Ok(())
    }

    /// Load one snapshot file.
    pub fn load_file(path: &Path) -> Result<Snapshot> {
        let bytes = fs::read(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        Snapshot::decode(&bytes)
            .with_context(|| format!("decoding snapshot {}", path.display()))
    }

    /// Resolve a `--resume` argument: a snapshot file loads directly, a
    /// directory loads its newest snapshot.
    pub fn load_resume(path: &Path) -> Result<Snapshot> {
        if path.is_dir() {
            let store = SnapshotStore { dir: path.to_path_buf(), keep: usize::MAX };
            let latest = store.latest()?.with_context(|| {
                format!("no *.{EXTENSION} snapshots in {}", path.display())
            })?;
            Self::load_file(&latest)
        } else {
            Self::load_file(path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> Snapshot {
        Snapshot {
            fingerprint: "v1|model=test|seed=42".into(),
            next_round: 7,
            vtime: 123.5,
            calib_total: 0.25,
            train_wall: 1.5,
            params: vec![
                Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, -0.0, 9.25]),
                Tensor::from_vec(&[2], vec![0.5, 0.125]),
            ],
            policy: PolicyState::Invariant {
                th: vec![0.01, 0.02],
                streak: vec![vec![0, 1, 2], vec![3, 0]],
                score: vec![vec![0.1, 0.2, 0.3], vec![0.4, 0.5]],
                observations: 4,
            },
            availability: vec![true, false, true, true, false, false, true, true, true],
            detection: Some(Detection {
                stragglers: vec![4, 2],
                t_target: 8.5,
                speedups: vec![1.5, 1.25],
                rates: vec![0.65, 0.85],
            }),
            ctrl: Some(CtrlState {
                profile: vec![1.25, 0.0, 4.5],
                measured: vec![1.0, 0.0, 3.75],
                rates: vec![1.0, 1.0, 0.625],
                t_target: 1.5,
            }),
            zoo: Some(ZooState::Safa { version: vec![0, 5, 0, 6, 2] }),
            last_latencies: vec![1.0, 2.0, 3.0],
            last_full_latencies: vec![1.5, 2.5, 3.5],
            free_at: vec![0.0, 10.0, 0.0],
            stale: vec![StaleEntry {
                params: vec![Tensor::from_vec(&[2], vec![7.0, 8.0])],
                weight: 16.0,
                mean_loss: 0.5,
                mean_acc: 0.75,
                steps: 3,
                mask: vec![Tensor::from_vec(&[2], vec![1.0, 0.0])],
                arrives_at: 42.0,
                born_round: 5,
                client: 4,
            }],
            resid: vec![
                (3, vec![vec![0.25, -0.5, 0.0, 1.0, -0.0, 2.5], vec![0.125, -0.125]]),
                (11, vec![vec![0.0; 6], vec![7.75, f32::MIN_POSITIVE]]),
            ],
            quarantine: vec![
                QuarEntry { client: 2, strikes: 3, barred_until: 14, last_strike: 6 },
                QuarEntry { client: 8, strikes: 1, barred_until: 7, last_strike: 5 },
            ],
            records: vec![RoundRecord {
                round: 0,
                round_time: 3.0,
                vtime: 3.0,
                cohort: vec![0, 1, 2],
                straggler_ids: vec![2],
                straggler_rates: vec![0.75],
                t_target: 2.5,
                straggler_time: 3.0,
                train_loss: 1.25,
                train_acc: 0.5,
                test_loss: f64::NAN,
                test_acc: f64::NAN,
                invariant_fraction: 0.1,
                calibration_secs: 0.001,
                aggregated: 3,
                dropped_updates: 0,
                stale_folded: 1,
                update_bytes: 48_216,
                vanished: 2,
                quarantined: 1,
                shard_retries: 1,
                quorum_fraction: 0.625,
                straggler_wait: 0.5,
                admitted_stale: 1,
                soft_fraction: 1.0,
            }],
        }
    }

    #[test]
    fn encode_matches_container_framing() {
        // the arena encoder must produce byte-identical output to the
        // reference per-section framing, and reusing dirty buffers must
        // not change a single byte
        let snap = sample_snapshot();
        let reference = {
            let mk = |f: fn(&Snapshot, &mut Writer)| {
                let mut w = Writer::new();
                f(&snap, &mut w);
                w.into_bytes()
            };
            encode_container(&[
                (section::META, mk(Snapshot::enc_meta)),
                (section::ENGINE, mk(Snapshot::enc_engine)),
                (section::MODEL, mk(Snapshot::enc_model)),
                (section::POLICY, mk(Snapshot::enc_policy)),
                (section::FLEET, mk(Snapshot::enc_fleet)),
                (section::SCHED, mk(Snapshot::enc_sched)),
                (section::HISTORY, mk(Snapshot::enc_history)),
                (section::CTRL, mk(Snapshot::enc_ctrl)),
                (section::RESID, mk(Snapshot::enc_resid)),
                (section::QUAR, mk(Snapshot::enc_quar)),
                (section::ZOO, mk(Snapshot::enc_zoo)),
            ])
        };
        assert_eq!(snap.encode(), reference);
        let mut blob = vec![0xAAu8; 9]; // deliberately dirty scratch
        let mut out = vec![0x55u8; 3];
        snap.encode_into(&mut blob, &mut out);
        assert_eq!(out, reference);
        // second use reuses capacity and still matches
        let cap = out.capacity();
        snap.encode_into(&mut blob, &mut out);
        assert_eq!(out, reference);
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn encode_decode_is_a_fixpoint() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        let back = Snapshot::decode(&bytes).unwrap();
        // re-encoding the decoded snapshot must be byte-identical — this
        // covers every field, including NaN bit patterns
        assert_eq!(back.encode(), bytes);
        assert_eq!(back.next_round, 7);
        assert_eq!(back.records.len(), 1);
        assert!(back.records[0].test_loss.is_nan());
        assert_eq!(back.records[0].vanished, 2);
        assert_eq!(back.records[0].quorum_fraction, 0.625);
        assert_eq!(back.params[0].shape(), &[2, 3]);
        assert_eq!(back.availability, snap.availability);
        assert_eq!(back.detection, snap.detection);
        assert_eq!(back.quarantine, snap.quarantine);
    }

    #[test]
    fn bad_magic_version_and_checksum_are_clean_errors() {
        let bytes = sample_snapshot().encode();
        // magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(Snapshot::decode(&bad).unwrap_err().to_string().contains("magic"));
        // future version
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(Snapshot::decode(&bad).unwrap_err().to_string().contains("newer"));
        // corruption anywhere in the payload trips the checksum
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        assert!(Snapshot::decode(&bad).is_err());
        // truncation at every prefix is an error, never a panic
        for cut in 0..bytes.len() {
            assert!(Snapshot::decode(&bytes[..cut]).is_err(), "cut {cut}");
        }
    }

    /// Encode one section through its `&mut Writer` encoder.
    fn enc(snap: &Snapshot, f: fn(&Snapshot, &mut Writer)) -> Vec<u8> {
        let mut w = Writer::new();
        f(snap, &mut w);
        w.into_bytes()
    }

    #[test]
    fn unknown_sections_are_skipped() {
        // splice an extra section id 99 into the table and blob
        let snap = sample_snapshot();
        let out = encode_container(&[
            (99, b"future data".to_vec()),
            (section::META, enc(&snap, Snapshot::enc_meta)),
            (section::ENGINE, enc(&snap, Snapshot::enc_engine)),
            (section::MODEL, enc(&snap, Snapshot::enc_model)),
            (section::POLICY, enc(&snap, Snapshot::enc_policy)),
            (section::FLEET, enc(&snap, Snapshot::enc_fleet)),
            (section::SCHED, enc(&snap, Snapshot::enc_sched)),
            (section::HISTORY, enc(&snap, Snapshot::enc_history)),
            (section::CTRL, enc(&snap, Snapshot::enc_ctrl)),
            (section::RESID, enc(&snap, Snapshot::enc_resid)),
            (section::QUAR, enc(&snap, Snapshot::enc_quar)),
            (section::ZOO, enc(&snap, Snapshot::enc_zoo)),
        ]);
        let back = Snapshot::decode(&out).unwrap();
        assert_eq!(back.next_round, snap.next_round);
        assert_eq!(back.encode(), snap.encode());
    }

    #[test]
    fn snapshot_without_ctrl_section_decodes_as_none() {
        // a container from a pre-controller writer has no CTRL section
        // at all: the reader must not demand one (older snapshots stay
        // resumable), and the decoded state carries no controller state
        let snap = sample_snapshot();
        let out = encode_container(&[
            (section::META, enc(&snap, Snapshot::enc_meta)),
            (section::ENGINE, enc(&snap, Snapshot::enc_engine)),
            (section::MODEL, enc(&snap, Snapshot::enc_model)),
            (section::POLICY, enc(&snap, Snapshot::enc_policy)),
            (section::FLEET, enc(&snap, Snapshot::enc_fleet)),
            (section::SCHED, enc(&snap, Snapshot::enc_sched)),
            (section::HISTORY, enc(&snap, Snapshot::enc_history)),
        ]);
        let back = Snapshot::decode(&out).unwrap();
        assert!(back.ctrl.is_none());
        // the RESID section is likewise optional: absent means no q8
        // residual state, not an error
        assert!(back.resid.is_empty());
        // and so is QUAR: absent means an empty quarantine ledger, so
        // pre-chaos snapshots stay resumable
        assert!(back.quarantine.is_empty());
        // ZOO too: absent means no zoo mitigation state, so pre-zoo
        // snapshots stay resumable
        assert!(back.zoo.is_none());
        assert_eq!(back.next_round, snap.next_round);
        assert_eq!(back.detection, snap.detection);
        // and a present-but-empty CTRL section is the same as none
        let mut empty = snap.clone();
        empty.ctrl = None;
        empty.zoo = None;
        let back = Snapshot::decode(&empty.encode()).unwrap();
        assert!(back.ctrl.is_none());
        assert!(back.zoo.is_none());
    }

    #[test]
    fn zoo_state_round_trips_both_variants() {
        let mut snap = sample_snapshot();
        snap.zoo = Some(ZooState::Helios { frac: vec![1.0, 0.5, 0.8125] });
        let back = Snapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.zoo, snap.zoo);
        snap.zoo = Some(ZooState::Safa { version: vec![9, 0, 3] });
        let back = Snapshot::decode(&snap.encode()).unwrap();
        assert_eq!(back.zoo, snap.zoo);
        assert_eq!(back.stale[0].client, 4);
    }

    #[test]
    fn store_saves_atomically_rotates_and_resolves_latest() {
        let dir = std::env::temp_dir().join(format!(
            "fluid-snapstore-{}-{:x}",
            std::process::id(),
            fnv1a(b"store-test")
        ));
        let _ = fs::remove_dir_all(&dir);
        let store = SnapshotStore::new(&dir, 2).unwrap();
        let mut snap = sample_snapshot();
        for round in [3usize, 6, 9, 12] {
            snap.next_round = round;
            store.save(&snap).unwrap();
        }
        let files = store.list().unwrap();
        let rounds: Vec<usize> = files.iter().map(|(r, _)| *r).collect();
        assert_eq!(rounds, vec![9, 12], "keep-last-2 rotation");
        // no tmp leftovers
        for e in fs::read_dir(&dir).unwrap() {
            let name = e.unwrap().file_name();
            assert!(!name.to_string_lossy().ends_with(".tmp"), "{name:?}");
        }
        assert_eq!(
            store.latest().unwrap().unwrap(),
            dir.join(format!("snap-000012.{EXTENSION}"))
        );
        // dir resume resolves to the newest snapshot
        let resumed = SnapshotStore::load_resume(&dir).unwrap();
        assert_eq!(resumed.next_round, 12);
        // file resume loads that exact file
        let direct =
            SnapshotStore::load_file(&dir.join(format!("snap-000009.{EXTENSION}"))).unwrap();
        assert_eq!(direct.next_round, 9);
        // empty dir is a clean error
        let empty = dir.join("empty");
        fs::create_dir_all(&empty).unwrap();
        assert!(SnapshotStore::load_resume(&empty).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprint_tracks_semantic_fields_only() {
        use crate::dropout::PolicyKind;
        let a = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
        let mut b = a.clone();
        b.threads = a.threads + 3;
        b.checkpoint_every = 5;
        b.checkpoint_dir = Some("/tmp/x".into());
        b.checkpoint_keep = 9;
        b.resume_from = Some("/tmp/y".into());
        b.crash_after = Some(4);
        // shard topology is non-semantic too — this is the N→M resume
        // rule (DESIGN.md §11): a snapshot taken under 4 shards must
        // resume under 1 shard (and vice versa) without a fingerprint
        // mismatch, because results are bit-identical either way
        b.shards = 4;
        b.shard_crash_after = Some((1, 2));
        b.shard_retry = true;
        // the retry budget is recovery topology, not trajectory: a run
        // checkpointed under --shard-retry-max 1 resumes under 3
        b.shard_retry_max = 3;
        // the quorum floor only aborts — rounds that pass it are
        // bit-identical at any value, so a QuorumFailed run can resume
        // from its last checkpoint under a relaxed floor
        b.quorum = 0.5;
        assert_eq!(
            config_fingerprint(&a),
            config_fingerprint(&b),
            "non-semantic knobs must not change the fingerprint"
        );
        let mut c = a.clone();
        c.seed = 43;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&c));
        let mut d = a.clone();
        d.lr = 0.005;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&d));
        // the controller knobs shape the trajectory: an ewma run can
        // never silently resume as a paper run (or vice versa)
        let mut e = a.clone();
        e.adapt = crate::straggler::AdaptMode::Ewma;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&e));
        let mut f = a.clone();
        f.adapt_gain = 0.75;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&f));
        // the chaos script shapes the trajectory: semantic
        let mut g = a.clone();
        g.chaos = crate::engine::ChaosConfig::parse("storm").unwrap();
        assert_ne!(config_fingerprint(&a), config_fingerprint(&g));
        // so do the mitigation-policy knobs: a safa run can never
        // silently resume as a fluid run, nor under a different lag
        let mut h = a.clone();
        h.mitigation = crate::policy::Mitigation::Safa;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&h));
        let mut i = a.clone();
        i.mitigation_trade_off = 0.5;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&i));
        let mut j = a.clone();
        j.safa_lag = 5;
        assert_ne!(config_fingerprint(&a), config_fingerprint(&j));
    }
}
