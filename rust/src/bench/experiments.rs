//! Shared experiment-sweep helpers for the table/figure bench harnesses.
//!
//! Every paper table is some cross product of (model, policy, r, seed);
//! these helpers keep the bench binaries thin and the protocol identical
//! across tables. Scaled-down defaults keep each bench minutes-scale on
//! CPU; `--full` restores paper-sized sweeps (see DESIGN.md §4).

use crate::coordinator::{self, ExperimentConfig, ExperimentResult};
use crate::dropout::PolicyKind;
use crate::runtime::Session;
use crate::util::stats;

/// Accuracy over `seeds` runs: returns (mean, std) of final test accuracy.
pub fn accuracy_over_seeds(
    sess: &Session,
    base: &ExperimentConfig,
    seeds: usize,
) -> crate::Result<(f64, f64, Vec<f64>)> {
    let mut accs = Vec::with_capacity(seeds);
    for s in 0..seeds {
        let mut cfg = base.clone();
        cfg.seed = base.seed + 1000 * s as u64;
        let res = coordinator::run(sess, &cfg)?;
        accs.push(res.final_test_acc);
    }
    Ok((stats::mean(&accs), stats::std_dev(&accs), accs))
}

/// One full run (convenience wrapper that keeps bench mains tiny).
pub fn single(sess: &Session, cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    coordinator::run(sess, cfg)
}

/// The Table-2 protocol: fixed straggler keep-rate, mobile fleet.
pub fn table2_config(model: &str, policy: PolicyKind, r: f64, full: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mobile(model, policy);
    cfg.fixed_rate = Some(r);
    cfg.lr = tuned_lr(model);
    if full {
        cfg.rounds = 60;
        cfg.samples_per_client = 100;
        cfg.local_steps = 4;
    } else {
        // 16 rounds is the quick-mode floor at which invariant dropout's
        // ordering becomes visible — invariance needs some training to be
        // informative (the paper trains 250 FEMNIST epochs); below ~12
        // rounds all policies are statistically tied.
        cfg.rounds = 16;
        cfg.samples_per_client = 40;
        cfg.local_steps = 3;
    }
    cfg.eval_every = cfg.rounds; // final-only eval (accuracy protocol)
    cfg
}

/// Learning rates tuned for the *synthetic* datasets (the paper's rates
/// target the real corpora; synthetic templates train faster at slightly
/// higher lr — same value across all policies, so comparisons are fair).
pub fn tuned_lr(model: &str) -> f32 {
    match model {
        "femnist_cnn" => 0.01,
        "cifar_vgg9" | "cifar_resnet18" => 0.01,
        "shakespeare_lstm" => 0.05,
        _ => 0.01,
    }
}

/// The scale-study protocol (Fig 5 / Fig 8 / Table 4).
pub fn scale_config(
    model: &str,
    policy: PolicyKind,
    clients: usize,
    r: f64,
    full: bool,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::scale(model, policy, clients);
    cfg.fixed_rate = Some(r);
    cfg.lr = tuned_lr(model);
    if full {
        cfg.rounds = 40;
        cfg.samples_per_client = 40;
        cfg.local_steps = 2;
    } else {
        cfg.rounds = 8;
        cfg.samples_per_client = 16;
        cfg.local_steps = 1;
    }
    cfg.eval_every = cfg.rounds;
    cfg.recalibrate_every = 2;
    cfg
}

/// Open the default session or exit with a hint.
pub fn session_or_exit() -> Session {
    match Session::new(Session::default_dir()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!(
                "cannot open PJRT session ({e:#}).\nRun `make artifacts` first."
            );
            std::process::exit(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builders() {
        let t2 = table2_config("femnist_cnn", PolicyKind::Invariant, 0.75, false);
        assert_eq!(t2.fixed_rate, Some(0.75));
        assert!(t2.mobile_fleet);
        let t2f = table2_config("femnist_cnn", PolicyKind::Invariant, 0.75, true);
        assert!(t2f.rounds > t2.rounds);
        let sc = scale_config("cifar_vgg9", PolicyKind::Ordered, 50, 0.75, false);
        assert_eq!(sc.clients, 50);
        assert!(!sc.mobile_fleet);
    }
}
