//! In-repo benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`Bench`] to get
//! warmup + repeated timed runs with mean/std/min reporting, plus the
//! experiment-grade sweep helpers the table/figure benches share.

pub mod experiments;

use crate::util::stats::Summary;
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10.3} ms ± {:>7.3} (min {:>9.3}, n={})",
            self.name,
            self.mean_s * 1e3,
            self.std_s * 1e3,
            self.min_s * 1e3,
            self.iters
        )
    }
}

/// Micro/meso benchmark runner.
pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: 3,
            iters: 10,
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Self { warmup, iters }
    }

    /// Time `f` (excluding warmup runs).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters.max(1) {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            iters: self.iters,
            mean_s: s.mean(),
            std_s: s.std_dev(),
            min_s: s.min,
        }
    }
}

/// Convenience: is `--full` passed to a bench binary? (cargo bench passes
/// `--bench` after the binary name; ignore unknown flags.)
pub fn full_mode() -> bool {
    std::env::args().any(|a| a == "--full")
}

/// Bench-harness seed list: `--seeds N` (default 3, 5 in full mode).
pub fn seed_count() -> usize {
    let args: Vec<String> = std::env::args().collect();
    for i in 0..args.len() {
        if args[i] == "--seeds" {
            if let Some(v) = args.get(i + 1).and_then(|s| s.parse().ok()) {
                return v;
            }
        }
    }
    if full_mode() {
        5
    } else {
        3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::new(1, 5);
        let m = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s);
        assert_eq!(m.iters, 5);
        assert!(m.report().contains("spin"));
    }

    #[test]
    fn default_config() {
        let b = Bench::default();
        assert_eq!(b.warmup, 3);
        assert_eq!(b.iters, 10);
    }
}
