//! `fluid` — the FLuID coordinator CLI (L3 leader entrypoint).
//!
//! Subcommands:
//! * `train`   — run one federated experiment and print/save the history
//! * `devices` — print the device fleet and its per-model epoch times
//! * `sweep`   — run a policy x rate sweep (Table-2 style) and print a table
//!
//! Python never runs here: the binary executes AOT artifacts produced
//! once by `make artifacts`.

use fluid::coordinator::{self, report, ExperimentConfig};
use fluid::dropout::PolicyKind;
use fluid::engine::{ChaosConfig, ScenarioConfig, SyncMode};
use fluid::fl::{Compression, SamplerKind};
use fluid::runtime::Session;
use fluid::straggler::{mobile_fleet, AdaptMode};
use fluid::util::cli::Args;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let code = match cmd {
        "train" => cmd_train(&rest),
        "devices" => cmd_devices(),
        "sweep" => cmd_sweep(&rest),
        _ => {
            println!(
                "fluid — Federated Learning using Invariant Dropout (NeurIPS 2023 reproduction)\n\n\
                 usage: fluid <command> [options]\n\n\
                 commands:\n\
                 \x20 train     run one federated experiment (--help for options)\n\
                 \x20 sweep     policy x sub-model-size sweep, Table-2 style\n\
                 \x20 devices   show the Table-1 device fleet\n"
            );
            0
        }
    };
    std::process::exit(code);
}

fn train_args(program: &str) -> Args {
    Args::new(program, "run one FLuID experiment")
        .opt("model", "femnist_cnn", "femnist_cnn|cifar_vgg9|shakespeare_lstm|cifar_resnet18")
        .opt("policy", "invariant", "none|random|ordered|invariant|exclude|fedprox|safa|helios")
        .opt("trade-off", "1", "fedprox: elastic mix new = λ·agg + (1-λ)·old")
        .opt("safa-lag", "2", "safa: admit stale updates up to this version lag")
        .opt("rounds", "30", "federated rounds")
        .opt("clients", "5", "number of clients")
        .opt("spc", "60", "samples per client")
        .opt("local-steps", "4", "local SGD steps per round")
        .opt("lr", "", "learning rate (default: paper value per model)")
        .opt("rate", "", "fixed straggler keep-rate r (default: FLuID auto)")
        .opt("straggler-frac", "0.2", "fraction of fleet treated as stragglers")
        .opt("adapt", "paper", "sub-model sizing: paper (menu snap) | ewma (closed loop)")
        .opt("adapt-gain", "0.5", "ewma: proportional gain of the rate step")
        .opt("adapt-deadband", "0.05", "ewma: hysteresis half-width around the setpoint")
        .opt("rate-min", "0.1", "ewma: floor on adaptive keep-rates")
        .opt("sample-frac", "1.0", "client sampling fraction per round")
        .opt("recalibrate", "1", "recalibration period (rounds)")
        .opt("sync-mode", "full", "round barrier: full|deadline|buffered")
        .opt("deadline-mult", "1.25", "deadline cutoff as a multiple of T_target")
        .opt("buffer-k", "0", "buffered: aggregate after k updates (0 = 80% of clients)")
        .opt("fleet-size", "0", "fleet mode: simulate this many clients (0 = classic)")
        .opt("sample-k", "0", "fleet mode: cohort size per round (0 = fleet/100)")
        .opt("sampler", "uniform", "fleet sampler: uniform|weighted|available")
        .opt("scenario", "none", "fleet dynamics: none|churn|drift|flux|storm[:rate]")
        .opt("seed", "42", "PRNG seed")
        .opt("threads", "0", "worker threads (0 = auto)")
        .opt("eval-every", "5", "test-eval period (rounds)")
        .opt("checkpoint-every", "0", "write a resumable snapshot every N rounds (0 = off)")
        .opt("checkpoint-dir", "checkpoints", "snapshot directory for --checkpoint-every")
        .opt("checkpoint-keep", "3", "keep only the newest N snapshots")
        .opt("resume", "", "resume from a snapshot file, or a dir (newest snapshot)")
        .opt("crash-after", "", "fault injection: exit(137) once N rounds completed (soak)")
        .opt("shards", "1", "aggregator shards (bit-identical at every value)")
        .opt("shard-crash-after", "", "fault injection: kill shard S at round R (format S:R)")
        .opt("shard-retry-max", "0", "bounded shard-slice retry budget (0 = legacy --shard-retry)")
        .opt("chaos", "none", "seeded faults: none|vanish|hang|corrupt|nan|shards|storm[:rate]")
        .opt("quorum", "0", "min fraction of fresh on-time updates per round (0 = off)")
        .opt("compress", "dense", "update codec: dense|sparse|q8 (dense = bit-exact reference)")
        .opt("out", "", "write result JSON to this path")
        .opt("artifacts", "", "artifacts dir (default: ./artifacts or $FLUID_ARTIFACTS)")
        .flag("sim", "run the runtime-free simulation backend (no artifacts)")
        .flag("shard-retry", "re-dispatch a killed shard's slice instead of failing")
        .flag("fluctuate", "enable the Fig-4b runtime fluctuation protocol")
        .flag("static-stragglers", "freeze the straggler set after first detection")
        .flag("synthetic-fleet", "use a synthetic fleet instead of the 5 phones")
}

fn build_config(a: &Args) -> ExperimentConfig {
    let model = a.get("model");
    // dropout names select a policy under the fluid mitigation; the zoo
    // names (fedprox|safa|helios) select a whole mitigation family
    let (policy, mitigation) =
        fluid::policy::parse_policy_arg(&a.get("policy")).unwrap_or_else(|| {
            eprintln!(
                "unknown policy {:?} \
                 (none|random|ordered|invariant|exclude|fedprox|safa|helios)",
                a.get("policy")
            );
            std::process::exit(2);
        });
    let mut cfg = ExperimentConfig::mobile(&model, policy);
    cfg.mitigation = mitigation;
    cfg.mitigation_trade_off = a.get_f64("trade-off");
    cfg.safa_lag = a.get_usize("safa-lag");
    cfg.rounds = a.get_usize("rounds");
    cfg.clients = a.get_usize("clients");
    cfg.samples_per_client = a.get_usize("spc");
    cfg.local_steps = a.get_usize("local-steps");
    if !a.get("lr").is_empty() {
        cfg.lr = a.get_f64("lr") as f32;
    }
    if !a.get("rate").is_empty() {
        cfg.fixed_rate = Some(a.get_f64("rate"));
    }
    cfg.straggler_fraction = a.get_f64("straggler-frac");
    cfg.adapt = AdaptMode::parse(&a.get("adapt")).unwrap_or_else(|| {
        eprintln!("unknown adapt mode {:?} (paper|ewma)", a.get("adapt"));
        std::process::exit(2);
    });
    cfg.adapt_gain = a.get_f64("adapt-gain");
    cfg.adapt_deadband = a.get_f64("adapt-deadband");
    cfg.rate_min = a.get_f64("rate-min");
    cfg.sample_fraction = a.get_f64("sample-frac");
    cfg.recalibrate_every = a.get_usize("recalibrate").max(1);
    cfg.sync_mode = match a.get("sync-mode").as_str() {
        "full" | "barrier" | "sync" => SyncMode::FullBarrier,
        "deadline" => SyncMode::Deadline {
            multiple_of_t_target: a.get_f64("deadline-mult"),
        },
        "buffered" | "async" => {
            let k = a.get_usize("buffer-k");
            // default: wait for 80% of the clients that actually
            // participate per round (sampling included) — otherwise a
            // sampled run would clamp k to the arrival count and
            // silently degenerate to a full barrier
            let k = if k == 0 {
                let per_round = (cfg.clients as f64 * cfg.sample_fraction.min(1.0)).ceil();
                (per_round * 0.8).ceil() as usize
            } else {
                k
            };
            SyncMode::Buffered { k: k.max(1) }
        }
        other => {
            eprintln!("unknown sync mode {other:?} (full|deadline|buffered)");
            std::process::exit(2);
        }
    };
    cfg.seed = a.get_u64("seed");
    cfg.eval_every = a.get_usize("eval-every").max(1);
    cfg.fluctuation = a.get_flag("fluctuate");
    cfg.static_stragglers = a.get_flag("static-stragglers");
    cfg.mobile_fleet = !a.get_flag("synthetic-fleet");
    let fleet_size = a.get_usize("fleet-size");
    if fleet_size > 0 {
        cfg.fleet_size = Some(fleet_size);
        cfg.mobile_fleet = false;
        let k = a.get_usize("sample-k");
        cfg.sample_k = if k == 0 {
            (fleet_size / 100).clamp(1, 512)
        } else {
            k
        };
    }
    cfg.sampler = SamplerKind::parse(&a.get("sampler")).unwrap_or_else(|| {
        eprintln!("unknown sampler {:?} (uniform|weighted|available)", a.get("sampler"));
        std::process::exit(2);
    });
    cfg.scenario = match ScenarioConfig::parse(&a.get("scenario")) {
        Ok(sc) => sc,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let threads = a.get_usize("threads");
    if threads > 0 {
        cfg.threads = threads;
    }
    let every = a.get_usize("checkpoint-every");
    if every > 0 {
        cfg.checkpoint_every = every;
        cfg.checkpoint_dir = Some(a.get("checkpoint-dir").into());
        cfg.checkpoint_keep = a.get_usize("checkpoint-keep").max(1);
    }
    if !a.get("resume").is_empty() {
        cfg.resume_from = Some(a.get("resume").into());
    }
    if !a.get("crash-after").is_empty() {
        cfg.crash_after = Some(a.get_usize("crash-after"));
    }
    cfg.shards = a.get_usize("shards").max(1);
    if !a.get("shard-crash-after").is_empty() {
        let spec = a.get("shard-crash-after");
        let parsed = spec.split_once(':').and_then(|(s, r)| {
            Some((s.trim().parse::<usize>().ok()?, r.trim().parse::<usize>().ok()?))
        });
        match parsed {
            Some(pair) => cfg.shard_crash_after = Some(pair),
            None => {
                eprintln!("invalid --shard-crash-after {spec:?} (expected SHARD:ROUND)");
                std::process::exit(2);
            }
        }
    }
    cfg.shard_retry = a.get_flag("shard-retry");
    cfg.shard_retry_max = a.get_usize("shard-retry-max");
    cfg.chaos = match ChaosConfig::parse(&a.get("chaos")) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    cfg.quorum = a.get_f64("quorum");
    cfg.compress = Compression::parse(&a.get("compress")).unwrap_or_else(|| {
        eprintln!("unknown compress mode {:?} (dense|sparse|q8)", a.get("compress"));
        std::process::exit(2);
    });
    // the sim/fleet paths serve only the built-in synthetic datasets;
    // fail with a clean message instead of panicking deep in the engine
    // (the classic artifact path accepts any model with a manifest and
    // reports a missing one contextually)
    if (a.get_flag("sim") || cfg.fleet_size.is_some())
        && !fluid::data::is_known_model(&cfg.model)
    {
        eprintln!(
            "unknown model {:?} for the sim/fleet path \
             (femnist_cnn|cifar_vgg9|cifar_resnet18|shakespeare_lstm)",
            cfg.model
        );
        std::process::exit(2);
    }
    // surface menu/controller misconfiguration at parse time instead of
    // deep inside the engine
    if let Err(e) = cfg.validate() {
        eprintln!("invalid configuration: {e:#}");
        std::process::exit(2);
    }
    cfg
}

fn open_session(a: &Args) -> Session {
    let dir = if a.get("artifacts").is_empty() {
        Session::default_dir()
    } else {
        a.get("artifacts").into()
    };
    Session::new(&dir).unwrap_or_else(|e| {
        eprintln!("failed to open PJRT session at {}: {e:#}", dir.display());
        std::process::exit(1);
    })
}

fn cmd_train(argv: &[String]) -> i32 {
    let a = match train_args("fluid train")
        .flag("matrix", "run the policy x scenario leaderboard grid (sim backend)")
        .opt("policies", "none,invariant,fedprox,safa,helios", "matrix: policies to race")
        .opt("scenarios", "storm,drift", "matrix: fleet scenarios to race under")
        .opt("target-acc", "0.5", "matrix: test-acc threshold for time-to-target")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = build_config(&a);
    if a.get_flag("matrix") {
        return cmd_matrix(&a, cfg);
    }
    let population = cfg.fleet_size.unwrap_or(cfg.clients);
    let result = if a.get_flag("sim") {
        println!(
            "fluid train: model={} policy={} clients={} rounds={} sync={} (backend=sim)",
            cfg.model,
            cfg.policy.name(),
            population,
            cfg.rounds,
            cfg.sync_mode.name(),
        );
        coordinator::run_sim(&cfg)
    } else {
        let sess = open_session(&a);
        println!(
            "fluid train: model={} policy={} clients={} rounds={} sync={} (platform={})",
            cfg.model,
            cfg.policy.name(),
            population,
            cfg.rounds,
            cfg.sync_mode.name(),
            sess.platform()
        );
        coordinator::run(&sess, &cfg)
    };
    let res = match result {
        Ok(r) => r,
        Err(e) => {
            // --crash-after fault injection: die as if SIGKILLed (137),
            // which is what the kill/resume soak workflows assert on
            if let Some(f) = e.downcast_ref::<fluid::engine::FaultInjected>() {
                eprintln!("fluid: {f} — exiting 137");
                return 137;
            }
            // --shard-crash-after without --shard-retry: a shard died
            // mid-round and its slice is unrecoverable — same exit
            // convention as a whole-process kill
            if let Some(f) = e.downcast_ref::<fluid::engine::ShardFault>() {
                eprintln!("fluid: {f} — exiting 137");
                return 137;
            }
            // --quorum under chaos: too few fresh updates survived the
            // barrier — the round aborted before any state mutated, so
            // the last checkpoint is a clean resume point; same exit
            // convention as the other injected faults
            if let Some(f) = e.downcast_ref::<fluid::engine::QuorumFailed>() {
                eprintln!("fluid: {f} — exiting 137");
                return 137;
            }
            eprintln!("experiment failed: {e:#}");
            return 1;
        }
    };
    // round table
    let rows: Vec<Vec<String>> = res
        .records
        .iter()
        .map(|r| {
            vec![
                r.round.to_string(),
                format!("{:.2}", r.round_time),
                format!("{:.2}", r.vtime),
                format!("{:.4}", r.train_loss),
                if r.test_acc.is_nan() {
                    "-".into()
                } else {
                    format!("{:.3}", r.test_acc)
                },
                format!("{:?}", r.straggler_ids),
                format!("{:?}", r.straggler_rates),
                format!("{:.3}", r.invariant_fraction),
            ]
        })
        .collect();
    println!(
        "{}",
        report::text_table(
            &["round", "t_round", "vtime", "loss", "test_acc", "stragglers", "rates", "inv%"],
            &rows
        )
    );
    println!(
        "final: test_acc={:.4} test_loss={:.4} vtime={:.1}s calib_overhead={:.2}%",
        res.final_test_acc,
        res.final_test_loss,
        res.total_vtime,
        res.calibration_overhead() * 100.0
    );
    if !a.get("out").is_empty() {
        let path = a.get("out");
        if let Err(e) = std::fs::write(&path, res.to_json().to_string_pretty()) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_matrix(a: &Args, base: ExperimentConfig) -> i32 {
    // the grid always runs on the deterministic sim backend so the
    // leaderboard JSON is byte-identical at any --threads
    if !fluid::data::is_known_model(&base.model) {
        eprintln!(
            "unknown model {:?} for --matrix (sim backend only)",
            base.model
        );
        return 2;
    }
    let mc = coordinator::MatrixConfig {
        base,
        policies: a.get_list("policies"),
        scenarios: a.get_list("scenarios"),
        target_acc: a.get_f64("target-acc"),
    };
    eprintln!(
        "fluid matrix: {} policies x {} scenarios (backend=sim)",
        mc.policies.len(),
        mc.scenarios.len()
    );
    let json = match coordinator::run_matrix(&mc) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("matrix failed: {e:#}");
            return 1;
        }
    };
    let text = json.to_string_pretty();
    if a.get("out").is_empty() {
        println!("{text}");
    } else {
        let path = a.get("out");
        if let Err(e) = std::fs::write(&path, &text) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        eprintln!("wrote {path}");
    }
    0
}

fn cmd_devices() -> i32 {
    let rows: Vec<Vec<String>> = mobile_fleet()
        .iter()
        .map(|d| {
            vec![
                d.name.clone(),
                d.year.to_string(),
                format!("{:.1}", d.base_femnist),
                format!("{:.1}", d.base_cifar),
                format!("{:.1}", d.base_shakespeare),
                format!("{:.1}", d.bandwidth_mbps),
            ]
        })
        .collect();
    println!(
        "{}",
        report::text_table(
            &["device", "year", "femnist s/ep", "cifar s/ep", "shakespeare s/ep", "MB/s"],
            &rows
        )
    );
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let a = match train_args("fluid sweep")
        .opt("rates", "0.95,0.85,0.75,0.65,0.5", "keep-rates to sweep")
        .opt("policies", "random,ordered,invariant", "policies to sweep")
        .parse_from(argv)
    {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let sess = open_session(&a);
    let base = build_config(&a);
    let mut rows = Vec::new();
    for pol in a.get_list("policies") {
        let Some(policy) = PolicyKind::parse(&pol) else {
            eprintln!("unknown policy {pol}");
            return 2;
        };
        for &r in &a.get_f64_list("rates") {
            let mut cfg = base.clone();
            cfg.policy = policy;
            cfg.fixed_rate = Some(r);
            match coordinator::run(&sess, &cfg) {
                Ok(res) => rows.push(vec![
                    pol.clone(),
                    format!("{r:.2}"),
                    format!("{:.2}", res.final_test_acc * 100.0),
                    format!("{:.1}", res.total_vtime),
                ]),
                Err(e) => {
                    eprintln!("run failed ({pol}, r={r}): {e:#}");
                    return 1;
                }
            }
        }
    }
    println!(
        "{}",
        report::text_table(&["policy", "r", "test acc %", "vtime s"], &rows)
    );
    0
}
