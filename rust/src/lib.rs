//! # FLuID — Federated Learning using Invariant Dropout
//!
//! Production-grade reproduction of *"FLuID: Mitigating Stragglers in
//! Federated Learning using Invariant Dropout"* (Wang, Nair, Mahajan —
//! NeurIPS 2023) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FLuID coordinator: straggler detection,
//!   drop-threshold calibration, invariant-neuron identification, masked
//!   FedAvg aggregation, and a virtual-time heterogeneous device fleet,
//!   executed by the layered [`engine`] (pluggable client executors,
//!   event-scheduled virtual time, sync / deadline / buffered rounds).
//! * **L2** — JAX model step functions (`python/compile/model.py`),
//!   AOT-lowered once to `artifacts/*.hlo.txt` and executed here through
//!   the PJRT CPU client ([`runtime`]). Python never runs at runtime.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for the masked
//!   dense hot path and the per-neuron invariant scan.
//!
//! See `DESIGN.md` for the module map and the per-experiment index, and
//! `EXPERIMENTS.md` for reproduced tables/figures.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod dropout;
pub mod engine;
pub mod fl;
pub mod jsonlite;
pub mod model;
pub mod policy;
pub mod runtime;
pub mod snapshot;
pub mod straggler;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
