//! Report emission: markdown tables and aligned text tables for the
//! bench harnesses (EXPERIMENTS.md is assembled from these).

/// Render a GitHub-flavored markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = String::new();
    s.push('|');
    for h in headers {
        s.push_str(&format!(" {h} |"));
    }
    s.push('\n');
    s.push('|');
    for _ in headers {
        s.push_str("---|");
    }
    s.push('\n');
    for row in rows {
        s.push('|');
        for cell in row {
            s.push_str(&format!(" {cell} |"));
        }
        s.push('\n');
    }
    s
}

/// Render aligned plain-text columns (for terminal bench output).
pub fn text_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut s = String::new();
    for (i, h) in headers.iter().enumerate() {
        s.push_str(&format!("{:<w$}  ", h, w = widths[i]));
    }
    s.push('\n');
    for (i, _) in headers.iter().enumerate() {
        s.push_str(&format!("{:-<w$}  ", "", w = widths[i]));
    }
    s.push('\n');
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            s.push_str(&format!("{:<w$}  ", cell, w = widths[i]));
        }
        s.push('\n');
    }
    s
}

/// Format a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

/// Format mean ± std as the paper's (µ, σ) pairs.
pub fn mean_std(mu: f64, sigma: f64) -> String {
    format!("{:.1} ± {:.1}", mu * 100.0, sigma * 100.0)
}

/// Append a section to EXPERIMENTS-style output files.
pub fn append_section(path: &str, title: &str, body: &str) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    writeln!(f, "\n## {title}\n\n{body}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0], "| a | b |");
        assert_eq!(lines[1], "|---|---|");
        assert_eq!(lines[3], "| 3 | 4 |");
    }

    #[test]
    fn text_alignment() {
        let t = text_table(&["name", "x"], &[vec!["longvalue".into(), "1".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("longvalue"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.8064), "80.6");
        assert_eq!(mean_std(0.806, 0.002), "80.6 ± 0.2");
    }
}
