//! The policy × scenario leaderboard harness (`fluid train --matrix`).
//!
//! Races every requested mitigation policy against every requested fleet
//! scenario under *identical seeds* — same cohort draws, same latency
//! jitter, same churn script — so the only thing that differs between
//! two cells in a column is the mitigation itself. Each cell runs the
//! runtime-free simulation backend ([`super::run_sim`]), which is pinned
//! bit-identical across `--threads` and `--shards`, so the emitted
//! leaderboard JSON is byte-identical across runs at any thread count
//! (the suite's matrix smoke diffs two runs outright).
//!
//! The report carries only *algorithmic* quantities (virtual time,
//! accuracy, bytes moved, admission counts) — never wall-clock — and is
//! emitted through [`crate::jsonlite`], whose sorted-key objects make
//! the byte layout a pure function of the values.

use super::{run_sim, ExperimentConfig, ExperimentResult};
use crate::engine::{ScenarioConfig, SyncMode};
use crate::jsonlite::Json;
use crate::policy::{active_id, parse_policy_arg, Mitigation};

/// Schema tag stamped into the leaderboard JSON; bump when the cell
/// field set changes shape.
pub const LEADERBOARD_SCHEMA: &str = "fluid-leaderboard-v1";

/// One policy × scenario grid to race.
#[derive(Clone, Debug)]
pub struct MatrixConfig {
    /// the shared experiment shape: model, fleet, rounds, seed — every
    /// cell clones this and changes only policy + scenario
    pub base: ExperimentConfig,
    /// `--policy` argument per column (dropout names and zoo names alike)
    pub policies: Vec<String>,
    /// `--scenario` argument per row (`none` is legal)
    pub scenarios: Vec<String>,
    /// accuracy bar for the time-to-accuracy metric
    pub target_acc: f64,
}

/// The algorithmic summary of one finished cell.
#[derive(Clone, Debug)]
pub struct CellMetrics {
    /// reporting id ([`active_id`]) of the policy the cell ran
    pub policy: &'static str,
    pub scenario: String,
    pub final_test_acc: f64,
    /// virtual seconds until test accuracy first reached the target
    /// (-1.0 when it never did)
    pub time_to_target: f64,
    /// rounds completed when the target was first reached (-1 otherwise)
    pub rounds_to_target: i64,
    /// mean per-round wait on the slowest straggler beyond T_target
    pub mean_straggler_wait: f64,
    pub mean_round_time: f64,
    /// summed wire bytes across every aggregated payload
    pub total_update_bytes: usize,
    /// stale updates admitted (semi-async lag tolerance)
    pub admitted_stale: usize,
    /// late/stale updates refused or discarded
    pub dropped_updates: usize,
    /// mean soft-training fraction (1.0 unless a policy trims epochs)
    pub mean_soft_fraction: f64,
}

/// Derive one cell's config from the shared base. Zoo policies get the
/// coherence adjustments `ExperimentConfig::validate` demands, applied
/// the same deterministic way for every cell:
///
/// * `fedprox` — `mitigation_trade_off` defaults to 0.5 when the base
///   left it at the no-op 1.0 (a λ=1 cell would be indistinguishable
///   from `none`); other policies force it back to 1.0.
/// * `safa` — requires `SyncMode::Buffered`; when the base runs another
///   barrier, the cell switches to `Buffered{k = max(1, ⌊0.8·cohort⌋)}`.
/// * every zoo policy runs `PolicyKind::None` + paper detection (that is
///   what [`parse_policy_arg`] returns).
pub fn cell_config(
    base: &ExperimentConfig,
    policy_arg: &str,
    scenario_arg: &str,
) -> crate::Result<ExperimentConfig> {
    let (kind, mitigation) = parse_policy_arg(policy_arg).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown policy {policy_arg:?} \
             (none|random|ordered|invariant|exclude|fedprox|safa|helios)"
        )
    })?;
    let mut cfg = base.clone();
    cfg.policy = kind;
    cfg.mitigation = mitigation;
    cfg.scenario = ScenarioConfig::parse(scenario_arg)
        .map_err(|e| anyhow::anyhow!("scenario {scenario_arg:?}: {e}"))?;
    cfg.mitigation_trade_off = if mitigation == Mitigation::FedProx {
        if base.mitigation_trade_off == 1.0 {
            0.5
        } else {
            base.mitigation_trade_off
        }
    } else {
        1.0
    };
    if mitigation == Mitigation::Safa && !matches!(cfg.sync_mode, SyncMode::Buffered { .. }) {
        let cohort = cfg.fleet_size.map(|_| cfg.sample_k).unwrap_or(cfg.clients);
        cfg.sync_mode = SyncMode::Buffered {
            k: (cohort * 4 / 5).max(1),
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Reduce one finished run to its leaderboard cell.
pub fn cell_metrics(
    res: &ExperimentResult,
    scenario: &str,
    target_acc: f64,
) -> CellMetrics {
    let n = res.records.len().max(1) as f64;
    let hit = res
        .records
        .iter()
        .find(|r| !r.test_acc.is_nan() && r.test_acc >= target_acc);
    CellMetrics {
        policy: active_id(res.mitigation, res.policy),
        scenario: scenario.to_string(),
        final_test_acc: res.final_test_acc,
        time_to_target: hit.map_or(-1.0, |r| r.vtime),
        rounds_to_target: hit.map_or(-1, |r| r.round as i64 + 1),
        mean_straggler_wait: res.records.iter().map(|r| r.straggler_wait).sum::<f64>() / n,
        mean_round_time: res.records.iter().map(|r| r.round_time).sum::<f64>() / n,
        total_update_bytes: res.records.iter().map(|r| r.update_bytes).sum(),
        admitted_stale: res.records.iter().map(|r| r.admitted_stale).sum(),
        dropped_updates: res.records.iter().map(|r| r.dropped_updates).sum(),
        mean_soft_fraction: res.records.iter().map(|r| r.soft_fraction).sum::<f64>() / n,
    }
}

impl CellMetrics {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("policy", self.policy)
            .set("scenario", self.scenario.as_str())
            .set("final_test_acc", self.final_test_acc)
            .set("time_to_target", self.time_to_target)
            .set("rounds_to_target", self.rounds_to_target)
            .set("mean_straggler_wait", self.mean_straggler_wait)
            .set("mean_round_time", self.mean_round_time)
            .set("total_update_bytes", self.total_update_bytes)
            .set("admitted_stale", self.admitted_stale)
            .set("dropped_updates", self.dropped_updates)
            .set("mean_soft_fraction", self.mean_soft_fraction)
    }
}

/// Rank one scenario's cells, best first: reached-target cells by
/// time-to-accuracy, then unreached cells by final accuracy; exact ties
/// break on the policy name so the order is total and deterministic.
pub fn rank(cells: &[CellMetrics]) -> Vec<&'static str> {
    let mut order: Vec<&CellMetrics> = cells.iter().collect();
    order.sort_by(|a, b| {
        let ka = if a.time_to_target < 0.0 { f64::INFINITY } else { a.time_to_target };
        let kb = if b.time_to_target < 0.0 { f64::INFINITY } else { b.time_to_target };
        ka.total_cmp(&kb)
            .then(b.final_test_acc.total_cmp(&a.final_test_acc))
            .then(a.policy.cmp(b.policy))
    });
    order.into_iter().map(|c| c.policy).collect()
}

/// Execute the whole grid through the simulation backend and emit the
/// leaderboard JSON. Cells run sequentially under identical seeds; a
/// failing cell fails the matrix (partial leaderboards would silently
/// bias comparisons).
pub fn run_matrix(mc: &MatrixConfig) -> crate::Result<Json> {
    anyhow::ensure!(!mc.policies.is_empty(), "matrix needs at least one policy");
    anyhow::ensure!(!mc.scenarios.is_empty(), "matrix needs at least one scenario");
    let mut cells: Vec<CellMetrics> = Vec::new();
    let mut board: Vec<Json> = Vec::new();
    for scenario in &mc.scenarios {
        let mut row: Vec<CellMetrics> = Vec::new();
        for policy in &mc.policies {
            let cfg = cell_config(&mc.base, policy, scenario)?;
            let res = run_sim(&cfg).map_err(|e| {
                anyhow::anyhow!("matrix cell ({policy}, {scenario}) failed: {e:#}")
            })?;
            row.push(cell_metrics(&res, scenario, mc.target_acc));
        }
        board.push(
            Json::obj()
                .set("scenario", scenario.as_str())
                .set(
                    "ranking",
                    Json::Arr(rank(&row).into_iter().map(Json::from).collect()),
                ),
        );
        cells.extend(row);
    }
    Ok(Json::obj()
        .set("schema", LEADERBOARD_SCHEMA)
        .set("model", mc.base.model.as_str())
        .set("seed", mc.base.seed as i64)
        .set("rounds", mc.base.rounds)
        .set(
            "fleet_size",
            mc.base.fleet_size.map(|v| v as i64).unwrap_or(0),
        )
        .set("sample_k", mc.base.sample_k)
        .set("target_acc", mc.target_acc)
        .set(
            "policies",
            Json::Arr(mc.policies.iter().map(|p| Json::from(p.as_str())).collect()),
        )
        .set(
            "scenarios",
            Json::Arr(mc.scenarios.iter().map(|s| Json::from(s.as_str())).collect()),
        )
        .set("cells", Json::Arr(cells.iter().map(CellMetrics::to_json).collect()))
        .set("leaderboard", Json::Arr(board)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::PolicyKind;

    fn base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::fleet("femnist_cnn", PolicyKind::None, 256, 16);
        cfg.rounds = 4;
        cfg.eval_every = 2;
        cfg
    }

    #[test]
    fn cell_config_applies_zoo_coherence() {
        let b = base();
        let safa = cell_config(&b, "safa", "storm").unwrap();
        assert_eq!(safa.mitigation, Mitigation::Safa);
        assert_eq!(safa.policy, PolicyKind::None);
        assert!(matches!(safa.sync_mode, SyncMode::Buffered { k: 12 }));

        let prox = cell_config(&b, "fedprox", "drift").unwrap();
        assert_eq!(prox.mitigation_trade_off, 0.5, "λ=1 cell would alias none");
        let inv = cell_config(&b, "invariant", "none").unwrap();
        assert_eq!(inv.mitigation, Mitigation::Fluid);
        assert_eq!(inv.policy, PolicyKind::Invariant);
        assert_eq!(inv.mitigation_trade_off, 1.0);

        assert!(cell_config(&b, "bogus", "storm").is_err());
        assert!(cell_config(&b, "safa", "not-a-scenario").is_err());
    }

    #[test]
    fn ranking_is_total_and_prefers_reached_targets() {
        let mk = |policy: &'static str, ttt: f64, acc: f64| CellMetrics {
            policy,
            scenario: "storm".into(),
            final_test_acc: acc,
            time_to_target: ttt,
            rounds_to_target: if ttt < 0.0 { -1 } else { 3 },
            mean_straggler_wait: 0.0,
            mean_round_time: 1.0,
            total_update_bytes: 0,
            admitted_stale: 0,
            dropped_updates: 0,
            mean_soft_fraction: 1.0,
        };
        let cells = vec![
            mk("none", -1.0, 0.40),
            mk("invariant", 12.0, 0.55),
            mk("safa", 15.0, 0.60),
            mk("helios", -1.0, 0.45),
        ];
        assert_eq!(rank(&cells), vec!["invariant", "safa", "helios", "none"]);
    }

    #[test]
    fn matrix_runs_the_grid_and_is_replay_stable() {
        let mc = MatrixConfig {
            base: base(),
            policies: vec!["none".into(), "invariant".into(), "fedprox".into()],
            scenarios: vec!["storm".into()],
            target_acc: 0.99, // unreachable in 4 pseudo-training rounds
        };
        let a = run_matrix(&mc).unwrap().to_string_pretty();
        let mut mc2 = mc.clone();
        mc2.base.threads = mc.base.threads.saturating_add(1).max(2);
        let b = run_matrix(&mc2).unwrap().to_string_pretty();
        assert_eq!(a, b, "leaderboard must be byte-identical across threads");

        let parsed = crate::jsonlite::parse(&a).unwrap();
        assert_eq!(
            parsed.req("schema").unwrap().as_str(),
            Some(LEADERBOARD_SCHEMA)
        );
        let cells = parsed.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 3);
        for c in cells {
            assert!(c.req("mean_round_time").unwrap().as_f64().unwrap() > 0.0);
            assert_eq!(c.req("rounds_to_target").unwrap().as_f64(), Some(-1.0));
        }
        let board = parsed.req("leaderboard").unwrap().as_arr().unwrap();
        assert_eq!(board.len(), 1);
        assert_eq!(
            board[0].req("ranking").unwrap().as_arr().unwrap().len(),
            3
        );
    }
}
