//! The FLuID round loop (Algorithm 1) — thin wrapper over the engine.
//!
//! Per calibration step: profile client latencies → determine stragglers
//! and `T_target` (next-slowest) → size each straggler's sub-model
//! (`r ≈ 1/speedup`, snapped to the menu) → extract sub-models by
//! invariant-neuron masking → broadcast → local training → masked FedAvg
//! → observe non-straggler deltas (the L1 `neuron_delta` kernel) to
//! refresh the invariant sets and thresholds.
//!
//! The mechanics live in [`crate::engine`]: this function only opens the
//! model's step runner and hands the config to a [`RoundEngine`] backed
//! by the in-process [`LocalExecutor`]. Round synchronization follows
//! [`ExperimentConfig::sync_mode`] — the default `FullBarrier` reproduces
//! the historical monolithic loop bit-for-bit (pinned by
//! `tests/engine_regression.rs`).

use super::{ExperimentConfig, ExperimentResult};
use crate::engine::{LocalExecutor, RoundEngine};
use crate::runtime::Session;
use anyhow::Context;

/// Run one experiment to completion.
pub fn run(sess: &Session, cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let runner = sess
        .runner(&cfg.model)
        .with_context(|| format!("loading artifacts for {}", cfg.model))?;
    let engine = RoundEngine::new(&runner, cfg, LocalExecutor::new(cfg.threads))?;
    engine.run()
}
