//! The FLuID round loop (Algorithm 1).
//!
//! Per calibration step: profile client latencies → determine stragglers
//! and `T_target` (next-slowest) → size each straggler's sub-model
//! (`r ≈ 1/speedup`, snapped to the menu) → extract sub-models by
//! invariant-neuron masking → broadcast → local training → masked FedAvg
//! → observe non-straggler deltas (the L1 `neuron_delta` kernel) to
//! refresh the invariant sets and thresholds.

use super::{ExperimentConfig, ExperimentResult, RoundRecord};
use crate::data::FlData;
use crate::dropout::{MaskSet, Policy, PolicyKind};
use crate::fl::{self, fedavg, Client, ClientUpdate};
use crate::runtime::Session;
use crate::straggler::{
    detect_stragglers, mobile_fleet, snap_rate, synthetic_fleet, Detection,
    FluctuationSchedule, PerfModel,
};
use crate::util::pool::scope_map;
use crate::util::prng::Pcg32;
use anyhow::Context;
use std::time::Instant;

/// Cap on how many non-stragglers vote on invariance per calibration —
/// the information saturates quickly and each voter costs one
/// `delta_step` execution (documented server-side optimization).
const MAX_DELTA_VOTERS: usize = 16;

/// Run one experiment to completion.
pub fn run(sess: &Session, cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let runner = sess
        .runner(&cfg.model)
        .with_context(|| format!("loading artifacts for {}", cfg.model))?;
    let spec = runner.spec.clone();

    // fleet + data + clients -------------------------------------------------
    let fleet = if cfg.mobile_fleet {
        let base = mobile_fleet();
        (0..cfg.clients).map(|i| base[i % base.len()].clone()).collect::<Vec<_>>()
    } else {
        synthetic_fleet(cfg.clients, cfg.seed ^ 0xF1EE7)
    };
    let data = FlData::for_model(&cfg.model, cfg.clients, cfg.samples_per_client, cfg.seed);
    let clients: Vec<Client> = data
        .clients
        .iter()
        .enumerate()
        .map(|(i, split)| Client::new(i, i % fleet.len(), split.clone()))
        .collect();

    let perf = PerfModel::new(&cfg.model, spec.size_bytes());
    // the natural straggler is the slowest base device — excluded from the
    // fluctuation protocol so that the straggler identity really changes
    let natural_straggler = (0..cfg.clients)
        .max_by(|&a, &b| {
            fleet[a % fleet.len()]
                .base_time(&cfg.model)
                .partial_cmp(&fleet[b % fleet.len()].base_time(&cfg.model))
                .unwrap()
        })
        .unwrap_or(0);
    let sched = if cfg.fluctuation {
        FluctuationSchedule::paper_marks(cfg.clients, natural_straggler, cfg.seed ^ 0xF1C)
    } else {
        FluctuationSchedule::none()
    };

    let inv_cfg = crate::dropout::InvariantConfig {
        th_override: cfg.invariant_th_override,
        ..Default::default()
    };
    let mut policy = Policy::new_with(cfg.policy, &spec, cfg.seed ^ 0xD20, inv_cfg);
    let mut params = spec.init_params(cfg.seed);
    let full_mask = MaskSet::full(&spec);

    let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
    let mut vtime = 0.0f64;
    let mut calib_total = 0.0f64;
    let mut train_wall = 0.0f64;
    let mut detection: Option<Detection> = None;
    // measured end-to-end latency of the last round (actual, with masks)
    let mut last_latencies: Vec<f64> = vec![0.0; cfg.clients];
    // the same latencies normalized to r = 1.0 — what the client *would*
    // take on the full model. Detection must use these, otherwise a
    // straggler that got a sub-model looks fast next round and flaps in
    // and out of the straggler set.
    let mut last_full_latencies: Vec<f64> = vec![0.0; cfg.clients];

    for round in 0..cfg.rounds {
        let t_frac = round as f64 / cfg.rounds.max(1) as f64;
        let mut rng = Pcg32::new(cfg.seed ^ 0xA0_0000, round as u64);

        // --- client sampling (A.6) ------------------------------------------
        let selected: Vec<usize> = if cfg.sample_fraction >= 1.0 {
            (0..cfg.clients).collect()
        } else {
            let k = ((cfg.clients as f64 * cfg.sample_fraction).ceil() as usize)
                .clamp(1, cfg.clients);
            let mut s = rng.sample_indices(cfg.clients, k);
            s.sort_unstable();
            s
        };

        // --- straggler recalibration (Algorithm 1 lines 18-22) --------------
        let recalibrate = round > 0
            && round % cfg.recalibrate_every == 0
            && !(cfg.static_stragglers && detection.is_some());
        if recalibrate {
            let lat: Vec<f64> = selected.iter().map(|&c| last_full_latencies[c]).collect();
            let det = detect_stragglers(
                &lat,
                cfg.straggler_fraction,
                0.02,
                &cfg.rates_menu,
            );
            // map sample-local ids back to client ids
            detection = Some(Detection {
                stragglers: det.stragglers.iter().map(|&i| selected[i]).collect(),
                ..det
            });
        }

        // --- sub-model assignment --------------------------------------------
        let calib_start = Instant::now();
        let mut masks: Vec<MaskSet> = vec![full_mask.clone(); cfg.clients];
        let mut rates: Vec<f64> = vec![1.0; cfg.clients];
        let mut straggler_ids: Vec<usize> = Vec::new();
        if let Some(det) = &detection {
            for (k, &c) in det.stragglers.iter().enumerate() {
                let desired = cfg.fixed_rate.unwrap_or(det.rates[k]);
                let r = match &cfg.cluster_rates {
                    Some(menu) => snap_rate(desired, menu),
                    None => desired,
                };
                if cfg.policy != PolicyKind::None && cfg.policy != PolicyKind::Exclude {
                    let m = policy.make_mask(&spec, r);
                    // the straggler only speeds up if it actually received
                    // a sub-model (invariant dropout returns the full mask
                    // until its first calibration observation)
                    if !m.is_full() {
                        rates[c] = r;
                        masks[c] = m;
                    }
                }
                straggler_ids.push(c);
            }
        }
        let mut calib_secs = calib_start.elapsed().as_secs_f64();

        // --- local training (parallel over clients) --------------------------
        // Exclude policy: stragglers neither train nor aggregate.
        let participants: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|c| cfg.policy != PolicyKind::Exclude || !straggler_ids.contains(c))
            .collect();
        let round_seed = cfg.seed ^ ((round as u64) << 32);
        let t0 = Instant::now();
        let results: Vec<crate::Result<fl::LocalResult>> =
            scope_map(&participants, cfg.threads, |_, &c| {
                clients[c].local_train(
                    &runner,
                    &params,
                    masks[c].tensors(),
                    cfg.local_steps,
                    cfg.lr,
                    round_seed,
                    cfg.use_fused_steps,
                )
            });
        train_wall += t0.elapsed().as_secs_f64();
        let mut updates: Vec<(usize, fl::LocalResult)> = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            updates.push((participants[i], r?));
        }

        // --- virtual latency of every selected client -------------------------
        for &c in &selected {
            let dev = &fleet[clients[c].device];
            let mut lrng = Pcg32::new(round_seed ^ 0x7A7, c as u64);
            let mut lrng_full = lrng.clone(); // same jitter draw for both
            last_latencies[c] = perf.round_latency(
                dev,
                c,
                rates[c],
                masks[c].comm_fraction(),
                t_frac,
                &sched,
                &mut lrng,
            );
            last_full_latencies[c] =
                perf.round_latency(dev, c, 1.0, 1.0, t_frac, &sched, &mut lrng_full);
        }
        // Exclude baseline does not wait for stragglers: the round
        // advances as soon as the participants finish.
        let timed: &[usize] = if cfg.policy == PolicyKind::Exclude {
            &participants
        } else {
            &selected
        };
        let round_time = timed
            .iter()
            .map(|&c| last_latencies[c])
            .fold(0.0f64, f64::max);
        vtime += round_time;

        let straggler_time = straggler_ids
            .iter()
            .map(|&c| last_latencies[c])
            .fold(0.0f64, f64::max);
        let t_target = detection.as_ref().map(|d| d.t_target).unwrap_or(round_time);

        // --- aggregation -------------------------------------------------------
        let mean_loss = crate::util::stats::mean(
            &updates.iter().map(|(_, u)| u.mean_loss).collect::<Vec<_>>(),
        );
        let mean_acc = crate::util::stats::mean(
            &updates.iter().map(|(_, u)| u.mean_acc).collect::<Vec<_>>(),
        );
        let client_updates: Vec<ClientUpdate> = updates
            .iter()
            .map(|(c, u)| ClientUpdate {
                params: u.params.clone(),
                weight: u.weight,
                mask: masks[*c].clone(),
            })
            .collect();
        let new_params = fedavg(&spec, &params, &client_updates, cfg.aggregate);

        // --- invariant observation (non-straggler deltas, L1 kernel) ----------
        let is_calib_round = round % cfg.recalibrate_every == 0;
        if is_calib_round && matches!(policy, Policy::Invariant(_)) {
            let t0 = Instant::now();
            let voters: Vec<&(usize, fl::LocalResult)> = updates
                .iter()
                .filter(|(c, _)| !straggler_ids.contains(c))
                .take(MAX_DELTA_VOTERS)
                .collect();
            // §Perf L3: voters execute the delta kernel concurrently —
            // calibration cost drops from #voters x delta_latency to
            // roughly one delta_latency (paper claims < 5% overhead)
            let per_client: Vec<crate::Result<Vec<crate::tensor::Tensor>>> =
                scope_map(&voters, cfg.threads, |_, (_, u)| {
                    runner.delta_step(&params, &u.params)
                });
            let per_client = per_client
                .into_iter()
                .collect::<crate::Result<Vec<_>>>()?;
            policy.observe_deltas(&per_client);
            calib_secs += t0.elapsed().as_secs_f64();
        }
        params = new_params;
        calib_total += calib_secs;

        // --- evaluation ---------------------------------------------------------
        let (test_loss, test_acc) = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds
        {
            fl::evaluate_split(&runner, &params, full_mask.tensors(), &data.test)?
        } else {
            (f64::NAN, f64::NAN)
        };

        let invariant_fraction = match &policy {
            Policy::Invariant(p) => p.invariant_fraction(),
            _ => 0.0,
        };

        records.push(RoundRecord {
            round,
            round_time,
            vtime,
            straggler_ids: straggler_ids.clone(),
            straggler_rates: straggler_ids.iter().map(|&c| rates[c]).collect(),
            t_target,
            straggler_time,
            train_loss: mean_loss,
            train_acc: mean_acc,
            test_loss,
            test_acc,
            invariant_fraction,
            calibration_secs: calib_secs,
        });
    }

    let last_eval = records
        .iter()
        .rev()
        .find(|r| !r.test_acc.is_nan())
        .map(|r| (r.test_loss, r.test_acc))
        .unwrap_or((f64::NAN, f64::NAN));

    Ok(ExperimentResult {
        model: cfg.model.clone(),
        policy: cfg.policy,
        records,
        final_test_acc: last_eval.1,
        final_test_loss: last_eval.0,
        total_vtime: vtime,
        calibration_total: calib_total,
        seed: cfg.seed,
        train_wall_total: train_wall,
    })
}
