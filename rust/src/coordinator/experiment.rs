//! The FLuID round loop (Algorithm 1) — thin wrapper over the engine.
//!
//! Per calibration step: profile client latencies → determine stragglers
//! and `T_target` (next-slowest) → size each straggler's sub-model
//! (`r ≈ 1/speedup`, snapped to the menu) → extract sub-models by
//! invariant-neuron masking → broadcast → local training → masked FedAvg
//! → observe non-straggler deltas (the L1 `neuron_delta` kernel) to
//! refresh the invariant sets and thresholds.
//!
//! The mechanics live in [`crate::engine`]: these functions only pick an
//! executor backend and hand the config to a [`RoundEngine`]. The
//! executor is built with `cfg.threads`, and the engine mirrors that
//! budget through the [`crate::engine::ClientExecutor::threads`] seam
//! for its own server-side hot path (parallel masked FedAvg + the fused
//! invariant-observation sweep, DESIGN.md §7) — one `--threads` knob,
//! bit-identical results at every value.
//!
//! * [`run`] — PJRT-backed execution over real artifacts
//!   ([`LocalExecutor`]). Round synchronization follows
//!   [`ExperimentConfig::sync_mode`] — the default `FullBarrier`
//!   reproduces the historical monolithic loop bit-for-bit (pinned by
//!   `tests/engine_regression.rs`).
//! * [`run_sim`] — runtime-free deterministic simulation
//!   ([`crate::engine::SimExecutor`]): no artifacts, no `xla` feature.
//!   Timing, sampling, churn and aggregation flow through the identical
//!   engine paths; local training is pseudo. This is the backend for
//!   fleet-scale scenario studies and the determinism suite.

use super::{ExperimentConfig, ExperimentResult};
use crate::engine::{
    ChaosPlan, ClientExecutor, LocalExecutor, RoundEngine, ShardedExecutor, SimExecutor,
};
use crate::model::sim_spec;
use crate::runtime::Session;
use anyhow::Context;

/// Does this config route through the sharded multi-aggregator tree?
/// `--shards 1` without shard-fault knobs stays on the plain executor —
/// not for correctness (a 1-shard tree is bit-identical, pinned by the
/// determinism suite) but to keep the default path wire-free. A chaos
/// script with shard events forces the tree even at `--shards 1`, so
/// the faults have a worker to land on.
fn sharded(cfg: &ExperimentConfig) -> bool {
    cfg.shards > 1
        || cfg.shard_crash_after.is_some()
        || cfg.chaos.as_ref().is_some_and(|c| c.has_shard_faults())
}

/// The slice re-dispatch budget this config grants the tree:
/// `--shard-retry-max` wins; the legacy single-shot `--shard-retry`
/// switch maps to a budget of 1.
fn retry_budget(cfg: &ExperimentConfig) -> usize {
    if cfg.shard_retry_max > 0 {
        cfg.shard_retry_max
    } else {
        usize::from(cfg.shard_retry)
    }
}

fn run_engine<E: ClientExecutor>(
    cfg: &ExperimentConfig,
    executor: E,
) -> crate::Result<ExperimentResult> {
    if sharded(cfg) {
        // compressed configs ship mask-packed slices over the shard wire
        // too (sparse packing for q8 as well — quantization stays at the
        // root, see `engine::sharded`)
        let tree = ShardedExecutor::with_fault(
            executor,
            cfg.shards,
            cfg.shard_crash_after,
            cfg.shard_retry,
        )
        .with_compression(cfg.compress)
        .with_retry_budget(retry_budget(cfg))
        .with_chaos(
            cfg.chaos
                .as_ref()
                .filter(|c| c.has_shard_faults())
                .map(|c| ChaosPlan::new(c.clone(), cfg.seed)),
        );
        RoundEngine::new(cfg, tree)?.run()
    } else {
        RoundEngine::new(cfg, executor)?.run()
    }
}

/// Run one experiment to completion against real artifacts.
pub fn run(sess: &Session, cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    let runner = sess
        .runner(&cfg.model)
        .with_context(|| format!("loading artifacts for {}", cfg.model))?;
    run_engine(cfg, LocalExecutor::new(&runner, cfg.threads))
}

/// Run one experiment through the runtime-free simulation backend.
pub fn run_sim(cfg: &ExperimentConfig) -> crate::Result<ExperimentResult> {
    run_engine(cfg, SimExecutor::new(sim_spec(&cfg.model), cfg.threads))
}
