//! The FLuID coordinator — Algorithm 1 as a rust service.
//!
//! [`ExperimentConfig`] describes one federated run (model, dropout
//! policy, fleet, straggler handling); [`experiment::run`] executes it
//! against the AOT artifacts and returns an [`ExperimentResult`] with the
//! per-round history the benches turn into the paper's tables/figures.

pub mod experiment;
pub mod matrix;
pub mod report;

pub use experiment::{run, run_sim};
pub use matrix::{run_matrix, MatrixConfig};

use crate::dropout::PolicyKind;
use crate::engine::{ChaosConfig, ScenarioConfig, SyncMode};
use crate::fl::{AggregateMode, Compression, SamplerKind};
use crate::jsonlite::Json;
use crate::policy::Mitigation;
use crate::straggler::{AdaptConfig, AdaptMode};
use std::path::PathBuf;

/// Everything that defines one run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// manifest model name
    pub model: String,
    pub policy: PolicyKind,
    pub rounds: usize,
    pub clients: usize,
    pub samples_per_client: usize,
    /// local SGD steps per round per client
    pub local_steps: usize,
    pub lr: f32,
    /// how much of the fleet may be stragglers (1/5 on mobile, 0.2 at scale)
    pub straggler_fraction: f64,
    /// force every straggler to this keep-rate (Table 2 protocol);
    /// None = FLuID picks per-straggler rates from latency profiling
    pub fixed_rate: Option<f64>,
    /// sub-model size menu (paper §7: pre-defined sizes)
    pub rates_menu: Vec<f64>,
    /// A.4 clustering: when Some, straggler rates snap to these clusters
    pub cluster_rates: Option<Vec<f64>>,
    /// recalibrate stragglers + thresholds every this many rounds
    pub recalibrate_every: usize,
    /// enable the §6.1 runtime-fluctuation protocol (Fig 4b)
    pub fluctuation: bool,
    /// keep the straggler set fixed after the first detection
    /// (the "static straggler" baseline of Fig 4b)
    pub static_stragglers: bool,
    /// sub-model sizing law: `paper` = the §7 one-shot menu snap
    /// (bit-identical to the historic loop), `ewma` = the closed-loop
    /// `straggler::RateController` (continuous rates, feedback on the
    /// measured miss, hysteresis, straggler promotion/demotion)
    pub adapt: AdaptMode,
    /// ewma controller: proportional gain of the rate step
    pub adapt_gain: f64,
    /// ewma controller: hysteresis half-width around the latency
    /// setpoint `(1 - deadband) · T_target`
    pub adapt_deadband: f64,
    /// ewma controller: floor on adaptive keep-rates (paper mode is
    /// floored by its menu instead)
    pub rate_min: f64,
    /// client sampling fraction per round (A.6; 1.0 = all clients)
    pub sample_fraction: f64,
    /// evaluate on the test split every this many rounds
    pub eval_every: usize,
    pub aggregate: AggregateMode,
    /// run local steps through the fused k-step artifact when possible
    /// (§Perf: LSTM-only win on CPU-XLA — see EXPERIMENTS.md)
    pub use_fused_steps: bool,
    /// freeze the invariant drop-threshold at this value (Table 3 sweep)
    pub invariant_th_override: Option<f32>,
    /// use the 5-phone Table-1 fleet (else a synthetic fleet of `clients`)
    pub mobile_fleet: bool,
    /// round-synchronization policy (full barrier / deadline / buffered
    /// semi-async — see [`SyncMode`])
    pub sync_mode: SyncMode,
    /// fleet-scale mode: simulate this many clients as lightweight
    /// descriptors with per-round cohort sampling and lazy shard
    /// hydration (None = classic path, every client materialized)
    pub fleet_size: Option<usize>,
    /// sampled cohort size per round (fleet mode; clamped to [1, fleet])
    pub sample_k: usize,
    /// per-round client-sampling policy (fleet mode)
    pub sampler: SamplerKind,
    /// scripted fleet dynamics: churn, straggler drift, speed
    /// fluctuation (see `engine::scenario`; takes precedence over the
    /// paper's `fluctuation` protocol when set)
    pub scenario: Option<ScenarioConfig>,
    pub seed: u64,
    /// worker threads for parallel client execution
    pub threads: usize,
    /// write a resumable snapshot every N round boundaries (0 = off);
    /// requires [`ExperimentConfig::checkpoint_dir`]
    pub checkpoint_every: usize,
    /// where snapshot files live (see [`crate::snapshot::SnapshotStore`])
    pub checkpoint_dir: Option<PathBuf>,
    /// keep only the newest N snapshots (rotation)
    pub checkpoint_keep: usize,
    /// resume from this snapshot file, or the newest snapshot when the
    /// path is a directory; the snapshot's config fingerprint must match
    pub resume_from: Option<PathBuf>,
    /// fault injection for the kill/resume soak: `Some(r)` aborts the
    /// run with an `engine::FaultInjected` error once `r` rounds have
    /// completed, after any due checkpoint was written; the `fluid`
    /// binary translates it to exit code 137 (as if SIGKILLed)
    pub crash_after: Option<usize>,
    /// aggregator shards: split each round's cohort across this many
    /// shard workers behind `engine::ShardedExecutor` (1 = the plain
    /// single-engine path). Purely topological — results are
    /// bit-identical at every value, and snapshots carry no shard state
    /// (a run checkpointed under N shards resumes under M).
    pub shards: usize,
    /// shard-level fault injection: kill shard `.0` the first time it
    /// starts round ≥ `.1`. Without [`ExperimentConfig::shard_retry`]
    /// the run aborts with an `engine::ShardFault` error (exit 137 in
    /// the binary); with it, the root re-dispatches the dead slice.
    pub shard_crash_after: Option<(usize, usize)>,
    /// re-dispatch a killed shard's slice at the root instead of
    /// failing the round
    pub shard_retry: bool,
    /// update-codec mode (`--compress`): `Dense` is the bit-exact
    /// reference every pinned trajectory runs under; `Sparse` packs only
    /// the mask's kept columns; `Q8` adds int8 quantization with
    /// error-feedback residuals (DESIGN.md §12). Semantic: part of the
    /// snapshot fingerprint
    pub compress: Compression,
    /// seeded chaos script (`--chaos`): per-client vanish/hang/corrupt/
    /// nan-poison faults plus shard crash/stall events, replayed
    /// bit-identically across threads and shards (DESIGN.md §13).
    /// Semantic: part of the snapshot fingerprint
    pub chaos: Option<ChaosConfig>,
    /// minimum fraction of a round's participants that must deliver a
    /// fresh, valid, on-time update (`--quorum`); below it the round
    /// aborts with a typed `engine::QuorumFailed` (exit 137 in the
    /// binary). 0 disables the check. An abort floor, not trajectory
    /// state — excluded from the snapshot fingerprint so a failed run
    /// can resume from its last checkpoint under a relaxed floor
    pub quorum: f64,
    /// bounded shard-slice retry budget (`--shard-retry-max`): how many
    /// times the root may re-dispatch a faulted shard's slice per round
    /// before surfacing `engine::ShardFault`. 0 defers to the legacy
    /// single-shot [`ExperimentConfig::shard_retry`] switch. Recovery
    /// topology only — not part of the snapshot fingerprint
    pub shard_retry_max: usize,
    /// which straggler-mitigation family runs the round (`--policy`):
    /// `Fluid` hosts the five dropout policies above; `FedProx`, `Safa`
    /// and `Helios` are the zoo alternatives behind the
    /// `policy::MitigationPolicy` seam. Semantic: part of the snapshot
    /// fingerprint
    pub mitigation: Mitigation,
    /// FedProx elastic-aggregation knob (`--trade-off`): the aggregated
    /// proposal is blended as `new = α·proposal + (1-α)·old`. 1.0 (the
    /// default, and the only legal value outside `--policy fedprox`)
    /// is plain FedAvg, bit-identically
    pub mitigation_trade_off: f64,
    /// SAFA staleness-admission bound (`--safa-lag`): a buffered update
    /// is folded only while its version lag is within this many rounds
    pub safa_lag: usize,
}

impl ExperimentConfig {
    /// The paper's 5-phone / 1-straggler mobile setup.
    pub fn mobile(model: &str, policy: PolicyKind) -> Self {
        Self {
            model: model.to_string(),
            policy,
            rounds: 30,
            clients: 5,
            samples_per_client: 60,
            local_steps: 4,
            lr: default_lr(model),
            straggler_fraction: 0.2,
            fixed_rate: None,
            rates_menu: crate::straggler::detect::DEFAULT_RATES.to_vec(),
            cluster_rates: None,
            recalibrate_every: 1,
            fluctuation: false,
            static_stragglers: false,
            adapt: AdaptMode::Paper,
            adapt_gain: 0.5,
            adapt_deadband: 0.05,
            rate_min: 0.1,
            sample_fraction: 1.0,
            eval_every: 5,
            aggregate: AggregateMode::OwnershipWeighted,
            use_fused_steps: model == "shakespeare_lstm",
            invariant_th_override: None,
            mobile_fleet: true,
            sync_mode: SyncMode::FullBarrier,
            fleet_size: None,
            sample_k: 0,
            sampler: SamplerKind::Uniform,
            scenario: None,
            seed: 42,
            threads: crate::util::pool::default_threads(),
            checkpoint_every: 0,
            checkpoint_dir: None,
            checkpoint_keep: 3,
            resume_from: None,
            crash_after: None,
            shards: 1,
            shard_crash_after: None,
            shard_retry: false,
            compress: Compression::Dense,
            chaos: None,
            quorum: 0.0,
            shard_retry_max: 0,
            mitigation: Mitigation::Fluid,
            mitigation_trade_off: 1.0,
            safa_lag: 2,
        }
    }

    /// Scale-study setup (50-100+ synthetic clients, 20% stragglers).
    pub fn scale(model: &str, policy: PolicyKind, clients: usize) -> Self {
        Self {
            clients,
            mobile_fleet: false,
            samples_per_client: 30,
            ..Self::mobile(model, policy)
        }
    }

    /// The controller parameters as `straggler::adapt` consumes them.
    pub fn adapt_config(&self) -> AdaptConfig {
        AdaptConfig {
            mode: self.adapt,
            gain: self.adapt_gain,
            deadband: self.adapt_deadband,
            rate_min: self.rate_min,
            ..AdaptConfig::default()
        }
    }

    /// Validate the knobs no run can recover from at runtime. An empty
    /// or out-of-range `rates_menu`/`cluster_rates` used to slip through
    /// `snap_rate`, which silently yields 1.0 for any menu it cannot
    /// snap into — stragglers then never received a sub-model at all.
    /// Surfaced as a clean config error here (the engine calls this
    /// before building any state; the CLI calls it at parse time).
    pub fn validate(&self) -> crate::Result<()> {
        let check_menu = |name: &str, menu: &[f64]| -> crate::Result<()> {
            anyhow::ensure!(
                !menu.is_empty(),
                "{name} is empty: stragglers could never receive a sub-model"
            );
            for &r in menu {
                anyhow::ensure!(
                    r.is_finite() && r > 0.0 && r <= 1.0,
                    "{name} entry {r} is outside (0, 1]"
                );
            }
            Ok(())
        };
        check_menu("rates_menu", &self.rates_menu)?;
        if let Some(menu) = &self.cluster_rates {
            check_menu("cluster_rates", menu)?;
        }
        if let Some(r) = self.fixed_rate {
            anyhow::ensure!(
                r.is_finite() && r > 0.0 && r <= 1.0,
                "fixed_rate {r} is outside (0, 1]"
            );
        }
        anyhow::ensure!(
            self.adapt_gain.is_finite() && self.adapt_gain > 0.0 && self.adapt_gain <= 2.0,
            "adapt_gain {} is outside (0, 2]",
            self.adapt_gain
        );
        anyhow::ensure!(
            self.adapt_deadband.is_finite() && (0.0..0.5).contains(&self.adapt_deadband),
            "adapt_deadband {} is outside [0, 0.5)",
            self.adapt_deadband
        );
        anyhow::ensure!(
            self.rate_min.is_finite() && self.rate_min > 0.0 && self.rate_min <= 1.0,
            "rate_min {} is outside (0, 1]",
            self.rate_min
        );
        if self.adapt == AdaptMode::Ewma {
            // both knobs rewrite the rate *after* the controller assigns
            // it, so its feedback would step on evidence measured under
            // a rate it never chose — reject the combination up front
            anyhow::ensure!(
                self.fixed_rate.is_none(),
                "--adapt ewma is incompatible with a fixed straggler rate \
                 (the controller owns sub-model sizes; fixed_rate is the \
                 Table-2 static protocol)"
            );
            anyhow::ensure!(
                self.cluster_rates.is_none(),
                "--adapt ewma is incompatible with cluster_rates \
                 (adaptive rates are continuous, not menu-snapped)"
            );
            anyhow::ensure!(
                !self.static_stragglers,
                "--adapt ewma is incompatible with --static-stragglers \
                 (freezing the straggler set after the first detection \
                 disables the feedback loop entirely)"
            );
        }
        anyhow::ensure!(self.shards >= 1, "shards must be at least 1");
        if let Some((shard, _)) = self.shard_crash_after {
            anyhow::ensure!(
                shard < self.shards,
                "shard_crash_after names shard {shard}, but only {} shard(s) exist",
                self.shards
            );
        }
        anyhow::ensure!(
            self.quorum.is_finite() && (0.0..=1.0).contains(&self.quorum),
            "quorum {} is outside [0, 1]",
            self.quorum
        );
        if let Some(chaos) = &self.chaos {
            chaos
                .validate()
                .map_err(|e| anyhow::anyhow!("chaos config: {e}"))?;
        }
        anyhow::ensure!(
            self.mitigation_trade_off.is_finite()
                && self.mitigation_trade_off > 0.0
                && self.mitigation_trade_off <= 1.0,
            "mitigation_trade_off {} is outside (0, 1]",
            self.mitigation_trade_off
        );
        anyhow::ensure!(self.safa_lag >= 1, "safa_lag must be at least 1");
        if self.mitigation != Mitigation::Fluid {
            // the zoo policies answer "what to do about stragglers"
            // themselves — a dropout policy or the ewma rate loop
            // underneath them would fight over the same assignment
            anyhow::ensure!(
                self.policy == PolicyKind::None,
                "--policy {} does not compose with the {} dropout policy \
                 (the zoo mitigations own straggler handling)",
                self.mitigation.name(),
                self.policy.name()
            );
            anyhow::ensure!(
                self.adapt == AdaptMode::Paper,
                "--policy {} is incompatible with --adapt ewma \
                 (zoo mitigations reuse the paper's one-shot detection)",
                self.mitigation.name()
            );
        }
        if self.mitigation != Mitigation::FedProx {
            anyhow::ensure!(
                self.mitigation_trade_off == 1.0,
                "--trade-off only applies to --policy fedprox"
            );
        }
        if self.mitigation == Mitigation::Safa {
            anyhow::ensure!(
                matches!(self.sync_mode, SyncMode::Buffered { .. }),
                "--policy safa requires buffered semi-async sync \
                 (--sync buffered:K): lag-tolerant admission only exists \
                 where late updates are buffered, not dropped"
            );
        }
        Ok(())
    }

    /// Fleet-scale preset: a population of `fleet_size` descriptor-only
    /// clients, `sample_k` of them sampled per round, shards hydrated
    /// lazily. Pair with [`ExperimentConfig::scenario`] for scripted
    /// churn / drift and `coordinator::run_sim` for runtime-free runs.
    pub fn fleet(
        model: &str,
        policy: PolicyKind,
        fleet_size: usize,
        sample_k: usize,
    ) -> Self {
        Self {
            fleet_size: Some(fleet_size),
            sample_k: sample_k.max(1),
            sampler: SamplerKind::Uniform,
            mobile_fleet: false,
            samples_per_client: 16,
            recalibrate_every: 1,
            ..Self::mobile(model, policy)
        }
    }
}

/// Paper learning rates (§6): FEMNIST 0.004, CIFAR 0.01, Shakespeare 0.001.
/// (We use CIFAR's 0.01 for the ResNet variant as well.)
pub fn default_lr(model: &str) -> f32 {
    match model {
        "femnist_cnn" => 0.004,
        "cifar_vgg9" | "cifar_resnet18" => 0.01,
        "shakespeare_lstm" => 0.001,
        _ => 0.01,
    }
}

/// Per-round record for the history (one row of every figure).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// virtual seconds consumed by this round (max client latency)
    pub round_time: f64,
    /// cumulative virtual time
    pub vtime: f64,
    /// clients sampled into this round's cohort (id order)
    pub cohort: Vec<usize>,
    pub straggler_ids: Vec<usize>,
    pub straggler_rates: Vec<f64>,
    /// slowest non-straggler latency (the FLuID target)
    pub t_target: f64,
    /// actual slowest-straggler latency this round
    pub straggler_time: f64,
    pub train_loss: f64,
    pub train_acc: f64,
    /// test metrics (NaN on non-eval rounds)
    pub test_loss: f64,
    pub test_acc: f64,
    /// fraction of neurons currently invariant (invariant policy only)
    pub invariant_fraction: f64,
    /// wall-clock seconds the server spent on calibration this round
    pub calibration_secs: f64,
    /// updates folded into this round's aggregation (fresh + stale)
    pub aggregated: usize,
    /// late updates discarded by a Deadline barrier
    pub dropped_updates: usize,
    /// buffered semi-async updates folded in with a staleness discount
    pub stale_folded: usize,
    /// summed wire bytes of every payload aggregated this round — the
    /// bytes-moved figure the compression modes are compared on
    pub update_bytes: usize,
    /// participants lost to chaos Vanish/Hang faults this round
    pub vanished: usize,
    /// updates the validator refused and sent to quarantine
    pub quarantined: usize,
    /// shard-slice re-dispatches the executor performed this round
    pub shard_retries: usize,
    /// fresh on-time updates over planned participants (1.0 when the
    /// round planned no participants)
    pub quorum_fraction: f64,
    /// virtual seconds the round waited on its slowest straggler beyond
    /// the detection target (`max(0, straggler_time - t_target)`)
    pub straggler_wait: f64,
    /// stale updates the mitigation policy admitted into this round's
    /// aggregation (subset of `stale_folded`'s pre-seam meaning)
    pub admitted_stale: usize,
    /// mean soft-training fraction over this round's participants
    /// (1.0 unless a Helios-style policy trims local epochs)
    pub soft_fraction: f64,
}

/// Full outcome of one run.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub model: String,
    pub policy: PolicyKind,
    /// the mitigation family the run executed under (fluid hosts the
    /// dropout policies; the zoo alternatives report their own name)
    pub mitigation: Mitigation,
    pub records: Vec<RoundRecord>,
    pub final_test_acc: f64,
    pub final_test_loss: f64,
    pub total_vtime: f64,
    /// total wall-clock seconds of server-side calibration
    pub calibration_total: f64,
    pub seed: u64,
    /// total wall-clock seconds spent executing client train steps
    pub train_wall_total: f64,
}

impl ExperimentResult {
    /// Calibration overhead relative to actual training compute — the
    /// §6.1 claim is that FLuID's server-side calibration costs < 5% of
    /// training time.
    pub fn calibration_overhead(&self) -> f64 {
        if self.train_wall_total <= 0.0 {
            0.0
        } else {
            self.calibration_total / self.train_wall_total
        }
    }

    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj()
                    .set("round", r.round)
                    .set("round_time", r.round_time)
                    .set("vtime", r.vtime)
                    .set("t_target", r.t_target)
                    .set("straggler_time", r.straggler_time)
                    .set("train_loss", r.train_loss)
                    .set("train_acc", r.train_acc)
                    .set("test_loss", if r.test_loss.is_nan() { -1.0 } else { r.test_loss })
                    .set("test_acc", if r.test_acc.is_nan() { -1.0 } else { r.test_acc })
                    .set("invariant_fraction", r.invariant_fraction)
                    .set(
                        "stragglers",
                        r.straggler_ids.iter().map(|&i| i as i64).collect::<Vec<i64>>(),
                    )
                    .set(
                        "cohort",
                        r.cohort.iter().map(|&i| i as i64).collect::<Vec<i64>>(),
                    )
                    .set("rates", r.straggler_rates.clone())
                    .set("aggregated", r.aggregated)
                    .set("dropped", r.dropped_updates)
                    .set("stale", r.stale_folded)
                    .set("update_bytes", r.update_bytes)
                    .set("vanished", r.vanished)
                    .set("quarantined", r.quarantined)
                    .set("shard_retries", r.shard_retries)
                    .set("quorum_fraction", r.quorum_fraction)
                    .set("policy", crate::policy::active_id(self.mitigation, self.policy))
                    .set("straggler_wait", r.straggler_wait)
                    .set("admitted_stale", r.admitted_stale)
                    .set("soft_fraction", r.soft_fraction)
            })
            .collect();
        Json::obj()
            .set("model", self.model.as_str())
            .set("policy", self.policy.name())
            .set("mitigation", self.mitigation.name())
            .set("final_test_acc", self.final_test_acc)
            .set("final_test_loss", self.final_test_loss)
            .set("total_vtime", self.total_vtime)
            .set("calibration_overhead", self.calibration_overhead())
            .set("seed", self.seed as i64)
            .set("rounds", Json::Arr(rounds))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_lrs_match_paper() {
        assert_eq!(default_lr("femnist_cnn"), 0.004);
        assert_eq!(default_lr("cifar_vgg9"), 0.01);
        assert_eq!(default_lr("shakespeare_lstm"), 0.001);
    }

    #[test]
    fn config_presets() {
        let m = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
        assert!(m.mobile_fleet);
        assert_eq!(m.clients, 5);
        assert_eq!(m.sync_mode, SyncMode::FullBarrier);
        assert_eq!(m.fleet_size, None);
        let s = ExperimentConfig::scale("cifar_vgg9", PolicyKind::Ordered, 100);
        assert!(!s.mobile_fleet);
        assert_eq!(s.clients, 100);
        let f = ExperimentConfig::fleet("femnist_cnn", PolicyKind::Invariant, 10_000, 128);
        assert_eq!(f.fleet_size, Some(10_000));
        assert_eq!(f.sample_k, 128);
        assert_eq!(f.sampler, SamplerKind::Uniform);
        assert!(f.scenario.is_none());
        assert!(!f.mobile_fleet);
        assert!(m.chaos.is_none());
        assert_eq!(m.quorum, 0.0);
        assert_eq!(m.shard_retry_max, 0);
        assert_eq!(m.mitigation, Mitigation::Fluid);
        assert_eq!(m.mitigation_trade_off, 1.0);
        assert_eq!(m.safa_lag, 2);
    }

    #[test]
    fn validate_rejects_bad_menus_and_controller_knobs() {
        let good = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
        assert!(good.validate().is_ok());

        let mut bad = good.clone();
        bad.rates_menu = vec![];
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("rates_menu"), "{err}");

        let mut bad = good.clone();
        bad.rates_menu = vec![0.5, 1.5];
        assert!(bad.validate().is_err(), "rate > 1 accepted");
        let mut bad = good.clone();
        bad.rates_menu = vec![0.0];
        assert!(bad.validate().is_err(), "rate 0 accepted");
        let mut bad = good.clone();
        bad.rates_menu = vec![f64::NAN];
        assert!(bad.validate().is_err(), "NaN rate accepted");

        let mut bad = good.clone();
        bad.cluster_rates = Some(vec![]);
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("cluster_rates"), "{err}");
        let mut bad = good.clone();
        bad.cluster_rates = Some(vec![0.75, -0.1]);
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        bad.fixed_rate = Some(2.0);
        assert!(bad.validate().is_err());

        let mut bad = good.clone();
        bad.adapt_gain = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.adapt_deadband = 0.5;
        assert!(bad.validate().is_err());
        let mut bad = good.clone();
        bad.rate_min = 0.0;
        assert!(bad.validate().is_err());

        // ewma owns rates: post-assignment rewrites are rejected
        let mut bad = good.clone();
        bad.adapt = AdaptMode::Ewma;
        bad.fixed_rate = Some(0.5);
        assert!(bad.validate().is_err(), "ewma + fixed_rate accepted");
        let mut bad = good.clone();
        bad.adapt = AdaptMode::Ewma;
        bad.cluster_rates = Some(vec![0.65, 0.75]);
        assert!(bad.validate().is_err(), "ewma + cluster_rates accepted");
        let mut bad = good.clone();
        bad.adapt = AdaptMode::Ewma;
        bad.static_stragglers = true;
        assert!(bad.validate().is_err(), "ewma + static_stragglers accepted");
        let mut ok = good.clone();
        ok.adapt = AdaptMode::Ewma;
        assert!(ok.validate().is_ok());

        // chaos + quorum knobs are validated up front
        let mut bad = good.clone();
        bad.quorum = 1.5;
        assert!(bad.validate().is_err(), "quorum > 1 accepted");
        let mut bad = good.clone();
        bad.quorum = f64::NAN;
        assert!(bad.validate().is_err(), "NaN quorum accepted");
        let mut bad = good.clone();
        bad.chaos = ChaosConfig::parse("storm").unwrap();
        bad.chaos.as_mut().unwrap().vanish = 2.0;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("chaos"), "{err}");
        let mut ok = good.clone();
        ok.chaos = ChaosConfig::parse("storm").unwrap();
        ok.quorum = 0.5;
        ok.shard_retry_max = 3;
        assert!(ok.validate().is_ok());

        // the adapt knobs flow into the controller config
        let mut cfg = good.clone();
        cfg.adapt = AdaptMode::Ewma;
        cfg.adapt_gain = 0.7;
        let ac = cfg.adapt_config();
        assert_eq!(ac.mode, AdaptMode::Ewma);
        assert_eq!(ac.gain, 0.7);
        assert_eq!(ac.deadband, cfg.adapt_deadband);
        assert_eq!(ac.rate_min, cfg.rate_min);
    }

    #[test]
    fn validate_rejects_incoherent_mitigation_combos() {
        let base = ExperimentConfig::mobile("femnist_cnn", PolicyKind::None);

        // fedprox composes with neither ewma nor a dropout policy
        let mut bad = base.clone();
        bad.mitigation = Mitigation::FedProx;
        bad.adapt = AdaptMode::Ewma;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("ewma"), "{err}");
        let mut bad = base.clone();
        bad.mitigation = Mitigation::FedProx;
        bad.policy = PolicyKind::Invariant;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("dropout"), "{err}");

        // the trade-off knob belongs to fedprox alone, in (0, 1]
        let mut bad = base.clone();
        bad.mitigation_trade_off = 0.5;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("fedprox"), "{err}");
        let mut bad = base.clone();
        bad.mitigation = Mitigation::FedProx;
        bad.mitigation_trade_off = 0.0;
        assert!(bad.validate().is_err(), "trade-off 0 accepted");
        let mut bad = base.clone();
        bad.mitigation = Mitigation::FedProx;
        bad.mitigation_trade_off = f64::NAN;
        assert!(bad.validate().is_err(), "NaN trade-off accepted");
        let mut ok = base.clone();
        ok.mitigation = Mitigation::FedProx;
        ok.mitigation_trade_off = 0.5;
        assert!(ok.validate().is_ok());

        // safa needs the buffered barrier and a sane lag bound
        let mut bad = base.clone();
        bad.mitigation = Mitigation::Safa;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("buffered"), "{err}");
        let mut bad = base.clone();
        bad.mitigation = Mitigation::Safa;
        bad.sync_mode = SyncMode::Buffered { k: 3 };
        bad.safa_lag = 0;
        let err = format!("{:#}", bad.validate().unwrap_err());
        assert!(err.contains("safa_lag"), "{err}");
        let mut ok = base.clone();
        ok.mitigation = Mitigation::Safa;
        ok.sync_mode = SyncMode::Buffered { k: 3 };
        assert!(ok.validate().is_ok());

        // helios: no dropout policy underneath, paper detection only
        let mut bad = base.clone();
        bad.mitigation = Mitigation::Helios;
        bad.policy = PolicyKind::Random;
        assert!(bad.validate().is_err(), "helios + dropout accepted");
        let mut ok = base.clone();
        ok.mitigation = Mitigation::Helios;
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn result_json_round_trips() {
        let res = ExperimentResult {
            model: "femnist_cnn".into(),
            policy: PolicyKind::Invariant,
            mitigation: Mitigation::Fluid,
            records: vec![RoundRecord {
                round: 0,
                round_time: 3.0,
                vtime: 3.0,
                cohort: vec![0, 1, 2, 3, 4],
                straggler_ids: vec![4],
                straggler_rates: vec![0.75],
                t_target: 2.8,
                straggler_time: 3.0,
                train_loss: 4.1,
                train_acc: 0.02,
                test_loss: f64::NAN,
                test_acc: f64::NAN,
                invariant_fraction: 0.0,
                calibration_secs: 0.001,
                aggregated: 5,
                dropped_updates: 0,
                stale_folded: 0,
                update_bytes: 120_000,
                vanished: 1,
                quarantined: 2,
                shard_retries: 1,
                quorum_fraction: 0.75,
                straggler_wait: 0.2,
                admitted_stale: 0,
                soft_fraction: 1.0,
            }],
            final_test_acc: 0.8,
            final_test_loss: 0.7,
            total_vtime: 3.0,
            calibration_total: 0.001,
            seed: 1,
            train_wall_total: 1.0,
        };
        let j = res.to_json();
        let text = j.to_string_pretty();
        let back = crate::jsonlite::parse(&text).unwrap();
        assert_eq!(back.req("policy").unwrap().as_str(), Some("invariant"));
        let rounds = back.req("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        // the bytes-moved report field rides along per round
        assert_eq!(
            rounds[0].req("update_bytes").unwrap().as_f64(),
            Some(120_000.0)
        );
        // the fault-telemetry quad rides along per round
        assert_eq!(rounds[0].req("vanished").unwrap().as_f64(), Some(1.0));
        assert_eq!(rounds[0].req("quarantined").unwrap().as_f64(), Some(2.0));
        assert_eq!(rounds[0].req("shard_retries").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            rounds[0].req("quorum_fraction").unwrap().as_f64(),
            Some(0.75)
        );
        // the mitigation telemetry rides along per round: the active
        // policy id plus the three policy-zoo metrics
        assert_eq!(back.req("mitigation").unwrap().as_str(), Some("fluid"));
        assert_eq!(rounds[0].req("policy").unwrap().as_str(), Some("invariant"));
        assert_eq!(rounds[0].req("straggler_wait").unwrap().as_f64(), Some(0.2));
        assert_eq!(rounds[0].req("admitted_stale").unwrap().as_f64(), Some(0.0));
        assert_eq!(rounds[0].req("soft_fraction").unwrap().as_f64(), Some(1.0));
        assert!(res.calibration_overhead() < 0.05);
    }
}
