//! The PJRT CPU session: artifact loading and the compile cache.

use super::StepRunner;
use crate::model::ModelSpec;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A PJRT CPU session with a compile cache.
///
/// Compilation of a VGG-9 train step takes O(100ms); experiments run the
/// same artifacts for thousands of virtual clients, so executables are
/// compiled once and shared.
pub struct Session {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Session {
    /// Create a CPU session rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifacts dir: `$FLUID_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FLUID_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(file) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Build a [`StepRunner`] for a model: loads its manifest and compiles
    /// train/eval/delta executables.
    pub fn runner(&self, model: &str) -> Result<StepRunner> {
        let spec = ModelSpec::load(&self.artifacts_dir, model)?;
        StepRunner::new(self, spec)
    }

    pub fn runner_for_spec(&self, spec: ModelSpec) -> Result<StepRunner> {
        StepRunner::new(self, spec)
    }
}

// SAFETY: the PJRT CPU client is internally synchronized (TFRT CPU client);
// executables are immutable after compilation and `execute` is documented
// thread-compatible. The compile cache is Mutex-guarded. We gate actual
// multi-threaded use behind `runtime::stress` tests before relying on it.
unsafe impl Send for Session {}
unsafe impl Sync for Session {}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        // tests run from the workspace root via `cargo test`
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        p
    }

    fn have_artifacts() -> bool {
        artifacts().join("smoke.hlo.txt").exists()
    }

    #[test]
    fn smoke_round_trip() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let sess = Session::new(artifacts()).unwrap();
        assert_eq!(sess.platform(), "cpu");
        let exe = sess.load("smoke.hlo.txt").unwrap();
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let out = exe.execute::<xla::Literal>(&[x, y]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        let v = out.to_tuple1().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(v, vec![5., 5., 9., 9.]);
    }

    #[test]
    fn load_is_cached() {
        if !have_artifacts() {
            return;
        }
        let sess = Session::new(artifacts()).unwrap();
        let a = sess.load("smoke.hlo.txt").unwrap();
        let b = sess.load("smoke.hlo.txt").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn missing_artifact_is_context_error() {
        let sess = Session::new(artifacts()).unwrap();
        let err = match sess.load("nope.hlo.txt") {
            Ok(_) => panic!("expected error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains("nope.hlo.txt"), "{err}");
    }
}
