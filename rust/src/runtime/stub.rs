//! API-identical placeholders compiled when the `xla` feature is off.
//!
//! The pure layers (data pipeline, dropout policies, straggler model,
//! round engine) never touch PJRT; gating only the runtime lets
//! `cargo build --no-default-features` succeed on machines without the
//! xla_extension native library. [`Session::new`] fails with a clear
//! message, so anything that would actually execute an artifact reports
//! the missing feature instead of failing to link.

use super::types::{Batch, EvalOut, TrainOut};
use crate::model::ModelSpec;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::path::{Path, PathBuf};

const NO_XLA: &str =
    "fluid was built without the `xla` feature; the PJRT runtime is unavailable \
     (rebuild with default features to execute artifacts)";

/// Placeholder for the PJRT session. Construction always fails, so a
/// [`StepRunner`] can never be obtained from this backend.
pub struct Session {
    artifacts_dir: PathBuf,
}

impl Session {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let _ = artifacts_dir.as_ref();
        bail!(NO_XLA)
    }

    /// Default artifacts dir: `$FLUID_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FLUID_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        "none".to_string()
    }

    pub fn runner(&self, model: &str) -> Result<StepRunner> {
        let spec = ModelSpec::load(&self.artifacts_dir, model)?;
        self.runner_for_spec(spec)
    }

    pub fn runner_for_spec(&self, _spec: ModelSpec) -> Result<StepRunner> {
        bail!(NO_XLA)
    }
}

/// Placeholder step runner: same surface as the PJRT-backed one, every
/// execution path errors. Unreachable in practice (no [`Session`] can be
/// constructed) but keeps downstream code compiling unchanged.
pub struct StepRunner {
    pub spec: ModelSpec,
}

impl StepRunner {
    /// k of the fused multi-step program (0 = unavailable).
    pub fn multi_k(&self) -> usize {
        0
    }

    pub fn train_step(
        &self,
        _params: &[Tensor],
        _masks: &[Tensor],
        _batch: &Batch,
        _lr: f32,
    ) -> Result<TrainOut> {
        bail!(NO_XLA)
    }

    pub fn train_multi_step(
        &self,
        _params: &[Tensor],
        _masks: &[Tensor],
        _batches: &[Batch],
        _lr: f32,
    ) -> Result<TrainOut> {
        bail!(NO_XLA)
    }

    pub fn eval_step(
        &self,
        _params: &[Tensor],
        _masks: &[Tensor],
        _batch: &Batch,
    ) -> Result<EvalOut> {
        bail!(NO_XLA)
    }

    pub fn delta_step(&self, _old: &[Tensor], _new: &[Tensor]) -> Result<Vec<Tensor>> {
        bail!(NO_XLA)
    }

    /// All-ones masks (full model).
    pub fn full_masks(&self) -> Vec<Tensor> {
        self.spec
            .masks
            .iter()
            .map(|m| Tensor::ones(&[m.size]))
            .collect()
    }
}
