//! Step execution: train / eval / delta over one model's artifacts.
//!
//! The argument and result layouts are the manifest ordering contract
//! (see [`crate::model::ModelSpec`]):
//!
//! * train: `(params..., masks..., x, y, lr)` → `(params'..., loss, acc)`
//! * eval:  `(params..., masks..., x, y)`     → `(loss, correct)`
//! * delta: `(old params..., new params...)`  → per-group delta vectors

use super::convert::{i32s_to_literal, literal_scalar, literal_to_tensor, tensor_to_literal};
use super::types::{Batch, EvalOut, TrainOut, XData};
use super::Session;
use crate::model::ModelSpec;
use crate::tensor::Tensor;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// Compiled step functions for one model.
pub struct StepRunner {
    pub spec: ModelSpec,
    train: Arc<xla::PjRtLoadedExecutable>,
    eval: Arc<xla::PjRtLoadedExecutable>,
    delta: Arc<xla::PjRtLoadedExecutable>,
    /// fused k-step train program (§Perf L2): one host<->device round
    /// trip per round instead of per local step
    train_multi: Option<Arc<xla::PjRtLoadedExecutable>>,
}

// SAFETY: see Session — executables are immutable post-compile and the
// TFRT CPU client's execute path is thread-compatible. Validated by the
// `parallel_exec_stress` integration test.
unsafe impl Send for StepRunner {}
unsafe impl Sync for StepRunner {}

impl StepRunner {
    pub(super) fn new(sess: &Session, spec: ModelSpec) -> Result<Self> {
        let train = sess.load(&spec.train_hlo)?;
        let eval = sess.load(&spec.eval_hlo)?;
        let delta = sess.load(&spec.delta_hlo)?;
        let train_multi = match &spec.train_multi_hlo {
            Some(f) => Some(sess.load(f)?),
            None => None,
        };
        Ok(Self {
            spec,
            train,
            eval,
            delta,
            train_multi,
        })
    }

    /// k of the fused multi-step program (0 = unavailable).
    pub fn multi_k(&self) -> usize {
        if self.train_multi.is_some() {
            self.spec.train_multi_k
        } else {
            0
        }
    }

    fn x_literal(&self, x: &XData) -> Result<xla::Literal> {
        match x {
            XData::F32(t) => {
                if t.shape() != self.spec.x_shape.as_slice() {
                    return Err(anyhow!(
                        "x shape {:?} != manifest {:?}",
                        t.shape(),
                        self.spec.x_shape
                    ));
                }
                tensor_to_literal(t)
            }
            XData::I32(v) => i32s_to_literal(v, &self.spec.x_shape),
        }
    }

    fn check_params(&self, params: &[Tensor]) -> Result<()> {
        if params.len() != self.spec.params.len() {
            return Err(anyhow!(
                "{} params given, manifest has {}",
                params.len(),
                self.spec.params.len()
            ));
        }
        for (t, p) in params.iter().zip(&self.spec.params) {
            if t.shape() != p.shape.as_slice() {
                return Err(anyhow!(
                    "param {} shape {:?} != manifest {:?}",
                    p.name,
                    t.shape(),
                    p.shape
                ));
            }
        }
        Ok(())
    }

    fn check_masks(&self, masks: &[Tensor]) -> Result<()> {
        if masks.len() != self.spec.masks.len() {
            return Err(anyhow!(
                "{} masks given, manifest has {}",
                masks.len(),
                self.spec.masks.len()
            ));
        }
        for (t, m) in masks.iter().zip(&self.spec.masks) {
            if t.len() != m.size {
                return Err(anyhow!(
                    "mask {} len {} != manifest {}",
                    m.name,
                    t.len(),
                    m.size
                ));
            }
        }
        Ok(())
    }

    /// Execute one local SGD step.
    pub fn train_step(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        batch: &Batch,
        lr: f32,
    ) -> Result<TrainOut> {
        self.check_params(params)?;
        self.check_masks(masks)?;
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + masks.len() + 3);
        for t in params {
            args.push(tensor_to_literal(t)?);
        }
        for m in masks {
            args.push(tensor_to_literal(m)?);
        }
        args.push(self.x_literal(&batch.x)?);
        args.push(i32s_to_literal(&batch.y, &[self.spec.batch_size])?);
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);

        let outs = self
            .train
            .execute::<xla::Literal>(&args)
            .context("train_step execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let want = self.spec.params.len() + 2;
        if outs.len() != want {
            return Err(anyhow!("train returned {} outputs, want {want}", outs.len()));
        }
        let mut new_params = Vec::with_capacity(self.spec.params.len());
        for lit in &outs[..self.spec.params.len()] {
            new_params.push(literal_to_tensor(lit)?);
        }
        let loss = literal_scalar(&outs[outs.len() - 2])?;
        let acc = literal_scalar(&outs[outs.len() - 1])?;
        Ok(TrainOut {
            params: new_params,
            loss,
            acc,
        })
    }

    /// Execute the fused k-step train program over `k` stacked batches.
    /// `batches.len()` must equal `self.multi_k()`. Returns the final
    /// params and the mean loss/acc over the k steps.
    pub fn train_multi_step(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        batches: &[Batch],
        lr: f32,
    ) -> Result<TrainOut> {
        let exe = self
            .train_multi
            .as_ref()
            .ok_or_else(|| anyhow!("no train_multi artifact for {}", self.spec.name))?;
        let k = self.spec.train_multi_k;
        if batches.len() != k {
            return Err(anyhow!("train_multi needs {k} batches, got {}", batches.len()));
        }
        self.check_params(params)?;
        self.check_masks(masks)?;

        // stack xs: [k, *x_shape]; ys: [k, bs]
        let mut xs_shape = vec![k];
        xs_shape.extend_from_slice(&self.spec.x_shape);
        let x_lit = match &batches[0].x {
            XData::F32(_) => {
                let mut flat: Vec<f32> = Vec::new();
                for b in batches {
                    match &b.x {
                        XData::F32(t) => flat.extend_from_slice(t.data()),
                        _ => return Err(anyhow!("mixed batch dtypes")),
                    }
                }
                tensor_to_literal(&Tensor::from_vec(&xs_shape, flat))?
            }
            XData::I32(_) => {
                let mut flat: Vec<i32> = Vec::new();
                for b in batches {
                    match &b.x {
                        XData::I32(v) => flat.extend_from_slice(v),
                        _ => return Err(anyhow!("mixed batch dtypes")),
                    }
                }
                i32s_to_literal(&flat, &xs_shape)?
            }
        };
        let mut ys: Vec<i32> = Vec::with_capacity(k * self.spec.batch_size);
        for b in batches {
            ys.extend_from_slice(&b.y);
        }

        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + masks.len() + 3);
        for t in params {
            args.push(tensor_to_literal(t)?);
        }
        for m in masks {
            args.push(tensor_to_literal(m)?);
        }
        args.push(x_lit);
        args.push(i32s_to_literal(&ys, &[k, self.spec.batch_size])?);
        args.push(tensor_to_literal(&Tensor::scalar(lr))?);

        let outs = exe
            .execute::<xla::Literal>(&args)
            .context("train_multi execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        let want = self.spec.params.len() + 2;
        if outs.len() != want {
            return Err(anyhow!("train_multi returned {} outputs, want {want}", outs.len()));
        }
        let mut new_params = Vec::with_capacity(self.spec.params.len());
        for lit in &outs[..self.spec.params.len()] {
            new_params.push(literal_to_tensor(lit)?);
        }
        Ok(TrainOut {
            params: new_params,
            loss: literal_scalar(&outs[outs.len() - 2])?,
            acc: literal_scalar(&outs[outs.len() - 1])?,
        })
    }

    /// Evaluate one batch: mean loss + number of correct predictions.
    pub fn eval_step(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        batch: &Batch,
    ) -> Result<EvalOut> {
        self.check_params(params)?;
        self.check_masks(masks)?;
        let mut args: Vec<xla::Literal> =
            Vec::with_capacity(params.len() + masks.len() + 2);
        for t in params {
            args.push(tensor_to_literal(t)?);
        }
        for m in masks {
            args.push(tensor_to_literal(m)?);
        }
        args.push(self.x_literal(&batch.x)?);
        args.push(i32s_to_literal(&batch.y, &[self.spec.batch_size])?);

        let outs = self
            .eval
            .execute::<xla::Literal>(&args)
            .context("eval_step execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        if outs.len() != 2 {
            return Err(anyhow!("eval returned {} outputs, want 2", outs.len()));
        }
        Ok(EvalOut {
            loss: literal_scalar(&outs[0])?,
            correct: literal_scalar(&outs[1])?,
        })
    }

    /// Per-neuron max relative update between two parameter sets
    /// (the L1 `neuron_delta` Pallas kernel). Takes the *full* parameter
    /// lists and extracts the per-group weight tensors the delta artifact
    /// expects (manifest `delta_inputs`). Returns one vector per maskable
    /// group, aligned with `spec.masks`.
    pub fn delta_step(&self, old: &[Tensor], new: &[Tensor]) -> Result<Vec<Tensor>> {
        self.check_params(old)?;
        self.check_params(new)?;
        let idx: Vec<usize> = self
            .spec
            .delta_inputs
            .iter()
            .map(|p| self.spec.param_index(p).expect("validated at load"))
            .collect();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(idx.len() * 2);
        for &i in &idx {
            args.push(tensor_to_literal(&old[i])?);
        }
        for &i in &idx {
            args.push(tensor_to_literal(&new[i])?);
        }
        let outs = self
            .delta
            .execute::<xla::Literal>(&args)
            .context("delta_step execute")?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        if outs.len() != self.spec.masks.len() {
            return Err(anyhow!(
                "delta returned {} outputs, want {}",
                outs.len(),
                self.spec.masks.len()
            ));
        }
        outs.iter().map(literal_to_tensor).collect()
    }

    /// All-ones masks (full model).
    pub fn full_masks(&self) -> Vec<Tensor> {
        self.spec
            .masks
            .iter()
            .map(|m| Tensor::ones(&[m.size]))
            .collect()
    }
}
