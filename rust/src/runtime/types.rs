//! Runtime data types shared by the PJRT-backed step runner and the
//! no-`xla` stub: these carry no PJRT state, so everything above the
//! runtime (data pipeline, clients, engine) compiles with either backend.

use crate::tensor::Tensor;

/// Input features for one batch.
#[derive(Clone, Debug)]
pub enum XData {
    /// dense features, shape = spec.x_shape
    F32(Tensor),
    /// token ids, logical shape = spec.x_shape
    I32(Vec<i32>),
}

/// One training/eval batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: XData,
    pub y: Vec<i32>,
}

/// Result of a train step.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub params: Vec<Tensor>,
    pub loss: f32,
    pub acc: f32,
}

/// Result of an eval step.
#[derive(Clone, Debug, Copy)]
pub struct EvalOut {
    pub loss: f32,
    pub correct: f32,
}
