//! Host [`Tensor`] ⇄ XLA [`Literal`] conversion.

use crate::tensor::Tensor;
use anyhow::Result;

/// Tensor -> f32 literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.shape().is_empty() {
        // rank-0: reshape a [1] literal to []
        return Ok(lit.reshape(&[])?);
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// i32 slice -> literal of the given shape.
pub fn i32s_to_literal(xs: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    assert_eq!(shape.iter().product::<usize>(), xs.len());
    let lit = xla::Literal::vec1(xs);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// f32 literal -> Tensor (reads the literal's own shape).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::from_vec(&dims, data))
}

/// Scalar f32 from a rank-0 literal.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_2d() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn round_trip_scalar() {
        let t = Tensor::scalar(3.5);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(literal_scalar(&lit).unwrap(), 3.5);
    }

    #[test]
    fn round_trip_4d() {
        let data: Vec<f32> = (0..2 * 3 * 4 * 5).map(|i| i as f32).collect();
        let t = Tensor::from_vec(&[2, 3, 4, 5], data);
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_literal_shape() {
        let lit = i32s_to_literal(&[1, 2, 3, 4, 5, 6], &[2, 3]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4, 5, 6]);
    }
}
