//! PJRT runtime — loads AOT artifacts and executes them on the hot path.
//!
//! Wiring (see /opt/xla-example/load_hlo and DESIGN.md §1):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. HLO **text** is the interchange format;
//! xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos.
//!
//! The runtime is the only module that touches the `xla` crate, and every
//! xla-dependent piece is gated behind the default-on `xla` cargo
//! feature. Building with `--no-default-features` swaps in [`stub`]'s
//! API-identical placeholders so the pure coordinator/engine layers (and
//! their tests) compile where the PJRT native library is absent.
//! Everything above the runtime works in host [`crate::tensor::Tensor`]s.

mod types;

pub use types::{Batch, EvalOut, TrainOut, XData};

#[cfg(feature = "xla")]
mod convert;
#[cfg(feature = "xla")]
mod session;
#[cfg(feature = "xla")]
mod step;

#[cfg(feature = "xla")]
pub use convert::{i32s_to_literal, literal_to_tensor, tensor_to_literal};
#[cfg(feature = "xla")]
pub use session::Session;
#[cfg(feature = "xla")]
pub use step::StepRunner;

#[cfg(not(feature = "xla"))]
mod stub;

#[cfg(not(feature = "xla"))]
pub use stub::{Session, StepRunner};
