//! Host-side f32 tensor substrate.
//!
//! The coordinator owns all model state between PJRT executions as plain
//! row-major `Tensor`s. Deliberately minimal: shape bookkeeping, the
//! element-wise ops aggregation needs, and the weight initializers that
//! mirror `ModelDef.init_params` on the python side.

pub mod init;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![],
            data: vec![v],
        }
    }

    // ---- accessors ----------------------------------------------------------
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Reinterpret the buffer with a new shape of the same element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// View a weight tensor as [fan_in, neurons] — the delta-view layout
    /// used by the invariant scan (matches python `conv_view`/`dense_view`:
    /// row-major [KH,KW,Cin,Cout] flattens to exactly [KH*KW*Cin, Cout]).
    pub fn as_2d_neurons(&self) -> (usize, usize) {
        assert!(!self.shape.is_empty());
        let neurons = *self.shape.last().unwrap();
        (self.data.len() / neurons, neurons)
    }

    // ---- element-wise ops ----------------------------------------------------
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    /// a += w * b (axpy).
    pub fn axpy(&mut self, w: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape);
        for (a, x) in self.data.iter_mut().zip(&b.data) {
            *a += w * x;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    pub fn has_nan(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }

    /// Count of exactly-zero entries (mask diagnostics).
    pub fn count_zeros(&self) -> usize {
        self.data.iter().filter(|&&x| x == 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(Tensor::scalar(4.0).item(), 4.0);
    }

    #[test]
    #[should_panic]
    fn bad_from_vec_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn elementwise_ops() {
        let mut a = Tensor::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let b = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[11.0, 22.0, 33.0]);
        a.scale(0.5);
        assert_eq!(a.data(), &[5.5, 11.0, 16.5]);
        let d = b.sub(&a);
        assert_eq!(d.data(), &[4.5, 9.0, 13.5]);
        let mut c = Tensor::zeros(&[3]);
        c.axpy(2.0, &b);
        assert_eq!(c.data(), &[20.0, 40.0, 60.0]);
    }

    #[test]
    fn neurons_view() {
        let t = Tensor::zeros(&[5, 5, 1, 16]);
        assert_eq!(t.as_2d_neurons(), (25, 16));
        let t = Tensor::zeros(&[120, 62]);
        assert_eq!(t.as_2d_neurons(), (120, 62));
    }

    #[test]
    fn diagnostics() {
        let t = Tensor::from_vec(&[4], vec![0.0, -2.0, 1.0, 0.0]);
        assert_eq!(t.count_zeros(), 2);
        assert_eq!(t.max_abs(), 2.0);
        assert!(!t.has_nan());
        let nan = Tensor::from_vec(&[1], vec![f32::NAN]);
        assert!(nan.has_nan());
        assert!((t.l2_norm() - 5.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn reshape_keeps_data() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).reshape(&[4]);
        assert_eq!(t.shape(), &[4]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
    }
}
