//! Weight initializers — rust mirror of `ModelDef.init_params` in
//! python/compile/model.py: biases zero, matrices He-uniform over fan-in,
//! vectors small-normal. Keeping the schemes aligned means python-side
//! training dynamics (validated by pytest) carry over to the runtime.

use super::Tensor;
use crate::util::prng::Pcg32;

/// He-uniform: U(-sqrt(6/fan_in), +sqrt(6/fan_in)); fan_in = prod(shape[..-1]).
pub fn he_uniform(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let fan_in: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
    let bound = (6.0 / fan_in as f32).sqrt();
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.uniform(-bound, bound)).collect();
    Tensor::from_vec(shape, data)
}

/// N(0, 0.05) — embeddings / 1-D parameter vectors.
pub fn small_normal(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.normal() * 0.05).collect();
    Tensor::from_vec(shape, data)
}

/// Initialize one named parameter the way model.py does.
pub fn init_param(rng: &mut Pcg32, name: &str, shape: &[usize]) -> Tensor {
    if name.ends_with("_b") {
        Tensor::zeros(shape)
    } else if shape.len() >= 2 {
        he_uniform(rng, shape)
    } else {
        small_normal(rng, shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_is_zero() {
        let mut rng = Pcg32::new(1, 1);
        let t = init_param(&mut rng, "conv1_b", &[16]);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn he_uniform_within_bound() {
        let mut rng = Pcg32::new(2, 1);
        let t = init_param(&mut rng, "fc1_w", &[3136, 120]);
        let bound = (6.0f32 / 3136.0).sqrt();
        assert!(t.data().iter().all(|&x| x.abs() <= bound));
        // roughly centered
        let mean: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < bound * 0.05, "mean {mean}");
    }

    #[test]
    fn conv_fan_in_uses_all_leading_dims() {
        let mut rng = Pcg32::new(3, 1);
        let t = he_uniform(&mut rng, &[5, 5, 16, 64]);
        let bound = (6.0f32 / (5.0 * 5.0 * 16.0)).sqrt();
        assert!(t.max_abs() <= bound);
    }

    #[test]
    fn embedding_uses_small_normal() {
        let mut rng = Pcg32::new(4, 1);
        let t = init_param(&mut rng, "emb", &[80]);
        assert!(t.max_abs() < 0.5);
        assert!(t.data().iter().any(|&x| x != 0.0));
    }

    #[test]
    fn deterministic() {
        let a = init_param(&mut Pcg32::new(5, 1), "w", &[10, 10]);
        let b = init_param(&mut Pcg32::new(5, 1), "w", &[10, 10]);
        assert_eq!(a, b);
    }
}
