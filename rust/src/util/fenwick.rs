//! Incremental prefix-sum structures for O(cohort)-per-round fleet
//! sampling (DESIGN.md §10).
//!
//! * [`Fenwick`] — a binary indexed tree over **integer** weights. The
//!   historical weighted sampler materialized an O(fleet) `f64`
//!   cumulative vector every round and binary-searched it; the Fenwick
//!   tree answers the same search in O(log n) and absorbs churn-delta
//!   weight updates in O(log n), with no per-round rebuild. Because the
//!   weights are integers and every partial sum stays far below 2^53,
//!   each internal `u64 -> f64` comparison is *exact* — the descent
//!   reproduces the old `partition_point(|&c| c <= x)` answer bit for
//!   bit (see [`Fenwick::count_prefix_le`]).
//! * [`RankSelectBitset`] — a packed availability bitmap with
//!   rank/select in O(log words). `select1(i)` equals `avail[i]` of the
//!   old per-round ascending `Vec<usize>` collect, so availability-aware
//!   draws map through it bit-identically without ever materializing the
//!   available set.

/// Binary indexed tree over `u64` weights (1-based internal layout).
#[derive(Clone, Debug)]
pub struct Fenwick {
    /// tree[i] holds the sum of weights (i - lowbit(i), i], 1-based
    tree: Vec<u64>,
    n: usize,
    total: u64,
}

impl Fenwick {
    /// All-zero weights.
    pub fn new(n: usize) -> Self {
        Self { tree: vec![0; n + 1], n, total: 0 }
    }

    /// O(n) build from explicit weights.
    pub fn from_weights(ws: &[u64]) -> Self {
        let n = ws.len();
        let mut tree = vec![0u64; n + 1];
        tree[1..].copy_from_slice(ws);
        for i in 1..=n {
            let j = i + (i & i.wrapping_neg());
            if j <= n {
                tree[j] += tree[i];
            }
        }
        let total = ws.iter().sum();
        Self { tree, n, total }
    }

    /// Rebuild in place from an iterator (reuses the allocation).
    pub fn assign(&mut self, ws: impl Iterator<Item = u64>) {
        let n = self.n;
        self.tree[0] = 0;
        let mut total = 0u64;
        for (slot, w) in self.tree[1..].iter_mut().zip(ws) {
            *slot = w;
            total += w;
        }
        for i in 1..=n {
            let j = i + (i & i.wrapping_neg());
            if j <= n {
                self.tree[j] += self.tree[i];
            }
        }
        self.total = total;
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    /// Sum of weights in `[0, i)` (0-based exclusive prefix).
    pub fn prefix(&self, mut i: usize) -> u64 {
        let mut s = 0u64;
        while i > 0 {
            s += self.tree[i];
            i -= i & i.wrapping_neg();
        }
        s
    }

    /// Point query: the weight at 0-based index `i`.
    pub fn get(&self, i: usize) -> u64 {
        self.prefix(i + 1) - self.prefix(i)
    }

    /// Point update: set the weight at 0-based index `i`.
    pub fn set(&mut self, i: usize, w: u64) {
        let old = self.get(i);
        if w == old {
            return;
        }
        let mut j = i + 1;
        if w >= old {
            let d = w - old;
            self.total += d;
            while j <= self.n {
                self.tree[j] += d;
                j += j & j.wrapping_neg();
            }
        } else {
            let d = old - w;
            self.total -= d;
            while j <= self.n {
                self.tree[j] -= d;
                j += j & j.wrapping_neg();
            }
        }
    }

    /// How many 1-based prefix sums `S_1..=S_n` are `<= x` — exactly
    /// `cum.partition_point(|&c| c <= x)` over the cumulative-weight
    /// vector `cum[i] = S_{i+1}` the historical sampler built per round.
    ///
    /// The descent accumulates node sums in `u64` and compares each
    /// candidate as `f64`; with every partial sum below 2^53 the cast is
    /// exact, so the comparisons see the same values the sequential f64
    /// accumulation produced and the answer matches bit for bit. Weights
    /// are non-negative, so the prefix sums are nondecreasing and the
    /// count equals the largest position whose prefix sum is `<= x`.
    pub fn count_prefix_le(&self, x: f64) -> usize {
        let mut pos = 0usize;
        let mut acc = 0u64;
        let mut step = self.n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= self.n {
                let cand = acc + self.tree[next];
                if (cand as f64) <= x {
                    pos = next;
                    acc = cand;
                }
            }
            step >>= 1;
        }
        pos
    }

    /// Largest position whose (integer) prefix sum is `<= r`, plus that
    /// prefix sum — the select primitive for count-based structures.
    pub fn count_prefix_le_u64(&self, r: u64) -> (usize, u64) {
        let mut pos = 0usize;
        let mut acc = 0u64;
        let mut step = self.n.next_power_of_two();
        while step > 0 {
            let next = pos + step;
            if next <= self.n {
                let cand = acc + self.tree[next];
                if cand <= r {
                    pos = next;
                    acc = cand;
                }
            }
            step >>= 1;
        }
        (pos, acc)
    }
}

/// Packed bitmap over `n` slots with O(log words) rank/select — the
/// incremental replacement for the per-round `Vec<bool>` availability
/// sweep. Two word-level Fenwick trees (set bits / cleared bits) absorb
/// per-slot flips in O(log words).
#[derive(Clone, Debug)]
pub struct RankSelectBitset {
    words: Vec<u64>,
    n: usize,
    /// per-word popcounts
    ones: Fenwick,
    /// per-word zero counts (within each word's capacity)
    zeros: Fenwick,
}

impl RankSelectBitset {
    pub fn new_filled(n: usize, v: bool) -> Self {
        let nw = n.div_ceil(64);
        let mut words = vec![if v { u64::MAX } else { 0 }; nw];
        if v && n % 64 != 0 {
            // mask padding bits in the last word to zero
            words[nw - 1] = (1u64 << (n % 64)) - 1;
        }
        let mut s = Self {
            words,
            n,
            ones: Fenwick::new(nw),
            zeros: Fenwick::new(nw),
        };
        s.rebuild_counts();
        s
    }

    /// Capacity (valid bit count) of word `w`.
    fn cap(&self, w: usize) -> u64 {
        if (w + 1) * 64 <= self.n {
            64
        } else {
            (self.n - w * 64) as u64
        }
    }

    fn rebuild_counts(&mut self) {
        let words = &self.words;
        let n = self.n;
        let cap = |w: usize| -> u64 {
            if (w + 1) * 64 <= n { 64 } else { (n - w * 64) as u64 }
        };
        self.ones.assign(words.iter().map(|w| w.count_ones() as u64));
        self.zeros
            .assign((0..words.len()).map(|i| cap(i) - words[i].count_ones() as u64));
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.n);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set slot `i`; returns whether the bit actually changed.
    pub fn set(&mut self, i: usize, v: bool) -> bool {
        debug_assert!(i < self.n);
        let (w, b) = (i / 64, i % 64);
        let cur = (self.words[w] >> b) & 1 == 1;
        if cur == v {
            return false;
        }
        self.words[w] ^= 1u64 << b;
        let pc = self.words[w].count_ones() as u64;
        self.ones.set(w, pc);
        self.zeros.set(w, self.cap(w) - pc);
        true
    }

    /// Bulk reinstall from a bool slice (snapshot restore path) — O(n).
    pub fn assign_from(&mut self, bits: &[bool]) {
        assert_eq!(bits.len(), self.n, "bitset length mismatch");
        for w in self.words.iter_mut() {
            *w = 0;
        }
        for (i, &b) in bits.iter().enumerate() {
            if b {
                self.words[i / 64] |= 1u64 << (i % 64);
            }
        }
        self.rebuild_counts();
    }

    pub fn count_ones(&self) -> usize {
        self.ones.total() as usize
    }

    pub fn count_zeros(&self) -> usize {
        self.n - self.count_ones()
    }

    /// Index of the `r`-th (0-based) set bit — equals `avail[r]` of an
    /// ascending collect of the set slots. Panics if `r >= count_ones()`.
    pub fn select1(&self, r: usize) -> usize {
        debug_assert!(r < self.count_ones());
        let (w, acc) = self.ones.count_prefix_le_u64(r as u64);
        // after skipping `w` whole words (acc set bits), the target is
        // the (r - acc)-th set bit of word w
        w * 64 + select_in_word(self.words[w], (r as u64 - acc) as u32)
    }

    /// Index of the `r`-th (0-based) cleared bit. Padding bits past `n`
    /// are excluded via the per-word capacity counts.
    pub fn select0(&self, r: usize) -> usize {
        debug_assert!(r < self.count_zeros());
        let (w, acc) = self.zeros.count_prefix_le_u64(r as u64);
        let inv = !self.words[w] & mask_low(self.cap(w));
        w * 64 + select_in_word(inv, (r as u64 - acc) as u32)
    }
}

fn mask_low(bits: u64) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

/// Position of the `r`-th (0-based) set bit inside one word.
fn select_in_word(mut w: u64, mut r: u32) -> usize {
    debug_assert!((w.count_ones()) > r);
    loop {
        let t = w.trailing_zeros();
        if r == 0 {
            return t as usize;
        }
        w &= w - 1;
        r -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn fenwick_prefix_and_point_ops() {
        let ws = [3u64, 0, 7, 1, 0, 0, 12, 5];
        let mut f = Fenwick::from_weights(&ws);
        assert_eq!(f.len(), 8);
        assert_eq!(f.total(), 28);
        let mut acc = 0;
        for (i, &w) in ws.iter().enumerate() {
            assert_eq!(f.prefix(i), acc);
            assert_eq!(f.get(i), w);
            acc += w;
        }
        assert_eq!(f.prefix(8), 28);
        f.set(2, 0);
        f.set(4, 9);
        assert_eq!(f.total(), 28 - 7 + 9);
        assert_eq!(f.get(2), 0);
        assert_eq!(f.get(4), 9);
        // no-op set keeps everything intact
        f.set(4, 9);
        assert_eq!(f.prefix(5), 3 + 0 + 0 + 1 + 9);
    }

    #[test]
    fn fenwick_count_matches_partition_point() {
        let mut rng = Pcg32::new(7, 1);
        for n in [1usize, 2, 5, 63, 64, 65, 300] {
            let ws: Vec<u64> = (0..n).map(|_| (rng.below(20)) as u64).collect();
            let f = Fenwick::from_weights(&ws);
            // the historical cumulative vector, built exactly as the old
            // sampler did (sequential f64 accumulation)
            let mut cum = Vec::with_capacity(n);
            let mut total = 0.0f64;
            for &w in &ws {
                total += w as f64;
                cum.push(total);
            }
            for _ in 0..200 {
                let x = rng.next_f64() * total;
                assert_eq!(
                    f.count_prefix_le(x),
                    cum.partition_point(|&c| c <= x),
                    "n={n} x={x}"
                );
            }
            // boundary values, including exact prefix sums
            for probe in [-1.0, 0.0, total, total + 1.0] {
                assert_eq!(
                    f.count_prefix_le(probe),
                    cum.partition_point(|&c| c <= probe),
                    "n={n} probe={probe}"
                );
            }
        }
    }

    #[test]
    fn fenwick_assign_reuses_allocation() {
        let mut f = Fenwick::new(6);
        f.assign([1u64, 2, 3, 4, 5, 6].into_iter());
        assert_eq!(f.total(), 21);
        assert_eq!(f.prefix(3), 6);
        f.assign([0u64, 0, 0, 0, 0, 10].into_iter());
        assert_eq!(f.total(), 10);
        assert_eq!(f.prefix(5), 0);
        assert_eq!(f.get(5), 10);
    }

    #[test]
    fn bitset_rank_select_matches_dense_reference() {
        let mut rng = Pcg32::new(5, 9);
        for n in [1usize, 63, 64, 65, 130, 1000] {
            let mut bits = RankSelectBitset::new_filled(n, false);
            let mut dense = vec![false; n];
            for _ in 0..3 * n {
                let i = rng.below_usize(n);
                let v = rng.next_f64() < 0.5;
                assert_eq!(bits.set(i, v), dense[i] != v);
                dense[i] = v;
            }
            let set: Vec<usize> =
                (0..n).filter(|&i| dense[i]).collect();
            let clear: Vec<usize> =
                (0..n).filter(|&i| !dense[i]).collect();
            assert_eq!(bits.count_ones(), set.len(), "n={n}");
            assert_eq!(bits.count_zeros(), clear.len(), "n={n}");
            for (r, &i) in set.iter().enumerate() {
                assert_eq!(bits.select1(r), i, "n={n} select1({r})");
            }
            for (r, &i) in clear.iter().enumerate() {
                assert_eq!(bits.select0(r), i, "n={n} select0({r})");
            }
            for i in 0..n {
                assert_eq!(bits.get(i), dense[i]);
            }
        }
    }

    #[test]
    fn bitset_filled_construction_and_bulk_assign() {
        let b = RankSelectBitset::new_filled(70, true);
        assert_eq!(b.count_ones(), 70);
        assert_eq!(b.count_zeros(), 0);
        assert_eq!(b.select1(69), 69);
        let mut b = RankSelectBitset::new_filled(70, false);
        assert_eq!(b.count_ones(), 0);
        assert_eq!(b.select0(64), 64);
        let pattern: Vec<bool> = (0..70).map(|i| i % 3 == 0).collect();
        b.assign_from(&pattern);
        assert_eq!(b.count_ones(), pattern.iter().filter(|&&x| x).count());
        assert_eq!(b.select1(1), 3);
        assert_eq!(b.select0(0), 1);
    }
}
