//! General substrates built in-repo (the offline registry has no rand /
//! clap / proptest — see DESIGN.md §2).

pub mod cli;
pub mod fenwick;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod stats;
