//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `Pcg32` (PCG-XSH-RR 64/32) for streams of random values plus a
//! `SplitMix64` seeder for deriving independent streams. Every stochastic
//! component in the repo (data synthesis, partitioning, device jitter,
//! random dropout, client sampling) takes an explicit seed so entire
//! experiments replay bit-identically.

/// SplitMix64 — used to expand one u64 seed into independent sub-seeds.
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create from a seed; the stream id makes independent generators.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Raw generator state `(state, inc)` — snapshot persistence only.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg32::state_parts`] output, resuming
    /// the stream at exactly the captured position.
    pub fn from_state_parts(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    /// Derive a child generator (stable under reordering of other draws).
    pub fn derive(&self, salt: u64) -> Pcg32 {
        let mut sm = SplitMix64::new(self.state ^ salt.wrapping_mul(0x9E37_79B9));
        Pcg32::new(sm.next_u64(), sm.next_u64() | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Unbiased integer in [0, n) (Lemire's method).
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Lognormal with median 1.0 and shape sigma — device jitter model.
    pub fn lognormal(&mut self, sigma: f32) -> f32 {
        (self.normal() * sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Dirichlet(alpha * 1) sample of dimension k via Gamma(alpha) draws
    /// (Marsaglia–Tsang; for alpha < 1 uses the boost trick).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = self.next_f64().max(1e-300);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal() as f64;
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 7);
        let mut b = Pcg32::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn state_parts_resume_the_stream_exactly() {
        let mut a = Pcg32::new(99, 3);
        for _ in 0..17 {
            a.next_u32();
        }
        let (state, inc) = a.state_parts();
        let mut b = Pcg32::from_state_parts(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::new(1, 1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg32::new(3, 3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(9, 1);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5, 5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::new(8, 8);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 10);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg32::new(11, 1);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let d = r.dirichlet(alpha, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn lognormal_positive_median_near_one() {
        let mut r = Pcg32::new(13, 1);
        let mut xs: Vec<f32> = (0..20_001).map(|_| r.lognormal(0.1)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let med = xs[10_000];
        assert!((med - 1.0).abs() < 0.02, "median {med}");
    }
}
