//! Minimal declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, and auto-generated `--help`. Used by the `fluid` binary, the
//! examples, and every bench harness (`--full`, `--seeds`, ...).

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative arg spec + parsed values.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse `std::env::args()`; prints help and exits on `--help`.
    pub fn parse(self) -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse from an explicit argv (testable).
    pub fn parse_from(mut self, argv: &[String]) -> Result<Self, String> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.help_text());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.help_text()))?
                    .clone();
                let val = if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .ok_or_else(|| format!("option --{key} needs a value"))?
                        .clone()
                };
                self.values.insert(key, val);
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(self)
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<26}{}{def}\n", o.help));
        }
        s.push_str("  --help                  show this help\n");
        s
    }

    // ---- typed getters -----------------------------------------------------

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|_| panic!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    /// Comma-separated list.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        let v = self.get(name);
        if v.is_empty() {
            vec![]
        } else {
            v.split(',').map(|s| s.trim().to_string()).collect()
        }
    }

    pub fn get_f64_list(&self, name: &str) -> Vec<f64> {
        self.get_list(name)
            .iter()
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name}: bad number {s}")))
            .collect()
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn spec() -> Args {
        Args::new("t", "test")
            .opt("rounds", "10", "rounds")
            .opt("model", "femnist_cnn", "model name")
            .opt("rs", "0.5,0.75", "r list")
            .flag("full", "full sweep")
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse_from(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("rounds"), 10);
        assert_eq!(a.get("model"), "femnist_cnn");
        assert!(!a.get_flag("full"));
    }

    #[test]
    fn values_override() {
        let a = spec()
            .parse_from(&argv(&["--rounds", "25", "--full", "--model=vgg9"]))
            .unwrap();
        assert_eq!(a.get_usize("rounds"), 25);
        assert!(a.get_flag("full"));
        assert_eq!(a.get("model"), "vgg9");
    }

    #[test]
    fn list_parsing() {
        let a = spec().parse_from(&argv(&["--rs", "0.95, 0.85,0.5"])).unwrap();
        assert_eq!(a.get_f64_list("rs"), vec![0.95, 0.85, 0.5]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse_from(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = spec().parse_from(&argv(&["cmd", "--rounds", "5", "x"])).unwrap();
        assert_eq!(a.positional(), &["cmd".to_string(), "x".to_string()]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(spec().parse_from(&argv(&["--rounds"])).is_err());
    }
}
