//! Statistics substrate: summary stats, Welch's t-test (the paper claims
//! significance at α < 0.05 for Table 2), and ordinary least squares (the
//! Appendix A.3 "training time is linear in sub-model size" fit).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Weighted mean of `xs` under `weights` (0 for empty).
///
/// Uniform weights reduce to the plain [`mean`] *through the same code
/// path*, so example-weighted round metrics are bit-identical to the
/// historical unweighted ones whenever every client holds the same number
/// of examples (the standard fleet setup).
pub fn weighted_mean(xs: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(xs.len(), weights.len(), "weighted_mean length mismatch");
    if xs.is_empty() {
        return 0.0;
    }
    let uniform = weights.windows(2).all(|w| w[0] == w[1]);
    if uniform {
        return mean(xs);
    }
    let wsum: f64 = weights.iter().sum();
    if wsum <= 0.0 {
        return mean(xs);
    }
    xs.iter().zip(weights).map(|(x, w)| x * w).sum::<f64>() / wsum
}

/// Sample standard deviation (n-1 denominator; 0 for n < 2 — the guard
/// also keeps the `xs.len() - 1` below from underflowing on an empty
/// slice).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b)); // NaN sorts last instead of panicking
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] by linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b)); // NaN sorts last instead of panicking
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Welch's unequal-variance t-test. Returns (t, approx two-sided p).
///
/// The p-value uses the normal approximation of the t distribution with
/// Welch–Satterthwaite dof — adequate for the n≈5..10 seed comparisons
/// in Table 2 significance checks (we only gate on p < 0.05).
pub fn welch_t_test(a: &[f64], b: &[f64]) -> (f64, f64) {
    let (na, nb) = (a.len() as f64, b.len() as f64);
    if na < 2.0 || nb < 2.0 {
        return (0.0, 1.0);
    }
    let (ma, mb) = (mean(a), mean(b));
    let (va, vb) = (std_dev(a).powi(2), std_dev(b).powi(2));
    let se = (va / na + vb / nb).sqrt();
    if se == 0.0 {
        return if ma == mb { (0.0, 1.0) } else { (f64::INFINITY, 0.0) };
    }
    let t = (ma - mb) / se;
    let dof = (va / na + vb / nb).powi(2)
        / ((va / na).powi(2) / (na - 1.0) + (vb / nb).powi(2) / (nb - 1.0));
    // t -> z via Cornish-Fisher-ish correction, then two-sided normal tail
    let z = t * (1.0 - 1.0 / (4.0 * dof)) / (1.0 + t * t / (2.0 * dof)).sqrt();
    let p = 2.0 * normal_sf(z.abs());
    (t, p)
}

/// Standard normal survival function via Abramowitz–Stegun 7.1.26.
pub fn normal_sf(z: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * z);
    let poly = t
        * (0.319381530
            + t * (-0.356563782 + t * (1.781477937 + t * (-1.821255978 + t * 1.330274429))));
    let pdf = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
    (pdf * poly).clamp(0.0, 1.0)
}

/// OLS fit y = a + b x. Returns (intercept, slope, r^2).
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.len() < 2 {
        return (mean(y), 0.0, 1.0);
    }
    let (mx, my) = (mean(x), mean(y));
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    if sxx == 0.0 {
        return (my, 0.0, 1.0);
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let ss_tot: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(a, b)| {
            let pred = intercept + slope * a;
            (b - pred) * (b - pred)
        })
        .sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    let _ = n;
    (intercept, slope, r2)
}

/// Running aggregator for streams of observations.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    m: f64,
    s: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            m: 0.0,
            s: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford online update.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.m;
        self.m += d / self.n as f64;
        self.s += d * (x - self.m);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.m
    }

    pub fn std_dev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.s / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.13809).abs() < 1e-4);
    }

    #[test]
    fn std_dev_degenerate_lengths_are_zero() {
        // len 0 and 1 must return 0.0, never underflow `len - 1`
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(std_dev(&[42.0]), 0.0);
        let mut s = Summary::new();
        assert_eq!(s.std_dev(), 0.0);
        s.add(42.0);
        assert_eq!(s.std_dev(), 0.0);
    }

    #[test]
    fn order_statistics_survive_nan() {
        // a NaN input sorts last (total_cmp) instead of panicking
        assert_eq!(median(&[3.0, f64::NAN, 1.0]), 3.0);
        assert_eq!(percentile(&[10.0, f64::NAN, 20.0], 0.0), 10.0);
        assert_eq!(percentile(&[10.0, f64::NAN, 20.0], 50.0), 20.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn welch_detects_difference() {
        let a = [10.0, 10.1, 9.9, 10.2, 9.8];
        let b = [12.0, 12.1, 11.9, 12.2, 11.8];
        let (_, p) = welch_t_test(&a, &b);
        assert!(p < 0.001, "p = {p}");
    }

    #[test]
    fn welch_same_distribution_not_significant() {
        let a = [1.0, 1.1, 0.9, 1.05, 0.95];
        let b = [1.02, 1.08, 0.92, 1.03, 0.97];
        let (_, p) = welch_t_test(&a, &b);
        assert!(p > 0.05, "p = {p}");
    }

    #[test]
    fn normal_sf_reference_values() {
        assert!((normal_sf(0.0) - 0.5).abs() < 1e-6);
        assert!((normal_sf(1.96) - 0.025).abs() < 2e-4);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [0.5, 0.65, 0.75, 0.85, 1.0];
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn summary_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 6.0);
    }

    #[test]
    fn weighted_mean_basics() {
        let xs = [1.0, 3.0];
        // uniform weights == plain mean, bitwise
        assert_eq!(weighted_mean(&xs, &[60.0, 60.0]).to_bits(), mean(&xs).to_bits());
        // non-uniform weights pull toward the heavier sample
        assert!((weighted_mean(&xs, &[1.0, 3.0]) - 2.5).abs() < 1e-12);
        // empty is 0
        assert_eq!(weighted_mean(&[], &[]), 0.0);
    }
}
