//! Scoped thread-pool substrate (tokio unavailable offline; the FL round
//! loop is embarrassingly parallel over clients, so a simple fork-join
//! `scope_map` over std threads is all the coordinator needs).
//!
//! Work is chunked over at most `threads` OS threads via
//! `std::thread::scope`, so borrowed data needs no `'static` bound.
//! The engine's [`crate::engine::LocalExecutor`] is the in-process
//! backend built on this substrate; alternative `ClientExecutor`
//! implementations bypass it entirely.

/// Map `f` over `items` in parallel, preserving order.
///
/// `threads == 1` (or a single item) degrades to a plain sequential map,
/// which keeps PJRT executions serialized when the runtime is not
/// thread-safe-enough to share (see `runtime::Session::parallelism`).
pub fn scope_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let mut start = 0usize;
        for slot in out.chunks_mut(chunk) {
            let begin = start;
            let end = begin + slot.len();
            start = end;
            let items = &items[begin..end];
            s.spawn(move || {
                for (k, item) in items.iter().enumerate() {
                    slot[k] = Some(f(begin + k, item));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker panicked")).collect()
}

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn maps_in_order() {
        let xs: Vec<usize> = (0..103).collect();
        let ys = scope_map(&xs, 8, |i, x| {
            assert_eq!(i, *x);
            x * 2
        });
        assert_eq!(ys, (0..103).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path() {
        let xs = vec![1, 2, 3];
        assert_eq!(scope_map(&xs, 1, |_, x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let xs: Vec<u32> = vec![];
        assert!(scope_map(&xs, 4, |_, x| *x).is_empty());
    }

    #[test]
    fn actually_parallel() {
        let counter = AtomicUsize::new(0);
        let xs: Vec<usize> = (0..64).collect();
        scope_map(&xs, 8, |_, _| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn more_threads_than_items() {
        let xs = vec![5, 6];
        assert_eq!(scope_map(&xs, 16, |_, x| *x), vec![5, 6]);
    }
}
