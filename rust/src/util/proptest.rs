//! Property-testing substrate (the offline registry has no `proptest`).
//!
//! A deliberately small core: a `Gen` wraps a PRNG with a size budget;
//! `Arbitrary`-style generator closures produce cases; [`check`] runs N
//! cases and on failure greedily *shrinks* using a caller-provided
//! shrinker before reporting the minimal counterexample.
//!
//! Used by the coordinator invariants test-suite (DESIGN.md §8):
//! aggregation conservation, mask algebra, threshold monotonicity,
//! partitioner coverage, JSON round-trips.

use crate::util::prng::Pcg32;

/// Random-case generator context.
pub struct Gen {
    pub rng: Pcg32,
    /// rough size budget for containers, grows over the run
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self {
            rng: Pcg32::new(seed, 0xA11CE),
            size: size.max(1),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        lo + self.rng.below_usize(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.uniform(lo, hi)).collect()
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 128,
            seed: 0xF1_D0,
            max_shrink_steps: 512,
        }
    }
}

/// Run `prop` over `cases` generated inputs; panic with the (shrunk)
/// counterexample on failure.
///
/// * `gen` — produce a case from a [`Gen`].
/// * `shrink` — yield strictly "smaller" candidates for a failing case
///   (return an empty vec to stop shrinking).
/// * `prop` — the property itself.
pub fn check<T, G, S, P>(cfg: Config, gen: G, shrink: S, prop: P)
where
    T: Clone + std::fmt::Debug,
    G: Fn(&mut Gen) -> T,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    for case_idx in 0..cfg.cases {
        // grow sizes over the run: small cases first for nicer failures
        let size = 1 + (case_idx * 32) / cfg.cases.max(1);
        let mut g = Gen::new(cfg.seed.wrapping_add(case_idx as u64), size);
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg, steps) =
                shrink_loop(input, msg, &shrink, &prop, cfg.max_shrink_steps);
            panic!(
                "property failed (case {case_idx}, shrunk {steps} steps)\n\
                 counterexample: {min_input:?}\nreason: {min_msg}"
            );
        }
    }
}

fn shrink_loop<T, S, P>(
    mut cur: T,
    mut msg: String,
    shrink: &S,
    prop: &P,
    max_steps: usize,
) -> (T, String, usize)
where
    T: Clone + std::fmt::Debug,
    S: Fn(&T) -> Vec<T>,
    P: Fn(&T) -> PropResult,
{
    let mut steps = 0;
    'outer: while steps < max_steps {
        for cand in shrink(&cur) {
            steps += 1;
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
            if steps >= max_steps {
                break;
            }
        }
        break;
    }
    (cur, msg, steps)
}

/// Common shrinker: all ways of removing one element from a vec, plus
/// halving it.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
    }
    for i in 0..v.len() {
        let mut w = v.to_vec();
        w.remove(i);
        out.push(w);
    }
    out
}

/// Common shrinker for numeric scalars: towards zero.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    if x == 0 {
        vec![]
    } else {
        vec![x / 2, x - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config::default(),
            |g| {
                let n = g.usize_in(0, 20);
                g.vec_f32(n, -1.0, 1.0)
            },
            |v| shrink_vec(v),
            |v| {
                if v.iter().all(|x| x.abs() <= 1.0) {
                    Ok(())
                } else {
                    Err("out of range".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(
            Config {
                cases: 64,
                ..Default::default()
            },
            |g| g.usize_in(0, 100),
            |&x| shrink_usize(x),
            |&x| if x < 42 { Ok(()) } else { Err("too big".into()) },
        );
    }

    #[test]
    fn shrinking_finds_minimal_vec() {
        // property: no vec contains an element > 0.5. The shrunk
        // counterexample should be a single-element vec.
        let res = std::panic::catch_unwind(|| {
            check(
                Config {
                    cases: 200,
                    ..Default::default()
                },
                |g| {
                    let n = g.usize_in(0, 30);
                    g.vec_f32(n, 0.0, 1.0)
                },
                |v| shrink_vec(v),
                |v| {
                    if v.iter().all(|&x| x <= 0.5) {
                        Ok(())
                    } else {
                        Err("elem > 0.5".into())
                    }
                },
            )
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // counterexample printed as a 1-element vec
        let after = msg.split("counterexample: ").nth(1).unwrap();
        let n_commas = after.split('\n').next().unwrap().matches(',').count();
        assert_eq!(n_commas, 0, "not minimal: {msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        // both runs must fail with the identical counterexample
        let run = || {
            std::panic::catch_unwind(|| {
                check(
                    Config {
                        cases: 64,
                        seed: 7,
                        ..Default::default()
                    },
                    |g| g.usize_in(0, 1000),
                    |&x| shrink_usize(x),
                    |&x| if x % 17 != 13 { Ok(()) } else { Err("hit".into()) },
                )
            })
        };
        let (a, b) = (run(), run());
        match (a, b) {
            (Err(x), Err(y)) => {
                let xs = *x.downcast::<String>().unwrap();
                let ys = *y.downcast::<String>().unwrap();
                assert_eq!(xs, ys);
            }
            _ => { /* property may simply never fail for this seed — fine */ }
        }
    }
}
