//! Fleet-scale client population: incremental per-round cohort sampling.
//!
//! The classic engine path materializes every client (device profile +
//! data shard) up front — fine for 5 phones, impossible for the ROADMAP
//! regime of 1M+ simulated clients. A [`Fleet`] holds a shared pool of
//! [`DeviceProfile`]s plus the population's sampling state in the
//! incremental structures of [`crate::fl::sampling`]: shard sizes in a
//! Fenwick tree, availability in a rank/select bitset. Per-client facts
//! that used to live in an O(fleet) descriptor vector are *derived*
//! (device = id mod pool, shard = id), so descriptor memory is the
//! Fenwick + bitmap alone; shard *data* only exists for the sampled
//! cohort each round (lazy hydration, see [`crate::data::ShardSource`]).
//!
//! [`SamplerKind`] + [`Fleet::sample`] are the per-round client sampler:
//! uniform (the A.6 protocol at population scale), weighted-by-data
//! (clients with more examples participate proportionally more, the
//! production-FL default), and availability-aware (never selects a
//! churned-out client — pair with `engine::scenario` churn scripts).
//! Every draw is bit-identical to the historical O(fleet) sampler for
//! the same seed (see the cross-implementation equivalence tests below
//! and DESIGN.md §10).

use crate::fl::sampling::CohortSampler;
use crate::straggler::{mobile_fleet, synthetic_fleet, DeviceProfile};
use crate::util::prng::Pcg32;

/// Upper bound on distinct synthetic device profiles held by a fleet —
/// beyond this, clients cycle through the pool (profiles are ~100 bytes
/// each; the pool keeps a 1M fleet's device table at a few hundred KB
/// while preserving the lognormal speed spread).
pub const DEVICE_POOL_CAP: usize = 2048;

/// One client, materialized on demand for diagnostics — the population
/// itself never stores these (device and shard are derived from the id,
/// size and availability live in the sampler structures).
#[derive(Clone, Debug)]
pub struct ClientDescriptor {
    pub id: usize,
    /// index into [`Fleet::devices`]
    pub device: usize,
    /// shard id for lazy hydration (== id for the built-in partitions)
    pub shard: usize,
    /// examples in the shard — known without hydrating it
    pub data_len: usize,
    /// availability state, driven by scenario churn scripts
    pub available: bool,
}

/// A client population: shared device pool + incremental sampling state.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<DeviceProfile>,
    n: usize,
    sampler: CohortSampler,
}

impl Fleet {
    fn from_devices(devices: Vec<DeviceProfile>, n: usize) -> Fleet {
        Fleet {
            devices,
            n,
            sampler: CohortSampler::new(n),
        }
    }

    /// The classic (pre-fleet) device assignment, preserved bit-for-bit:
    /// mobile fleets cycle the five Table-1 phones; synthetic fleets give
    /// every client its own lognormal profile.
    pub fn classic(n: usize, mobile: bool, device_seed: u64) -> Fleet {
        if mobile {
            Fleet::from_devices(mobile_fleet(), n)
        } else {
            Fleet::from_devices(synthetic_fleet(n, device_seed), n)
        }
    }

    /// Fleet-scale population: a capped pool of synthetic profiles cycled
    /// across `n` clients.
    pub fn synthetic_pool(n: usize, device_seed: u64) -> Fleet {
        Fleet::from_devices(
            synthetic_fleet(n.min(DEVICE_POOL_CAP).max(1), device_seed),
            n,
        )
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Device index of client `c` — the historical descriptor assignment
    /// (`id mod pool size`), now computed instead of stored.
    pub fn device_of(&self, c: usize) -> usize {
        debug_assert!(c < self.n);
        c % self.devices.len().max(1)
    }

    /// Shard id of client `c` (== id for the built-in partitions; the
    /// indirection is part of the descriptor contract).
    pub fn shard_of(&self, c: usize) -> usize {
        debug_assert!(c < self.n);
        c
    }

    pub fn profile(&self, c: usize) -> &DeviceProfile {
        &self.devices[self.device_of(c)]
    }

    /// Examples in client `c`'s shard (Fenwick point query).
    pub fn data_len(&self, c: usize) -> usize {
        self.sampler.weight(c) as usize
    }

    /// Update one client's shard size — O(log n) delta into the weighted
    /// sampler, no rebuild.
    pub fn set_data_len(&mut self, c: usize, len: usize) {
        self.sampler.set_weight(c, len as u64);
    }

    /// Bulk-install every client's shard size (engine build) — O(n) once.
    pub fn set_data_lens(&mut self, lens: impl Iterator<Item = usize>) {
        self.sampler.assign_weights(lens.map(|l| l as u64));
    }

    /// Materialize one client's descriptor (diagnostics / tests).
    pub fn descriptor(&self, c: usize) -> ClientDescriptor {
        ClientDescriptor {
            id: c,
            device: self.device_of(c),
            shard: self.shard_of(c),
            data_len: self.data_len(c),
            available: self.is_available(c),
        }
    }

    pub fn is_available(&self, c: usize) -> bool {
        self.sampler.is_available(c)
    }

    pub fn set_available(&mut self, c: usize, v: bool) {
        self.sampler.set_available(c, v);
    }

    /// O(1) — maintained incrementally by the availability bitset.
    pub fn num_available(&self) -> usize {
        self.sampler.num_available()
    }

    /// Materialize the availability map (snapshot capture) — O(n).
    pub fn availability(&self) -> Vec<bool> {
        self.sampler.availability()
    }

    /// Bulk reinstall availability (snapshot restore) — O(n).
    pub fn set_availability(&mut self, bits: &[bool]) {
        self.sampler.assign_availability(bits);
    }

    /// Client -> device index table (diagnostics; the scheduler resolves
    /// devices through [`Fleet::device_of`] instead).
    pub fn device_map(&self) -> Vec<usize> {
        (0..self.n).map(|c| self.device_of(c)).collect()
    }

    /// The slowest client on `model` — same answer as the historic O(n)
    /// `max_by` scan over every client (last maximum wins; `total_cmp`
    /// agrees with the old partial order on the finite base times and
    /// cannot panic), computed in O(pool) over the device table: clients
    /// sharing a device tie exactly, so the last maximal client is the
    /// last client of the last-winning maximal device.
    pub fn slowest(&self, model: &str) -> usize {
        if self.n == 0 {
            return 0;
        }
        let d = self.devices.len().max(1);
        let reachable = d.min(self.n);
        let mut best_time = f64::NEG_INFINITY;
        let mut best_client = 0usize;
        for dev in 0..reachable {
            let bt = self.devices[dev].base_time(model);
            // largest client id < n congruent to dev (mod d)
            let last = dev + d * ((self.n - 1 - dev) / d);
            match bt.total_cmp(&best_time) {
                std::cmp::Ordering::Greater => {
                    best_time = bt;
                    best_client = last;
                }
                std::cmp::Ordering::Equal => best_client = best_client.max(last),
                std::cmp::Ordering::Less => {}
            }
        }
        best_client
    }

    /// Sample a round's cohort of (at most) `k` distinct clients through
    /// the incremental sampler — O(k log n) per draw, bit-identical to
    /// the historical O(fleet) algorithms. The result is in sampler-draw
    /// order; callers sort if they need id order.
    pub fn sample(&mut self, kind: SamplerKind, k: usize, rng: &mut Pcg32) -> Vec<usize> {
        if self.n == 0 || k == 0 {
            return Vec::new();
        }
        match kind {
            SamplerKind::Uniform => self.sampler.sample_uniform(k, rng),
            SamplerKind::WeightedByData => self.sampler.sample_weighted(k, rng),
            SamplerKind::AvailabilityAware => self.sampler.sample_available(k, rng),
        }
    }

    /// Apply one round of Bernoulli join/leave churn as sparse deltas
    /// (see [`CohortSampler::apply_churn`]). Returns `(left, rejoined)`.
    pub fn apply_churn(
        &mut self,
        churn_out: f64,
        rejoin: f64,
        rng: &mut Pcg32,
    ) -> (usize, usize) {
        self.sampler.apply_churn(churn_out, rejoin, rng)
    }
}

/// Per-round client-sampling policy over a [`Fleet`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SamplerKind {
    /// uniform over the whole population (churned-out clients may be
    /// selected but will not participate)
    #[default]
    Uniform,
    /// probability proportional to shard size (production-FL default)
    WeightedByData,
    /// uniform over currently-available clients only
    AvailabilityAware,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Option<SamplerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "uniform" => SamplerKind::Uniform,
            "weighted" | "weighted-by-data" => SamplerKind::WeightedByData,
            "available" | "availability" | "availability-aware" => {
                SamplerKind::AvailabilityAware
            }
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::WeightedByData => "weighted",
            SamplerKind::AvailabilityAware => "available",
        }
    }
}

/// Sample a round's cohort — thin wrapper over [`Fleet::sample`], kept
/// as the historical free-function entry point (now `&mut` because the
/// sampler's scratch is reused across draws).
pub fn sample_cohort(
    fleet: &mut Fleet,
    kind: SamplerKind,
    k: usize,
    rng: &mut Pcg32,
) -> Vec<usize> {
    fleet.sample(kind, k, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_fleet(n: usize) -> Fleet {
        let mut f = Fleet::synthetic_pool(n, 7);
        f.set_data_lens((0..n).map(|i| 10 + (i % 5) * 10));
        f
    }

    /// The historical O(fleet) sampler, verbatim — the reference the
    /// incremental implementation must reproduce bit for bit.
    mod reference {
        use super::*;

        pub fn sample_cohort_ref(
            fleet: &Fleet,
            kind: SamplerKind,
            k: usize,
            rng: &mut Pcg32,
        ) -> Vec<usize> {
            let n = fleet.len();
            if n == 0 || k == 0 {
                return Vec::new();
            }
            match kind {
                SamplerKind::Uniform => rng.sample_indices(n, k.min(n)),
                SamplerKind::WeightedByData => sample_weighted_ref(fleet, k.min(n), rng),
                SamplerKind::AvailabilityAware => {
                    let avail: Vec<usize> =
                        (0..n).filter(|&c| fleet.is_available(c)).collect();
                    if avail.is_empty() {
                        return Vec::new();
                    }
                    let k = k.min(avail.len());
                    rng.sample_indices(avail.len(), k)
                        .into_iter()
                        .map(|i| avail[i])
                        .collect()
                }
            }
        }

        fn sample_weighted_ref(fleet: &Fleet, k: usize, rng: &mut Pcg32) -> Vec<usize> {
            let n = fleet.len();
            if k >= n {
                return (0..n).collect();
            }
            let mut cum = Vec::with_capacity(n);
            let mut total = 0.0f64;
            for c in 0..n {
                total += fleet.data_len(c) as f64;
                cum.push(total);
            }
            if total <= 0.0 {
                return rng.sample_indices(n, k);
            }
            let positive = (0..n).filter(|&c| fleet.data_len(c) > 0).count();
            let k = k.min(positive);
            let mut picked = Vec::with_capacity(k);
            let mut seen = vec![false; n];
            while picked.len() < k {
                let x = rng.next_f64() * total;
                let i = cum.partition_point(|&c| c <= x).min(n - 1);
                if !seen[i] {
                    seen[i] = true;
                    picked.push(i);
                }
            }
            picked
        }
    }

    #[test]
    fn incremental_sampler_is_bit_identical_to_reference_at_every_size() {
        // the ISSUE 6 equivalence pin: for identical seeds the Fenwick /
        // bitset / sparse-FY sampler must emit exactly the cohorts of the
        // historical O(fleet) scan, at every fleet size and sampler kind
        for n in [1usize, 2, 7, 64, 65, 1_000, 50_000, 200_000] {
            let mut f = Fleet::synthetic_pool(n, 7);
            f.set_data_lens((0..n).map(|i| (i % 13) + usize::from(i % 31 == 0) * 50));
            // churn some availability structure in
            for c in (0..n).step_by(3) {
                f.set_available(c, false);
            }
            for kind in [
                SamplerKind::Uniform,
                SamplerKind::WeightedByData,
                SamplerKind::AvailabilityAware,
            ] {
                for (seed, k) in [(1u64, 1usize), (9, 17), (42, 256), (7, n / 2 + 1)] {
                    let fast = f.sample(kind, k, &mut Pcg32::new(seed, 5));
                    let slow = reference::sample_cohort_ref(
                        &f,
                        kind,
                        k,
                        &mut Pcg32::new(seed, 5),
                    );
                    assert_eq!(
                        fast,
                        slow,
                        "n={n} kind={} k={k} seed={seed}",
                        kind.name()
                    );
                }
            }
        }
    }

    #[test]
    fn incremental_sampler_tracks_weight_and_availability_deltas() {
        // equivalence must survive incremental updates, not just builds
        let n = 5_000;
        let mut f = small_fleet(n);
        let mut rng = Pcg32::new(3, 3);
        for round in 0..20 {
            // drift some weights and availability, as churn would
            for _ in 0..50 {
                let c = rng.below_usize(n);
                f.set_data_len(c, rng.below_usize(40));
                let c = rng.below_usize(n);
                f.set_available(c, rng.next_f64() < 0.8);
            }
            for kind in [
                SamplerKind::Uniform,
                SamplerKind::WeightedByData,
                SamplerKind::AvailabilityAware,
            ] {
                let seed = 1000 + round;
                let fast = f.sample(kind, 64, &mut Pcg32::new(seed, 2));
                let slow = reference::sample_cohort_ref(
                    &f,
                    kind,
                    64,
                    &mut Pcg32::new(seed, 2),
                );
                assert_eq!(fast, slow, "round={round} kind={}", kind.name());
            }
        }
    }

    #[test]
    fn classic_mobile_matches_legacy_profiles() {
        let f = Fleet::classic(8, true, 0);
        assert_eq!(f.devices.len(), 5);
        assert_eq!(f.len(), 8);
        // client i gets the profile the legacy loop assigned (base[i % 5])
        let base = mobile_fleet();
        for i in 0..8 {
            assert_eq!(f.profile(i).name, base[i % 5].name);
        }
        // the Pixel 3 (index 4) is the natural straggler; ties break to
        // the last maximal client like the legacy max_by scan
        assert_eq!(f.slowest("cifar_vgg9") % 5, 4);
    }

    #[test]
    fn slowest_matches_the_legacy_per_client_scan() {
        for (n, mobile, seed) in
            [(8usize, true, 0u64), (12, false, 3), (100, true, 1), (striped(), true, 9)]
        {
            let f = Fleet::classic(n, mobile, seed);
            for model in ["cifar_vgg9", "femnist_cnn"] {
                let legacy = (0..f.len())
                    .max_by(|&a, &b| {
                        f.profile(a)
                            .base_time(model)
                            .total_cmp(&f.profile(b).base_time(model))
                    })
                    .unwrap_or(0);
                assert_eq!(
                    f.slowest(model),
                    legacy,
                    "n={n} mobile={mobile} model={model}"
                );
            }
        }
        // pooled fleet: ties across pool cycles resolve to the last client
        let f = Fleet::synthetic_pool(10_000, 3);
        let model = "cifar_vgg9";
        let legacy = (0..f.len())
            .max_by(|&a, &b| {
                f.profile(a).base_time(model).total_cmp(&f.profile(b).base_time(model))
            })
            .unwrap_or(0);
        assert_eq!(f.slowest(model), legacy);
    }

    fn striped() -> usize {
        7 // n < device pool size exercises the unreachable-device edge
    }

    #[test]
    fn classic_synthetic_is_one_profile_per_client() {
        let f = Fleet::classic(12, false, 99);
        assert_eq!(f.devices.len(), 12);
        let legacy = synthetic_fleet(12, 99);
        for i in 0..12 {
            assert_eq!(f.profile(i).base_cifar, legacy[i].base_cifar);
        }
    }

    #[test]
    fn pool_caps_device_table() {
        let f = Fleet::synthetic_pool(10_000, 3);
        assert_eq!(f.len(), 10_000);
        assert!(f.devices.len() <= DEVICE_POOL_CAP);
        assert_eq!(f.num_available(), 10_000);
        assert_eq!(f.device_map().len(), 10_000);
        let d = f.descriptor(4097);
        assert_eq!(d.id, 4097);
        assert_eq!(d.device, 4097 % f.devices.len());
        assert_eq!(d.shard, 4097);
        assert!(d.available);
    }

    #[test]
    fn uniform_sampling_is_distinct_and_in_range() {
        let mut f = small_fleet(100);
        let mut rng = Pcg32::new(1, 1);
        let s = sample_cohort(&mut f, SamplerKind::Uniform, 30, &mut rng);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
        assert!(t.iter().all(|&c| c < 100));
    }

    #[test]
    fn availability_aware_never_selects_churned_clients() {
        let mut f = small_fleet(50);
        for c in 0..25 {
            f.set_available(c * 2, false); // every even client churns out
        }
        let mut rng = Pcg32::new(2, 2);
        for _ in 0..200 {
            let s = sample_cohort(&mut f, SamplerKind::AvailabilityAware, 10, &mut rng);
            for &c in &s {
                assert!(f.is_available(c), "sampled churned-out client {c}");
            }
        }
        // cohort shrinks gracefully when availability is scarce
        for c in 0..50 {
            f.set_available(c, c == 7);
        }
        let s = sample_cohort(&mut f, SamplerKind::AvailabilityAware, 10, &mut rng);
        assert_eq!(s, vec![7]);
    }

    #[test]
    fn weighted_sampling_prefers_big_shards() {
        let mut f = small_fleet(40);
        for c in 0..40 {
            f.set_data_len(c, if c < 4 { 1000 } else { 1 });
        }
        let mut rng = Pcg32::new(3, 3);
        let mut heavy = 0usize;
        let rounds = 500;
        for _ in 0..rounds {
            let s = sample_cohort(&mut f, SamplerKind::WeightedByData, 2, &mut rng);
            assert_eq!(s.len(), 2);
            heavy += s.iter().filter(|&&c| c < 4).count();
        }
        // heavy shards own >99% of the mass; they must dominate selection
        assert!(heavy > rounds, "heavy clients picked only {heavy} times");
    }

    #[test]
    fn weighted_handles_degenerate_weights_and_full_draws() {
        let mut f = small_fleet(6);
        for c in 0..6 {
            f.set_data_len(c, 0);
        }
        let mut rng = Pcg32::new(4, 4);
        let s = sample_cohort(&mut f, SamplerKind::WeightedByData, 3, &mut rng);
        assert_eq!(s.len(), 3);
        let all = sample_cohort(&mut f, SamplerKind::WeightedByData, 6, &mut rng);
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
        // fewer positive-weight clients than requested: the cohort clamps
        // to the positive population instead of spinning forever
        f.set_data_len(1, 5);
        f.set_data_len(4, 9);
        let mut two = sample_cohort(&mut f, SamplerKind::WeightedByData, 4, &mut rng);
        two.sort_unstable();
        assert_eq!(two, vec![1, 4]);
    }

    #[test]
    fn uniform_sampler_frequency_is_unbiased() {
        // Over 1k sampled rounds every client's selection count must sit
        // near rounds*k/n. Seeded, so deterministic — the bounds are a
        // per-client 5σ hard cap, an "at most a few beyond 3σ" check
        // (the 3σ band holds in aggregate: expected excursions ≈ 0.5),
        // and a chi-squared smoke bound; a biased sampler (off-by-one
        // range, missing Fisher–Yates swap) blows all three.
        let mut f = small_fleet(200);
        let (rounds, k, n) = (1000usize, 20usize, 200usize);
        let mut rng = Pcg32::new(0x57A7, 1);
        let mut count = vec![0usize; n];
        for _ in 0..rounds {
            for &c in &sample_cohort(&mut f, SamplerKind::Uniform, k, &mut rng) {
                count[c] += 1;
            }
        }
        let p = k as f64 / n as f64;
        let mean = rounds as f64 * p;
        let sigma = (rounds as f64 * p * (1.0 - p)).sqrt();
        let mut beyond_3s = 0usize;
        let mut chi2 = 0.0f64;
        for (c, &obs) in count.iter().enumerate() {
            let dev = (obs as f64 - mean).abs();
            assert!(dev <= 5.0 * sigma, "client {c}: {obs} vs mean {mean:.1}");
            if dev > 3.0 * sigma {
                beyond_3s += 1;
            }
            chi2 += (obs as f64 - mean).powi(2) / (sigma * sigma);
        }
        assert!(beyond_3s <= 4, "{beyond_3s} clients beyond 3σ of k/N");
        // chi² over n cells: mean ≈ n (slightly below, without-replacement
        // rounds are negatively correlated), σ ≈ sqrt(2n) ≈ 20
        assert!(chi2 < 320.0, "chi-squared {chi2:.1} too large for {n} cells");
        assert!(chi2 > 80.0, "chi-squared {chi2:.1} implausibly small");
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let mut f = small_fleet(300);
        for kind in [
            SamplerKind::Uniform,
            SamplerKind::WeightedByData,
            SamplerKind::AvailabilityAware,
        ] {
            let a = sample_cohort(&mut f, kind, 32, &mut Pcg32::new(9, 5));
            let b = sample_cohort(&mut f, kind, 32, &mut Pcg32::new(9, 5));
            assert_eq!(a, b, "{}", kind.name());
        }
    }

    #[test]
    fn sampler_kind_parse_round_trips() {
        for kind in [
            SamplerKind::Uniform,
            SamplerKind::WeightedByData,
            SamplerKind::AvailabilityAware,
        ] {
            assert_eq!(SamplerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SamplerKind::parse("bogus"), None);
        assert_eq!(SamplerKind::default(), SamplerKind::Uniform);
    }
}
