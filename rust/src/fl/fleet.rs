//! Fleet-scale client population: lightweight descriptors + per-round
//! cohort sampling.
//!
//! The classic engine path materializes every client (device profile +
//! data shard) up front — fine for 5 phones, impossible for the ROADMAP
//! regime of 10k–100k simulated clients. A [`Fleet`] instead holds one
//! small [`ClientDescriptor`] per client (device index, shard id, shard
//! size, availability) and a shared pool of [`DeviceProfile`]s; shard
//! *data* only exists for the sampled cohort each round (lazy hydration,
//! see [`crate::data::ShardSource`]).
//!
//! [`SamplerKind`] + [`sample_cohort`] are the per-round client sampler:
//! uniform (the A.6 protocol at population scale), weighted-by-data
//! (clients with more examples participate proportionally more, the
//! production-FL default), and availability-aware (never selects a
//! churned-out client — pair with `engine::scenario` churn scripts).

use crate::straggler::{mobile_fleet, synthetic_fleet, DeviceProfile};
use crate::util::prng::Pcg32;

/// Upper bound on distinct synthetic device profiles held by a fleet —
/// beyond this, clients cycle through the pool (profiles are ~100 bytes
/// each; the pool keeps a 100k fleet's device table at a few hundred KB
/// while preserving the lognormal speed spread).
pub const DEVICE_POOL_CAP: usize = 2048;

/// One client, described without materializing its data.
#[derive(Clone, Debug)]
pub struct ClientDescriptor {
    pub id: usize,
    /// index into [`Fleet::devices`]
    pub device: usize,
    /// shard id for lazy hydration (== id for the built-in partitions)
    pub shard: usize,
    /// examples in the shard — known without hydrating it
    pub data_len: usize,
    /// availability state, driven by scenario churn scripts
    pub available: bool,
}

/// A client population: shared device pool + per-client descriptors.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub devices: Vec<DeviceProfile>,
    pub clients: Vec<ClientDescriptor>,
}

impl Fleet {
    fn from_devices(devices: Vec<DeviceProfile>, n: usize) -> Fleet {
        let d = devices.len().max(1);
        let clients = (0..n)
            .map(|i| ClientDescriptor {
                id: i,
                device: i % d,
                shard: i,
                data_len: 0,
                available: true,
            })
            .collect();
        Fleet { devices, clients }
    }

    /// The classic (pre-fleet) device assignment, preserved bit-for-bit:
    /// mobile fleets cycle the five Table-1 phones; synthetic fleets give
    /// every client its own lognormal profile.
    pub fn classic(n: usize, mobile: bool, device_seed: u64) -> Fleet {
        if mobile {
            Fleet::from_devices(mobile_fleet(), n)
        } else {
            Fleet::from_devices(synthetic_fleet(n, device_seed), n)
        }
    }

    /// Fleet-scale population: a capped pool of synthetic profiles cycled
    /// across `n` descriptors.
    pub fn synthetic_pool(n: usize, device_seed: u64) -> Fleet {
        Fleet::from_devices(
            synthetic_fleet(n.min(DEVICE_POOL_CAP).max(1), device_seed),
            n,
        )
    }

    pub fn len(&self) -> usize {
        self.clients.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clients.is_empty()
    }

    pub fn device_of(&self, c: usize) -> usize {
        self.clients[c].device
    }

    pub fn profile(&self, c: usize) -> &DeviceProfile {
        &self.devices[self.clients[c].device]
    }

    pub fn is_available(&self, c: usize) -> bool {
        self.clients[c].available
    }

    pub fn set_available(&mut self, c: usize, v: bool) {
        self.clients[c].available = v;
    }

    pub fn num_available(&self) -> usize {
        self.clients.iter().filter(|d| d.available).count()
    }

    /// Client -> device index table (what `EventScheduler::arrivals`
    /// consumes).
    pub fn device_map(&self) -> Vec<usize> {
        self.clients.iter().map(|d| d.device).collect()
    }

    /// The slowest client on `model` — same tie-breaking as the historic
    /// `max_by` scan (last maximum wins; total_cmp agrees with the old
    /// partial order on the finite base times and cannot panic).
    pub fn slowest(&self, model: &str) -> usize {
        (0..self.clients.len())
            .max_by(|&a, &b| {
                self.profile(a)
                    .base_time(model)
                    .total_cmp(&self.profile(b).base_time(model))
            })
            .unwrap_or(0)
    }
}

/// Per-round client-sampling policy over a [`Fleet`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SamplerKind {
    /// uniform over the whole population (churned-out clients may be
    /// selected but will not participate)
    #[default]
    Uniform,
    /// probability proportional to shard size (production-FL default)
    WeightedByData,
    /// uniform over currently-available clients only
    AvailabilityAware,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Option<SamplerKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "uniform" => SamplerKind::Uniform,
            "weighted" | "weighted-by-data" => SamplerKind::WeightedByData,
            "available" | "availability" | "availability-aware" => {
                SamplerKind::AvailabilityAware
            }
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Uniform => "uniform",
            SamplerKind::WeightedByData => "weighted",
            SamplerKind::AvailabilityAware => "available",
        }
    }
}

/// Sample a round's cohort of (at most) `k` distinct clients. The result
/// is in sampler-draw order; callers sort if they need id order.
pub fn sample_cohort(
    fleet: &Fleet,
    kind: SamplerKind,
    k: usize,
    rng: &mut Pcg32,
) -> Vec<usize> {
    let n = fleet.len();
    if n == 0 || k == 0 {
        return Vec::new();
    }
    match kind {
        SamplerKind::Uniform => rng.sample_indices(n, k.min(n)),
        SamplerKind::WeightedByData => sample_weighted(fleet, k.min(n), rng),
        SamplerKind::AvailabilityAware => {
            let avail: Vec<usize> = fleet
                .clients
                .iter()
                .filter(|d| d.available)
                .map(|d| d.id)
                .collect();
            if avail.is_empty() {
                return Vec::new();
            }
            let k = k.min(avail.len());
            rng.sample_indices(avail.len(), k)
                .into_iter()
                .map(|i| avail[i])
                .collect()
        }
    }
}

/// Weighted-without-replacement via cumulative-weight inversion with
/// rejection of duplicates — exact marginals at the first draw, a close
/// approximation for k << n (the fleet regime). Zero-weight populations
/// fall back to uniform.
fn sample_weighted(fleet: &Fleet, k: usize, rng: &mut Pcg32) -> Vec<usize> {
    let n = fleet.len();
    if k >= n {
        return (0..n).collect();
    }
    let mut cum = Vec::with_capacity(n);
    let mut total = 0.0f64;
    for d in &fleet.clients {
        total += d.data_len as f64;
        cum.push(total);
    }
    if total <= 0.0 {
        return rng.sample_indices(n, k);
    }
    // inversion can only ever land on positive-weight clients (zero-weight
    // plateaus are unreachable), so clamp k to that population or the
    // rejection loop below would never terminate
    let positive = fleet.clients.iter().filter(|d| d.data_len > 0).count();
    let k = k.min(positive);
    let mut picked = Vec::with_capacity(k);
    let mut seen = vec![false; n];
    while picked.len() < k {
        let x = rng.next_f64() * total;
        let i = cum.partition_point(|&c| c <= x).min(n - 1);
        if !seen[i] {
            seen[i] = true;
            picked.push(i);
        }
    }
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_fleet(n: usize) -> Fleet {
        let mut f = Fleet::synthetic_pool(n, 7);
        for (i, d) in f.clients.iter_mut().enumerate() {
            d.data_len = 10 + (i % 5) * 10;
        }
        f
    }

    #[test]
    fn classic_mobile_matches_legacy_profiles() {
        let f = Fleet::classic(8, true, 0);
        assert_eq!(f.devices.len(), 5);
        assert_eq!(f.len(), 8);
        // client i gets the profile the legacy loop assigned (base[i % 5])
        let base = mobile_fleet();
        for i in 0..8 {
            assert_eq!(f.profile(i).name, base[i % 5].name);
        }
        // the Pixel 3 (index 4) is the natural straggler; ties break to
        // the last maximal client like the legacy max_by scan
        assert_eq!(f.slowest("cifar_vgg9") % 5, 4);
    }

    #[test]
    fn classic_synthetic_is_one_profile_per_client() {
        let f = Fleet::classic(12, false, 99);
        assert_eq!(f.devices.len(), 12);
        let legacy = synthetic_fleet(12, 99);
        for i in 0..12 {
            assert_eq!(f.profile(i).base_cifar, legacy[i].base_cifar);
        }
    }

    #[test]
    fn pool_caps_device_table() {
        let f = Fleet::synthetic_pool(10_000, 3);
        assert_eq!(f.len(), 10_000);
        assert!(f.devices.len() <= DEVICE_POOL_CAP);
        assert_eq!(f.num_available(), 10_000);
        assert_eq!(f.device_map().len(), 10_000);
    }

    #[test]
    fn uniform_sampling_is_distinct_and_in_range() {
        let f = small_fleet(100);
        let mut rng = Pcg32::new(1, 1);
        let s = sample_cohort(&f, SamplerKind::Uniform, 30, &mut rng);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 30);
        assert!(t.iter().all(|&c| c < 100));
    }

    #[test]
    fn availability_aware_never_selects_churned_clients() {
        let mut f = small_fleet(50);
        for c in 0..25 {
            f.set_available(c * 2, false); // every even client churns out
        }
        let mut rng = Pcg32::new(2, 2);
        for _ in 0..200 {
            for &c in &sample_cohort(&f, SamplerKind::AvailabilityAware, 10, &mut rng) {
                assert!(f.is_available(c), "sampled churned-out client {c}");
            }
        }
        // cohort shrinks gracefully when availability is scarce
        for c in 0..50 {
            f.set_available(c, c == 7);
        }
        let s = sample_cohort(&f, SamplerKind::AvailabilityAware, 10, &mut rng);
        assert_eq!(s, vec![7]);
    }

    #[test]
    fn weighted_sampling_prefers_big_shards() {
        let mut f = small_fleet(40);
        for d in f.clients.iter_mut() {
            d.data_len = if d.id < 4 { 1000 } else { 1 };
        }
        let mut rng = Pcg32::new(3, 3);
        let mut heavy = 0usize;
        let rounds = 500;
        for _ in 0..rounds {
            let s = sample_cohort(&f, SamplerKind::WeightedByData, 2, &mut rng);
            assert_eq!(s.len(), 2);
            heavy += s.iter().filter(|&&c| c < 4).count();
        }
        // heavy shards own >99% of the mass; they must dominate selection
        assert!(heavy > rounds, "heavy clients picked only {heavy} times");
    }

    #[test]
    fn weighted_handles_degenerate_weights_and_full_draws() {
        let mut f = small_fleet(6);
        for d in f.clients.iter_mut() {
            d.data_len = 0;
        }
        let mut rng = Pcg32::new(4, 4);
        let s = sample_cohort(&f, SamplerKind::WeightedByData, 3, &mut rng);
        assert_eq!(s.len(), 3);
        let all = sample_cohort(&f, SamplerKind::WeightedByData, 6, &mut rng);
        assert_eq!(all, vec![0, 1, 2, 3, 4, 5]);
        // fewer positive-weight clients than requested: the cohort clamps
        // to the positive population instead of spinning forever
        f.clients[1].data_len = 5;
        f.clients[4].data_len = 9;
        let mut two = sample_cohort(&f, SamplerKind::WeightedByData, 4, &mut rng);
        two.sort_unstable();
        assert_eq!(two, vec![1, 4]);
    }

    #[test]
    fn uniform_sampler_frequency_is_unbiased() {
        // Over 1k sampled rounds every client's selection count must sit
        // near rounds*k/n. Seeded, so deterministic — the bounds are a
        // per-client 5σ hard cap, an "at most a few beyond 3σ" check
        // (the 3σ band holds in aggregate: expected excursions ≈ 0.5),
        // and a chi-squared smoke bound; a biased sampler (off-by-one
        // range, missing Fisher–Yates swap) blows all three.
        let f = small_fleet(200);
        let (rounds, k, n) = (1000usize, 20usize, 200usize);
        let mut rng = Pcg32::new(0x57A7, 1);
        let mut count = vec![0usize; n];
        for _ in 0..rounds {
            for &c in &sample_cohort(&f, SamplerKind::Uniform, k, &mut rng) {
                count[c] += 1;
            }
        }
        let p = k as f64 / n as f64;
        let mean = rounds as f64 * p;
        let sigma = (rounds as f64 * p * (1.0 - p)).sqrt();
        let mut beyond_3s = 0usize;
        let mut chi2 = 0.0f64;
        for (c, &obs) in count.iter().enumerate() {
            let dev = (obs as f64 - mean).abs();
            assert!(dev <= 5.0 * sigma, "client {c}: {obs} vs mean {mean:.1}");
            if dev > 3.0 * sigma {
                beyond_3s += 1;
            }
            chi2 += (obs as f64 - mean).powi(2) / (sigma * sigma);
        }
        assert!(beyond_3s <= 4, "{beyond_3s} clients beyond 3σ of k/N");
        // chi² over n cells: mean ≈ n (slightly below, without-replacement
        // rounds are negatively correlated), σ ≈ sqrt(2n) ≈ 20
        assert!(chi2 < 320.0, "chi-squared {chi2:.1} too large for {n} cells");
        assert!(chi2 > 80.0, "chi-squared {chi2:.1} implausibly small");
    }

    #[test]
    fn sampling_is_deterministic_given_seed() {
        let f = small_fleet(300);
        for kind in [
            SamplerKind::Uniform,
            SamplerKind::WeightedByData,
            SamplerKind::AvailabilityAware,
        ] {
            let a = sample_cohort(&f, kind, 32, &mut Pcg32::new(9, 5));
            let b = sample_cohort(&f, kind, 32, &mut Pcg32::new(9, 5));
            assert_eq!(a, b, "{}", kind.name());
        }
    }

    #[test]
    fn sampler_kind_parse_round_trips() {
        for kind in [
            SamplerKind::Uniform,
            SamplerKind::WeightedByData,
            SamplerKind::AvailabilityAware,
        ] {
            assert_eq!(SamplerKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(SamplerKind::parse("bogus"), None);
        assert_eq!(SamplerKind::default(), SamplerKind::Uniform);
    }
}
