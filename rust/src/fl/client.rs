//! Simulated FL client: local data + local SGD epochs through the AOT
//! train step. Virtual *timing* is not computed here — the coordinator
//! asks the [`crate::straggler::PerfModel`] for it — this is the pure
//! learning mechanics.

use crate::data::Split;
use crate::runtime::{StepRunner, TrainOut};
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;

/// One client and its local shard.
pub struct Client {
    pub id: usize,
    /// index into the device fleet
    pub device: usize,
    pub data: Split,
}

/// Outcome of a local training pass.
#[derive(Clone, Debug)]
pub struct LocalResult {
    pub params: Vec<Tensor>,
    pub mean_loss: f64,
    pub mean_acc: f64,
    pub steps: usize,
    /// examples used (FedAvg weight)
    pub weight: f64,
}

impl Client {
    pub fn new(id: usize, device: usize, data: Split) -> Self {
        Self { id, device, data }
    }

    /// Run `steps` local SGD steps starting from the broadcast `params`,
    /// under this client's sub-model `masks`.
    ///
    /// `use_fused` selects the fused k-step artifact when `steps` matches
    /// its k. §Perf verdict: a win for the LSTM (~3%), a large LOSS for
    /// the CNNs on CPU-XLA (the scan carry copies all parameters every
    /// step and defeats inter-op parallelism), so it is opt-in via
    /// `ExperimentConfig::use_fused_steps` — measured in
    /// `results/bench_hotpath_after.txt` and EXPERIMENTS.md §Perf.
    pub fn local_train(
        &self,
        runner: &StepRunner,
        params: &[Tensor],
        masks: &[Tensor],
        steps: usize,
        lr: f32,
        round_seed: u64,
        use_fused: bool,
    ) -> crate::Result<LocalResult> {
        let mut rng = Pcg32::new(round_seed ^ (self.id as u64) << 20, 0xC11E17);

        if use_fused && steps > 0 && steps == runner.multi_k() {
            let batches: Vec<_> = (0..steps)
                .map(|_| self.data.sample_batch(&mut rng, &runner.spec.x_shape))
                .collect();
            let out = runner.train_multi_step(params, masks, &batches, lr)?;
            return Ok(LocalResult {
                params: out.params,
                mean_loss: out.loss as f64,
                mean_acc: out.acc as f64,
                steps,
                weight: self.data.len() as f64,
            });
        }

        let mut cur: Vec<Tensor> = params.to_vec();
        let mut loss_acc = 0.0f64;
        let mut acc_acc = 0.0f64;
        for _ in 0..steps {
            let batch = self.data.sample_batch(&mut rng, &runner.spec.x_shape);
            let TrainOut { params: p, loss, acc } =
                runner.train_step(&cur, masks, &batch, lr)?;
            cur = p;
            loss_acc += loss as f64;
            acc_acc += acc as f64;
        }
        let denom = steps.max(1) as f64;
        Ok(LocalResult {
            params: cur,
            mean_loss: loss_acc / denom,
            mean_acc: acc_acc / denom,
            steps,
            weight: self.data.len() as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Split, XStore};

    #[test]
    fn construction() {
        let c = Client::new(
            3,
            1,
            Split {
                xs: XStore::F32(vec![0.0; 8]),
                ys: vec![0, 1],
                feature_len: 4,
            },
        );
        assert_eq!(c.id, 3);
        assert_eq!(c.device, 1);
        assert_eq!(c.data.len(), 2);
    }
    // local_train against real artifacts: rust/tests/integration_fluid.rs
}
