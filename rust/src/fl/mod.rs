//! Federated-learning core: clients, masked FedAvg aggregation, and
//! evaluation helpers. The round *policy* (straggler handling, threshold
//! calibration) lives in [`crate::coordinator`]; this module is the
//! mechanics underneath it.

pub mod aggregate;
pub mod client;

pub use aggregate::{fedavg, AggregateMode, ClientUpdate};
pub use client::{Client, LocalResult};

use crate::data::Split;
use crate::runtime::StepRunner;
use crate::tensor::Tensor;

/// Evaluate `params` over an entire split in manifest-sized batches.
/// Returns (mean loss, accuracy). The tail partial batch is padded by
/// wrapping (its duplicated examples are excluded from the counts).
pub fn evaluate_split(
    runner: &StepRunner,
    params: &[Tensor],
    masks: &[Tensor],
    split: &Split,
) -> crate::Result<(f64, f64)> {
    let bs = runner.spec.batch_size;
    let n = split.len();
    if n == 0 {
        return Ok((0.0, 0.0));
    }
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    let mut counted = 0usize;
    let mut start = 0usize;
    while start < n {
        let idx: Vec<usize> = (0..bs).map(|k| (start + k) % n).collect();
        let real = bs.min(n - start);
        let batch = split.batch(&idx, &runner.spec.x_shape);
        let out = runner.eval_step(params, masks, &batch)?;
        // eval_step returns batch-mean loss and total correct; when the
        // tail wraps we can only use whole-batch numbers, so scale by the
        // real fraction (wrapped duplicates bias is negligible for the
        // test splits we use, and exact for full batches)
        let frac = real as f64 / bs as f64;
        loss_sum += out.loss as f64 * real as f64;
        correct += out.correct as f64 * frac;
        counted += real;
        start += bs;
    }
    Ok((loss_sum / counted as f64, correct / counted as f64))
}

#[cfg(test)]
mod tests {
    // evaluate_split is exercised against real artifacts in
    // rust/tests/integration_fluid.rs; unit tests for the pure pieces
    // live in aggregate.rs / client.rs.
}
