//! Federated-learning core: clients, masked FedAvg aggregation, and
//! evaluation helpers. The round *policy* (straggler handling, threshold
//! calibration) lives in [`crate::coordinator`]; this module is the
//! mechanics underneath it.

pub mod aggregate;
pub mod client;
pub mod codec;
pub mod fleet;
pub mod parallel;
pub mod sampling;

pub use aggregate::{
    fedavg, fedavg_into, policy_weight, staleness_discount, AggregateMode, ClientUpdate,
};
pub use client::{Client, LocalResult};
pub use codec::{
    pack_result, pack_sparse, unpack, unpack_result, Codec, Compression, DeltaPayload,
    PackedResult, QuantUpdate, SparseUpdate, UpdateCodec,
};
pub use fleet::{sample_cohort, ClientDescriptor, Fleet, SamplerKind};
pub use sampling::CohortSampler;
pub use parallel::AggScratch;

use crate::data::Split;
use crate::runtime::{EvalOut, StepRunner};
use crate::tensor::Tensor;

/// Accumulates per-batch eval outputs under one *exact-fraction*
/// convention: a wrapped tail batch with `real` genuine examples out of
/// `bs` contributes exactly `frac = real/bs` of its whole-batch totals —
/// for the loss **and** for the correct count alike.
///
/// `eval_step` returns the whole-batch *mean* loss and the whole-batch
/// *total* correct count, so the two need different scale factors to land
/// on the same convention: `mean·real ≡ total·frac` for the loss, and
/// `total·frac` directly for correctness. Full batches have `frac = 1`
/// and are exact; on wrapped batches the duplicated head examples are
/// proportionally excluded rather than double-counted.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalAccum {
    loss_sum: f64,
    correct: f64,
    counted: usize,
}

impl EvalAccum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one batch result in; `real` is the number of non-duplicated
    /// examples in this batch (`real == bs` for all but the tail).
    pub fn push(&mut self, out: EvalOut, real: usize, bs: usize) {
        assert!(real > 0 && real <= bs, "real {real} out of range for bs {bs}");
        let frac = real as f64 / bs as f64;
        // whole-batch loss total is out.loss * bs; times frac == loss * real
        self.loss_sum += out.loss as f64 * real as f64;
        self.correct += out.correct as f64 * frac;
        self.counted += real;
    }

    pub fn counted(&self) -> usize {
        self.counted
    }

    /// (mean loss per example, accuracy).
    pub fn finish(&self) -> (f64, f64) {
        if self.counted == 0 {
            (0.0, 0.0)
        } else {
            (
                self.loss_sum / self.counted as f64,
                self.correct / self.counted as f64,
            )
        }
    }
}

/// Evaluate `params` over an entire split in manifest-sized batches.
/// Returns (mean loss, accuracy). The tail partial batch is padded by
/// wrapping; [`EvalAccum`] excludes the duplicated examples from both
/// counts under the exact-fraction convention.
pub fn evaluate_split(
    runner: &StepRunner,
    params: &[Tensor],
    masks: &[Tensor],
    split: &Split,
) -> crate::Result<(f64, f64)> {
    let bs = runner.spec.batch_size;
    let n = split.len();
    if n == 0 {
        return Ok((0.0, 0.0));
    }
    let mut acc = EvalAccum::new();
    let mut start = 0usize;
    while start < n {
        let idx: Vec<usize> = (0..bs).map(|k| (start + k) % n).collect();
        let real = bs.min(n - start);
        let batch = split.batch(&idx, &runner.spec.x_shape);
        let out = runner.eval_step(params, masks, &batch)?;
        acc.push(out, real, bs);
        start += bs;
    }
    debug_assert_eq!(acc.counted(), n);
    Ok(acc.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    // evaluate_split is exercised against real artifacts in
    // rust/tests/integration_fluid.rs; the accumulator's tail-batch
    // accounting is pure and tested here.

    #[test]
    fn tail_batch_accounting_is_exact() {
        // n = 5, bs = 2 -> batches of real = [2, 2, 1]; the tail wraps one
        // duplicate. Per-example loss is L everywhere and every prediction
        // is correct, so the exact answer is (L, 1.0) regardless of the
        // wrap — any convention mismatch between loss and correct scaling
        // breaks one of the two.
        let l = 0.75f32;
        let mut acc = EvalAccum::new();
        for real in [2usize, 2, 1] {
            let out = EvalOut {
                loss: l,               // whole-batch mean
                correct: 2.0,          // whole-batch total (bs = 2)
            };
            acc.push(out, real, 2);
        }
        assert_eq!(acc.counted(), 5);
        let (loss, acc_frac) = acc.finish();
        assert!((loss - l as f64).abs() < 1e-12, "loss {loss}");
        assert!((acc_frac - 1.0).abs() < 1e-12, "acc {acc_frac}");
    }

    #[test]
    fn wrapped_duplicates_are_proportionally_excluded() {
        // one batch, bs = 4, real = 1: whole-batch total correct of 2
        // contributes 2 * 1/4 = 0.5 of one counted example.
        let mut acc = EvalAccum::new();
        acc.push(EvalOut { loss: 1.0, correct: 2.0 }, 1, 4);
        let (loss, a) = acc.finish();
        assert!((loss - 1.0).abs() < 1e-12);
        assert!((a - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_accum_is_zero() {
        assert_eq!(EvalAccum::new().finish(), (0.0, 0.0));
    }
}
