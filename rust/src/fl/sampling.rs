//! Incremental cohort sampling: O(cohort + churn-delta) per round.
//!
//! The historical `sample_cohort` paid O(fleet) every round — a full
//! `(0..n)` index vector for uniform draws, a fresh cumulative-weight
//! vector plus a `vec![false; n]` duplicate bitmap for weighted draws,
//! and an O(fleet) collect of the available set for availability-aware
//! draws. [`CohortSampler`] keeps the population in incrementally
//! maintained structures instead:
//!
//! * weights live in a [`Fenwick`] tree, updated in O(log n) when a
//!   shard size changes and searched in O(log n) per draw;
//! * availability lives in a [`RankSelectBitset`], flipped in
//!   O(log words) per churn delta and selected in O(log words) per draw;
//! * uniform draws run a **sparse** Fisher–Yates over a reusable hash
//!   map, touching only the k drawn positions.
//!
//! Every draw reproduces the historical sampler's PRNG consumption and
//! output **bit for bit** (pinned by the cross-implementation
//! equivalence test in `fl::fleet`): the sparse Fisher–Yates performs
//! the same `below_usize(n - i)` sequence as `Pcg32::sample_indices`,
//! the Fenwick descent reproduces `partition_point` over the old
//! cumulative vector exactly (integer weights, sums below 2^53), and
//! `select1(i)` equals `avail[i]` of the old ascending collect.
//!
//! All scratch (hash map, duplicate set, output buffers) is hoisted into
//! the sampler and reused across rounds — at steady state a draw
//! allocates nothing but the returned cohort `Vec` (gated by
//! `tests/alloc_gate.rs`).

use crate::util::fenwick::{Fenwick, RankSelectBitset};
use crate::util::prng::Pcg32;
use std::collections::{HashMap, HashSet};

/// Draw budget multiplier for the weighted rejection loop: after
/// `WEIGHTED_RETRY_FACTOR * k + WEIGHTED_RETRY_SLACK` inversion draws the
/// sampler abandons rejection and falls back to a deterministic exact
/// sweep. In the fleet regime (k << positive population) the expected
/// draw count is barely above k, so the budget never binds and draws stay
/// bit-identical to the historical unbounded loop; in pathological
/// regimes (k ≈ positive population, where the coupon-collector tail
/// makes the old loop arbitrarily slow) the fallback bounds the round.
pub const WEIGHTED_RETRY_FACTOR: usize = 16;
pub const WEIGHTED_RETRY_SLACK: usize = 256;

/// Incrementally-maintained sampling state for one client population.
#[derive(Clone, Debug)]
pub struct CohortSampler {
    n: usize,
    /// per-client integer weights (shard sizes)
    weights: Fenwick,
    /// clients with weight > 0 (the weighted draw clamps k to this)
    positive: usize,
    /// availability bitmap with rank/select
    avail: RankSelectBitset,
    /// sparse Fisher–Yates displacement map (position -> displaced value)
    fy: HashMap<usize, usize>,
    /// duplicate-rejection set for weighted draws
    seen: HashSet<usize>,
    /// churn scratch: ids leaving / rejoining this round
    churn_out_ids: Vec<usize>,
    churn_in_ids: Vec<usize>,
}

impl CohortSampler {
    /// `n` clients, all available, all weight zero.
    pub fn new(n: usize) -> Self {
        Self {
            n,
            weights: Fenwick::new(n),
            positive: 0,
            avail: RankSelectBitset::new_filled(n, true),
            fy: HashMap::new(),
            seen: HashSet::new(),
            churn_out_ids: Vec::new(),
            churn_in_ids: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    // ---- weights ----------------------------------------------------------

    pub fn weight(&self, c: usize) -> u64 {
        self.weights.get(c)
    }

    pub fn set_weight(&mut self, c: usize, w: u64) {
        let old = self.weights.get(c);
        if old == 0 && w > 0 {
            self.positive += 1;
        } else if old > 0 && w == 0 {
            self.positive -= 1;
        }
        self.weights.set(c, w);
    }

    /// Bulk (re)install all weights — O(n), construction-time only.
    pub fn assign_weights(&mut self, ws: impl Iterator<Item = u64>) {
        let mut positive = 0usize;
        self.weights.assign(ws.inspect(|&w| {
            if w > 0 {
                positive += 1;
            }
        }));
        self.positive = positive;
    }

    pub fn total_weight(&self) -> u64 {
        self.weights.total()
    }

    pub fn positive_weight_count(&self) -> usize {
        self.positive
    }

    // ---- availability -----------------------------------------------------

    pub fn is_available(&self, c: usize) -> bool {
        self.avail.get(c)
    }

    pub fn set_available(&mut self, c: usize, v: bool) -> bool {
        self.avail.set(c, v)
    }

    pub fn num_available(&self) -> usize {
        self.avail.count_ones()
    }

    /// Bulk reinstall availability (snapshot restore) — O(n).
    pub fn assign_availability(&mut self, bits: &[bool]) {
        self.avail.assign_from(bits);
    }

    /// Materialize the availability map (snapshot capture) — O(n).
    pub fn availability(&self) -> Vec<bool> {
        (0..self.n).map(|i| self.avail.get(i)).collect()
    }

    // ---- draws ------------------------------------------------------------

    /// Uniform cohort over the whole population: bit-identical to
    /// `rng.sample_indices(n, k)` in O(k) via sparse Fisher–Yates.
    pub fn sample_uniform(&mut self, k: usize, rng: &mut Pcg32) -> Vec<usize> {
        let k = k.min(self.n);
        let mut out = Vec::with_capacity(k);
        self.sparse_fisher_yates(self.n, k, rng, |v| v, &mut out);
        out
    }

    /// Uniform cohort over currently-available clients: bit-identical to
    /// collecting the available ids ascending and uniform-sampling that
    /// vector, in O(k log n) — the collect never happens, `select1`
    /// resolves ranks to ids on demand.
    pub fn sample_available(&mut self, k: usize, rng: &mut Pcg32) -> Vec<usize> {
        let m = self.avail.count_ones();
        if m == 0 {
            return Vec::new();
        }
        let k = k.min(m);
        let mut out = Vec::with_capacity(k);
        // borrow dance: select1 needs &self.avail while the FY map is
        // &mut self.fy, so route through a local closure on the bitset
        let avail = &self.avail;
        let n = m;
        let fy = &mut self.fy;
        fy.clear();
        for i in 0..k {
            let j = i + rng.below_usize(n - i);
            let vj = fy.get(&j).copied().unwrap_or(j);
            let vi = fy.get(&i).copied().unwrap_or(i);
            fy.insert(j, vi);
            out.push(avail.select1(vj));
        }
        out
    }

    /// Weighted-without-replacement via cumulative-inversion with
    /// duplicate rejection — the historical algorithm, reproduced draw
    /// for draw through the Fenwick descent, with the O(n) per-round
    /// scratch (`cum`, `seen`) replaced by incremental state. Zero-weight
    /// populations fall back to uniform; `k >= n` returns everyone (both
    /// historical behaviors).
    ///
    /// The rejection loop is bounded: past the retry budget it falls
    /// back to [`Self::weighted_exact_sweep`].
    pub fn sample_weighted(&mut self, k: usize, rng: &mut Pcg32) -> Vec<usize> {
        let n = self.n;
        let k = k.min(n);
        if k >= n {
            return (0..n).collect();
        }
        let total = self.weights.total();
        if total == 0 {
            return self.sample_uniform(k, rng);
        }
        let total_f = total as f64;
        let k = k.min(self.positive);
        let budget = WEIGHTED_RETRY_FACTOR * k + WEIGHTED_RETRY_SLACK;
        let mut picked = Vec::with_capacity(k);
        self.seen.clear();
        let mut draws = 0usize;
        while picked.len() < k {
            if draws >= budget {
                self.weighted_exact_sweep(k, &mut picked);
                break;
            }
            draws += 1;
            let x = rng.next_f64() * total_f;
            let i = self.weights.count_prefix_le(x).min(n - 1);
            if self.seen.insert(i) {
                picked.push(i);
            }
        }
        picked
    }

    /// Deterministic completion of a weighted draw whose rejection loop
    /// exhausted its budget: scan ascending client ids and take every
    /// positive-weight client not already picked until the cohort is
    /// full. O(n), but only ever reached in the pathological
    /// k ≈ positive-population regime where the historical loop's
    /// coupon-collector tail was unbounded.
    fn weighted_exact_sweep(&mut self, k: usize, picked: &mut Vec<usize>) {
        for c in 0..self.n {
            if picked.len() >= k {
                break;
            }
            if self.weights.get(c) > 0 && !self.seen.contains(&c) {
                self.seen.insert(c);
                picked.push(c);
            }
        }
    }

    /// Sparse partial Fisher–Yates: performs exactly the PRNG draws of
    /// `Pcg32::sample_indices(n, k)` and emits the same outputs, but
    /// touches only the k drawn positions (reusable hash map holds the
    /// displacements; `clear()` retains capacity, so steady-state draws
    /// allocate nothing).
    fn sparse_fisher_yates(
        &mut self,
        n: usize,
        k: usize,
        rng: &mut Pcg32,
        map: impl Fn(usize) -> usize,
        out: &mut Vec<usize>,
    ) {
        self.fy.clear();
        for i in 0..k {
            let j = i + rng.below_usize(n - i);
            let vj = self.fy.get(&j).copied().unwrap_or(j);
            let vi = self.fy.get(&i).copied().unwrap_or(i);
            self.fy.insert(j, vi);
            out.push(map(vj));
        }
    }

    // ---- churn deltas -----------------------------------------------------

    /// Apply one round of Bernoulli join/leave churn as sparse deltas:
    /// O(expected flips · log n) instead of one PRNG draw per client.
    /// Geometric gap sampling walks the available set (leave events with
    /// probability `churn_out` per member) and then the unavailable set
    /// (rejoin events with probability `rejoin` per member); both rank
    /// lists resolve to client ids against the *start-of-round* state
    /// before any flip lands, so the two passes cannot observe each
    /// other. Returns `(left, rejoined)` counts.
    pub fn apply_churn(
        &mut self,
        churn_out: f64,
        rejoin: f64,
        rng: &mut Pcg32,
    ) -> (usize, usize) {
        let avail_n = self.avail.count_ones();
        let gone_n = self.avail.count_zeros();

        // resolve leave ranks -> ids (ascending ranks over the set bits)
        let mut out_ids = std::mem::take(&mut self.churn_out_ids);
        out_ids.clear();
        bernoulli_ranks_into(avail_n, churn_out, rng, |rank| {
            out_ids.push(self.avail.select1(rank));
        });
        // resolve rejoin ranks -> ids before applying the leaves
        let mut in_ids = std::mem::take(&mut self.churn_in_ids);
        in_ids.clear();
        bernoulli_ranks_into(gone_n, rejoin, rng, |rank| {
            in_ids.push(self.avail.select0(rank));
        });

        for &c in &out_ids {
            self.avail.set(c, false);
        }
        for &c in &in_ids {
            self.avail.set(c, true);
        }
        let counts = (out_ids.len(), in_ids.len());
        self.churn_out_ids = out_ids;
        self.churn_in_ids = in_ids;
        counts
    }
}

/// Visit the ranks of a Bernoulli(p) process over `m` ordered slots in
/// O(successes) PRNG draws: the gap to the next success is geometric,
/// `floor(ln(U) / ln(1 - p))` failures long. Equivalent in distribution
/// to flipping a coin per slot, with one uniform draw per success (plus
/// one terminating draw) instead of one per slot.
pub fn bernoulli_ranks_into(
    m: usize,
    p: f64,
    rng: &mut Pcg32,
    mut visit: impl FnMut(usize),
) {
    if m == 0 || !(p > 0.0) {
        return;
    }
    if p >= 1.0 {
        for r in 0..m {
            visit(r);
        }
        return;
    }
    let ln_q = (1.0 - p).ln(); // strictly negative
    let mut pos = -1.0f64;
    loop {
        let u = rng.next_f64();
        // u == 0 -> ln(0) = -inf -> skip = +inf -> loop terminates
        let skip = (u.ln() / ln_q).floor();
        pos += 1.0 + skip;
        if !(pos < m as f64) {
            return;
        }
        visit(pos as usize);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_fisher_yates_matches_sample_indices() {
        for (n, k) in [(1usize, 1usize), (500, 1), (500, 32), (500, 499), (500, 500)] {
            let mut s = CohortSampler::new(n);
            let mut a = Pcg32::new(3, 9);
            let mut b = Pcg32::new(3, 9);
            let sparse = s.sample_uniform(k, &mut a);
            assert_eq!(sparse, b.sample_indices(n, k), "n={n} k={k}");
            // and again on the same (now warm) sampler state
            let again = s.sample_uniform(k, &mut a);
            assert_eq!(again, b.sample_indices(n, k), "warm n={n} k={k}");
        }
    }

    #[test]
    fn sample_available_matches_collect_then_sample() {
        let n = 300;
        let mut s = CohortSampler::new(n);
        for c in 0..n {
            s.set_available(c, c % 3 != 0);
        }
        let avail: Vec<usize> = (0..n).filter(|&c| c % 3 != 0).collect();
        let mut a = Pcg32::new(11, 4);
        let mut b = Pcg32::new(11, 4);
        let fast = s.sample_available(40, &mut a);
        let slow: Vec<usize> = b
            .sample_indices(avail.len(), 40)
            .into_iter()
            .map(|i| avail[i])
            .collect();
        assert_eq!(fast, slow);
    }

    #[test]
    fn weighted_rejection_stays_within_budget_in_fleet_regime() {
        let n = 10_000;
        let mut s = CohortSampler::new(n);
        for c in 0..n {
            s.set_weight(c, 4 + (c % 13) as u64);
        }
        let mut rng = Pcg32::new(8, 8);
        for _ in 0..50 {
            let picked = s.sample_weighted(256, &mut rng);
            assert_eq!(picked.len(), 256);
            let mut t = picked.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 256, "weighted draw produced duplicates");
        }
    }

    #[test]
    fn weighted_fallback_sweep_completes_pathological_draws() {
        // pathological regime: k equals the positive population and one
        // client owns essentially all the mass, so rejection repeatedly
        // re-draws the heavy client. The bounded loop must fall back to
        // the exact sweep and return every positive-weight client.
        let n = 600;
        let mut s = CohortSampler::new(n);
        for c in 0..500 {
            s.set_weight(c, if c == 0 { 1_000_000_000 } else { 1 });
        }
        let mut rng = Pcg32::new(1, 1);
        let mut picked = s.sample_weighted(550, &mut rng); // clamps to 500
        assert_eq!(picked.len(), 500, "fallback did not complete the cohort");
        picked.sort_unstable();
        picked.dedup();
        assert_eq!(picked.len(), 500);
        assert!(picked.iter().all(|&c| c < 500), "picked a zero-weight client");
        // the sweep is deterministic: same seed, same cohort
        let again = s.sample_weighted(550, &mut Pcg32::new(1, 1));
        let mut again_sorted = again.clone();
        again_sorted.sort_unstable();
        assert_eq!(again_sorted, picked);
        // and the heavy head of the draw is still rejection-sampled
        assert!(again.contains(&0));
    }

    #[test]
    fn bernoulli_ranks_match_dense_process_statistically() {
        let m = 20_000;
        let p = 0.05;
        let mut rng = Pcg32::new(77, 2);
        let mut hits = 0usize;
        let mut last = None;
        bernoulli_ranks_into(m, p, &mut rng, |r| {
            assert!(r < m);
            if let Some(prev) = last {
                assert!(r > prev, "ranks must be strictly ascending");
            }
            last = Some(r);
            hits += 1;
        });
        let mean = m as f64 * p;
        let sigma = (m as f64 * p * (1.0 - p)).sqrt();
        assert!(
            (hits as f64 - mean).abs() < 5.0 * sigma,
            "{hits} hits vs expected {mean:.0}"
        );
    }

    #[test]
    fn bernoulli_ranks_edge_rates() {
        let mut rng = Pcg32::new(1, 1);
        let mut v = Vec::new();
        bernoulli_ranks_into(10, 0.0, &mut rng, |r| v.push(r));
        assert!(v.is_empty());
        bernoulli_ranks_into(0, 0.5, &mut rng, |r| v.push(r));
        assert!(v.is_empty());
        bernoulli_ranks_into(10, 1.0, &mut rng, |r| v.push(r));
        assert_eq!(v, (0..10).collect::<Vec<_>>());
        v.clear();
        bernoulli_ranks_into(10, f64::NAN, &mut rng, |r| v.push(r));
        assert!(v.is_empty(), "NaN rate must behave like zero");
    }

    #[test]
    fn churn_deltas_resolve_against_start_of_round_state() {
        // rejoin ranks must be computed over the set of clients that
        // were unavailable *before* this round's leaves applied
        let mut s = CohortSampler::new(100);
        for c in 0..50 {
            s.set_available(c, false);
        }
        let mut rng = Pcg32::new(4, 2);
        let before_gone: Vec<usize> = (0..50).collect();
        let (out, back) = s.apply_churn(0.5, 0.5, &mut rng);
        assert!(out > 0 && back > 0, "both directions should fire at 50%");
        // every rejoiner must come from the start-of-round gone set
        for c in 0..100 {
            if s.is_available(c) && c < 50 {
                assert!(before_gone.contains(&c));
            }
        }
        let avail = s.num_available();
        assert_eq!(avail, 50 - out + back);
    }

    #[test]
    fn steady_state_weight_updates_track_positive_count() {
        let mut s = CohortSampler::new(10);
        assert_eq!(s.positive_weight_count(), 0);
        s.set_weight(3, 5);
        s.set_weight(7, 2);
        assert_eq!(s.positive_weight_count(), 2);
        assert_eq!(s.total_weight(), 7);
        s.set_weight(3, 0);
        assert_eq!(s.positive_weight_count(), 1);
        s.assign_weights((0..10).map(|i| (i % 2) as u64));
        assert_eq!(s.positive_weight_count(), 5);
        assert_eq!(s.total_weight(), 5);
        assert_eq!(s.weight(9), 1);
    }
}
