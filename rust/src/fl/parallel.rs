//! Deterministic chunked thread-parallelism for the server hot path,
//! plus the [`AggScratch`] arena that makes that path allocation-free
//! (DESIGN.md §7).
//!
//! Everything here obeys one contract: **a result is a pure function of
//! the input, never of the thread count.** Work is split into chunks
//! whose boundaries depend only on the problem size (the fixed `chunk`
//! argument — never on `threads`), chunk outputs are disjoint, and
//! reductions combine per-chunk partials in a fixed pairwise tree over
//! the chunk index. `--threads` may change wall-clock time and cache
//! behavior and nothing else; the determinism suite and the fedavg
//! bit-identity property pin this for every entry point.
//!
//! Threads come from `std::thread::scope` (tokio/rayon are unavailable
//! offline), claiming chunks from a shared queue so a straggling chunk
//! cannot serialize the sweep. With `threads <= 1` every helper runs the
//! exact same per-chunk code inline, with zero allocation and zero
//! synchronization — that degenerate path is what the allocation-gate
//! test measures.

use crate::tensor::Tensor;
use std::sync::Mutex;

/// Fixed element-chunk target for the parallel sweeps. Big enough that a
/// chunk amortizes the queue lock (a chunk is ~hundreds of thousands of
/// fused multiply-adds once the update dimension is folded in), small
/// enough that a femnist-sized layer still splits across workers.
pub const CHUNK: usize = 4096;

/// Run `f` over every item of a work list, on up to `threads` scoped
/// worker threads. Items are claimed from a shared queue in list order;
/// the caller guarantees items are independent (all our callers hand out
/// disjoint `&mut` chunks).
fn drain_parallel<I, F>(items: Vec<I>, threads: usize, f: F)
where
    I: Send,
    F: Fn(I) + Sync,
{
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        for item in items {
            f(item);
        }
        return;
    }
    let queue = Mutex::new(items.into_iter());
    std::thread::scope(|s| {
        for _ in 0..threads {
            let queue = &queue;
            let f = &f;
            s.spawn(move || loop {
                let next = queue.lock().expect("chunk queue poisoned").next();
                match next {
                    Some(item) => f(item),
                    None => break,
                }
            });
        }
    });
}

/// Sweep `data` in fixed `chunk`-sized pieces, calling `f(start, piece)`
/// for each. Chunk boundaries are multiples of `chunk` regardless of
/// `threads`, and every element belongs to exactly one piece, so any
/// per-element computation is bit-identical at every thread count. With
/// `threads <= 1` this is a plain loop: no allocation, no spawn.
pub fn for_each_chunk_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    if data.is_empty() {
        return;
    }
    if threads <= 1 || data.len() <= chunk {
        let mut start = 0usize;
        for piece in data.chunks_mut(chunk) {
            let len = piece.len();
            f(start, piece);
            start += len;
        }
        return;
    }
    let mut items: Vec<(usize, &mut [T])> = Vec::with_capacity(data.len().div_ceil(chunk));
    let mut start = 0usize;
    for piece in data.chunks_mut(chunk) {
        let len = piece.len();
        items.push((start, piece));
        start += len;
    }
    drain_parallel(items, threads, |(s, piece)| f(s, piece));
}

/// Like [`for_each_chunk_mut`] over two equal-length slices split at the
/// same boundaries: `f(start, a_piece, b_piece)`. Used where one sweep
/// must fill two aligned outputs (observe's score + streak tables).
pub fn for_each_chunk2_mut<A, B, F>(a: &mut [A], b: &mut [B], chunk: usize, threads: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(chunk > 0, "chunk size must be positive");
    assert_eq!(a.len(), b.len(), "zipped chunk sweep needs equal lengths");
    if a.is_empty() {
        return;
    }
    if threads <= 1 || a.len() <= chunk {
        let mut start = 0usize;
        for (pa, pb) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)) {
            let len = pa.len();
            f(start, pa, pb);
            start += len;
        }
        return;
    }
    let mut items: Vec<(usize, &mut [A], &mut [B])> =
        Vec::with_capacity(a.len().div_ceil(chunk));
    let mut start = 0usize;
    for (pa, pb) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)) {
        let len = pa.len();
        items.push((start, pa, pb));
        start += len;
    }
    drain_parallel(items, threads, |(s, pa, pb)| f(s, pa, pb));
}

/// Deterministic chunked tree-reduction: `map(start, end)` produces one
/// partial per fixed chunk of `0..len`, and partials are combined in a
/// fixed pairwise tree over the chunk index — (0,1), (2,3), … then the
/// results pairwise again — independent of which worker computed which
/// chunk. Floating-point combines are therefore reproducible for every
/// `threads` value (pinned by the unit tests below with a deliberately
/// non-associative sum). Returns `None` for an empty range.
pub fn tree_reduce<R, M, C>(
    len: usize,
    chunk: usize,
    threads: usize,
    map: M,
    combine: C,
) -> Option<R>
where
    R: Send,
    M: Fn(usize, usize) -> R + Sync,
    C: Fn(R, R) -> R,
{
    assert!(chunk > 0, "chunk size must be positive");
    if len == 0 {
        return None;
    }
    let n_chunks = len.div_ceil(chunk);
    let mut partials: Vec<Option<R>> = (0..n_chunks).map(|_| None).collect();
    for_each_chunk_mut(&mut partials, 1, threads, |i, slot| {
        let start = i * chunk;
        let end = (start + chunk).min(len);
        slot[0] = Some(map(start, end));
    });
    let mut layer: Vec<R> = partials
        .into_iter()
        .map(|p| p.expect("every chunk produced a partial"))
        .collect();
    while layer.len() > 1 {
        let mut next = Vec::with_capacity(layer.len().div_ceil(2));
        let mut it = layer.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(combine(a, b)),
                None => next.push(a),
            }
        }
        layer = next;
    }
    layer.pop()
}

/// Cap on recycled output tensors held by the arena — enough for a few
/// rounds of full parameter sets, small enough that an aborted
/// experiment cannot pin unbounded memory.
const POOL_CAP: usize = 64;

/// Reusable server-side scratch arena (owned by the round engine).
///
/// One arena backs masked FedAvg (`fl::aggregate::fedavg_into`), the
/// invariant policy's fused observation sweep
/// (`dropout::InvariantDropout::observe_with`) and snapshot encoding
/// (`snapshot::SnapshotStore::save_with`): every per-round `vec![0.0;
/// len]` the historical hot path allocated now lands in one of these
/// buffers, which keep their capacity across rounds. Contents never
/// carry information between uses — each consumer resets what it needs —
/// so a shared arena can never couple two rounds, which is what keeps
/// the determinism suite honest.
#[derive(Default)]
pub struct AggScratch {
    /// f64 element accumulator (fedavg sums; observe per-neuron sums)
    pub(crate) acc: Vec<f64>,
    /// per-update kept-column weight vectors, `updates x cols`
    pub(crate) kw: Vec<f64>,
    /// per-column ownership denominators
    pub(crate) den: Vec<f64>,
    /// effective (staleness-discounted) per-update weights
    pub(crate) w: Vec<f64>,
    /// observe: per-neuron below-threshold vote counts
    pub(crate) votes: Vec<u32>,
    /// recycled output tensors, matched by shape
    pub(crate) pool: Vec<Tensor>,
    /// snapshot encoding: section blob + finished container
    pub(crate) snap_blob: Vec<u8>,
    pub(crate) snap_bytes: Vec<u8>,
    /// payload codec: per-update packed column-rank maps, `updates x cols`
    /// (kept column -> packed rank, dropped -> `u32::MAX`)
    pub(crate) cmap: Vec<u32>,
    /// payload codec: per-update kept-column counts
    pub(crate) kept: Vec<u32>,
    /// recycled per-client error strings for wire decode
    pub(crate) errs: Vec<String>,
}

impl AggScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch an output tensor of the given shape — recycled from the
    /// pool when a previous round returned one, freshly allocated only
    /// on cold start. Contents are unspecified; the caller overwrites
    /// every element.
    pub(crate) fn take_out(&mut self, shape: &[usize]) -> Tensor {
        if let Some(i) = self.pool.iter().position(|t| t.shape() == shape) {
            return self.pool.swap_remove(i);
        }
        Tensor::zeros(shape)
    }

    /// Return retired tensors (typically the previous round's global
    /// parameters) to the pool so the next aggregation reuses their
    /// buffers instead of allocating.
    pub fn recycle(&mut self, tensors: Vec<Tensor>) {
        for t in tensors {
            if self.pool.len() < POOL_CAP {
                self.pool.push(t);
            }
        }
    }

    /// Fetch a pooled `String` for a decoded per-client error message.
    /// Contents are unspecified; the caller overwrites them.
    pub(crate) fn take_err(&mut self) -> String {
        self.errs.pop().unwrap_or_default()
    }

    /// Return a retired error string so its capacity is reused by the
    /// next decode.
    pub fn recycle_err(&mut self, mut s: String) {
        if self.errs.len() < POOL_CAP {
            s.clear();
            self.errs.push(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunk_sweep_covers_every_element_once() {
        for threads in [1usize, 2, 4, 8] {
            let mut data = vec![0u32; 1003];
            for_each_chunk_mut(&mut data, 64, threads, |start, piece| {
                for (k, x) in piece.iter_mut().enumerate() {
                    *x += (start + k) as u32 + 1;
                }
            });
            for (i, &x) in data.iter().enumerate() {
                assert_eq!(x, i as u32 + 1, "threads={threads} elem {i}");
            }
        }
    }

    #[test]
    fn chunk_boundaries_are_thread_invariant() {
        // record the (start, len) set per thread count; must be identical
        let bounds = |threads: usize| {
            let seen = Mutex::new(Vec::new());
            let mut data = vec![0u8; 777];
            for_each_chunk_mut(&mut data, 100, threads, |start, piece| {
                seen.lock().unwrap().push((start, piece.len()));
            });
            let mut v = seen.into_inner().unwrap();
            v.sort_unstable();
            v
        };
        let reference = bounds(1);
        assert_eq!(reference.len(), 8);
        for threads in [2usize, 3, 8, 16] {
            assert_eq!(bounds(threads), reference, "threads={threads}");
        }
    }

    #[test]
    fn zipped_sweep_stays_aligned() {
        for threads in [1usize, 4] {
            let mut a = vec![0usize; 530];
            let mut b = vec![0usize; 530];
            for_each_chunk2_mut(&mut a, &mut b, 128, threads, |start, pa, pb| {
                assert_eq!(pa.len(), pb.len());
                for (k, (x, y)) in pa.iter_mut().zip(pb.iter_mut()).enumerate() {
                    *x = start + k;
                    *y = 2 * (start + k);
                }
            });
            for i in 0..530 {
                assert_eq!(a[i], i);
                assert_eq!(b[i], 2 * i);
            }
        }
    }

    #[test]
    fn tree_reduce_is_bit_identical_across_thread_counts() {
        // a deliberately non-associative float sum: any change in combine
        // order shows up in the low bits
        let xs: Vec<f64> = (0..10_000)
            .map(|i| ((i * 2_654_435_761usize) % 1_000) as f64 * 1e-3 + 1e-9)
            .collect();
        let sum = |threads: usize| {
            tree_reduce(
                xs.len(),
                256,
                threads,
                |s, e| xs[s..e].iter().sum::<f64>(),
                |a, b| a + b,
            )
            .unwrap()
        };
        let reference = sum(1);
        for threads in [2usize, 4, 8] {
            assert_eq!(reference.to_bits(), sum(threads).to_bits(), "threads={threads}");
        }
    }

    #[test]
    fn tree_reduce_empty_and_single() {
        assert_eq!(tree_reduce(0, 8, 4, |_, _| 1u32, |a, b| a + b), None);
        assert_eq!(tree_reduce(5, 8, 4, |s, e| e - s, |a, b| a + b), Some(5));
        // count chunks for a multi-chunk range
        assert_eq!(tree_reduce(100, 8, 4, |_, _| 1u32, |a, b| a + b), Some(13));
    }

    #[test]
    fn serial_path_runs_inline() {
        // threads=1 must not spawn: the closure observes the same thread id
        let main_id = std::thread::current().id();
        let mut data = vec![0u8; 10_000];
        let hits = AtomicUsize::new(0);
        for_each_chunk_mut(&mut data, 64, 1, |_, _| {
            assert_eq!(std::thread::current().id(), main_id);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 10_000usize.div_ceil(64));
    }

    #[test]
    fn scratch_pool_recycles_by_shape() {
        let mut s = AggScratch::new();
        let t = Tensor::from_vec(&[2, 3], vec![1.0; 6]);
        s.recycle(vec![t, Tensor::zeros(&[4])]);
        let got = s.take_out(&[2, 3]);
        assert_eq!(got.shape(), &[2, 3]);
        // second request for the same shape falls back to a fresh tensor
        let fresh = s.take_out(&[2, 3]);
        assert_eq!(fresh.shape(), &[2, 3]);
        let other = s.take_out(&[4]);
        assert_eq!(other.shape(), &[4]);
    }
}
