//! Client-update payload codec: sparse and quantized representations of
//! the tensors a client returns, behind one [`DeltaPayload`] value
//! (DESIGN.md §12).
//!
//! FLuID's invariant dropout guarantees that a straggler's dropped
//! neurons come back *bit-equal* to the broadcast global weights (zero
//! gradient — the L2 invariant the runtime tests pin). So a sub-model
//! update only needs to move its **kept** columns: [`Compression::Sparse`]
//! packs exactly those, reusing the [`MaskSet`] column indices the
//! aggregator already derives instead of shipping explicit index lists,
//! and reconstructs every dropped element from the broadcast global on
//! decode. [`Compression::Q8`] additionally quantizes the packed *delta*
//! (update minus broadcast) to int8 with one symmetric per-tensor scale,
//! carrying per-client error-feedback residuals across rounds so the
//! quantization error telescopes instead of accumulating.
//!
//! [`Compression::Dense`] (the default) is the bit-exact determinism
//! reference: its payloads are the raw tensors, every pinned trajectory
//! runs through it unchanged, and the compressed modes are *defined*
//! against it (sparse is bit-equal to dense wherever the invariant
//! holds; q8 is dense plus a bounded, error-fed quantization residual).
//!
//! Layering: the engine owns one [`Codec`] (the [`UpdateCodec`] impl
//! holding q8 residual state) and encodes fresh updates at aggregation
//! assembly; the shard wire carries stateless sparse packings (see
//! [`pack_result`] — quantizer state must live in exactly one place or
//! N→M shard resume would partition it); `fl::aggregate::fedavg_into`
//! consumes payloads directly with a fused dequantize-accumulate sweep.

use super::aggregate::{group_of_param, neuron_of};
use super::client::LocalResult;
use super::parallel::AggScratch;
use crate::dropout::MaskSet;
use crate::model::ModelSpec;
use crate::snapshot::{codec, Reader, Writer};
use crate::tensor::Tensor;
use anyhow::bail;
use std::collections::BTreeMap;

/// Which update representation an experiment moves and aggregates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Compression {
    /// Raw f32 tensors — the bit-exact reference path.
    Dense,
    /// Kept-column packing over the sub-model mask, raw f32 values.
    Sparse,
    /// Kept-column packing of int8-quantized deltas with per-tensor
    /// symmetric scales and per-client error feedback.
    Q8,
}

impl Compression {
    /// Parse a `--compress` flag value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "dense" => Some(Self::Dense),
            "sparse" => Some(Self::Sparse),
            "q8" => Some(Self::Q8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Dense => "dense",
            Self::Sparse => "sparse",
            Self::Q8 => "q8",
        }
    }
}

/// Kept-column packed update: one value vector per parameter. Group
/// parameters carry `rows x kept_cols` values in row-major order, kept
/// columns ascending (the rank order [`column_ranks`] assigns); non-group
/// parameters are trained by every client and stay fully represented.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseUpdate {
    pub values: Vec<Vec<f32>>,
}

/// Quantized kept-column packed delta: per-parameter symmetric scale
/// (`x ≈ global + scale * q`) over the same packing as [`SparseUpdate`].
#[derive(Clone, Debug, PartialEq)]
pub struct QuantUpdate {
    pub scales: Vec<f32>,
    pub values: Vec<Vec<i8>>,
}

/// One client update as it moves between layers: produced by an
/// [`UpdateCodec`], framed by `engine::wire`, consumed by
/// `fl::aggregate::fedavg_into`.
#[derive(Clone, Debug)]
pub enum DeltaPayload {
    DenseF32(Vec<Tensor>),
    SparseF32(SparseUpdate),
    SparseQ8(QuantUpdate),
}

impl DeltaPayload {
    pub fn mode(&self) -> Compression {
        match self {
            Self::DenseF32(_) => Compression::Dense,
            Self::SparseF32(_) => Compression::Sparse,
            Self::SparseQ8(_) => Compression::Q8,
        }
    }

    pub fn is_dense(&self) -> bool {
        matches!(self, Self::DenseF32(_))
    }

    /// Exact byte count this payload occupies inside a wire frame
    /// (mirrors [`put_payload`] — the per-round bytes-moved report sums
    /// this).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Self::DenseF32(ts) => {
                1 + 8
                    + ts.iter()
                        .map(|t| 8 + 8 * t.shape().len() + 8 + 4 * t.len())
                        .sum::<usize>()
            }
            Self::SparseF32(s) => {
                1 + 8 + s.values.iter().map(|v| 8 + 4 * v.len()).sum::<usize>()
            }
            Self::SparseQ8(q) => {
                1 + 8 + q.values.iter().map(|v| 4 + 8 + v.len()).sum::<usize>()
            }
        }
    }
}

/// Fill `map[c]` with the packed rank of column `c` (kept columns number
/// `0..kept` in ascending column order; dropped columns get `u32::MAX`)
/// and return the kept-column count. `mask_g` is the group's mask tensor
/// data (1.0 = kept), `span` the gate span ([`neuron_of`]).
pub(crate) fn column_ranks(
    mask_g: &[f32],
    cols: usize,
    n: usize,
    span: usize,
    map: &mut [u32],
) -> usize {
    debug_assert_eq!(map.len(), cols);
    let mut rank = 0u32;
    for (c, slot) in map.iter_mut().enumerate() {
        if mask_g[neuron_of(c, cols, n, span)] == 1.0 {
            *slot = rank;
            rank += 1;
        } else {
            *slot = u32::MAX;
        }
    }
    rank as usize
}

/// Stateless kept-column packing of a full parameter set against `mask`.
/// Bit-lossless wherever the invariant holds (a dropped column equals
/// the broadcast global, which [`unpack`] restores verbatim); the rank
/// map is staged in `scratch.cmap` so steady-state packing allocates
/// only the value vectors themselves.
pub fn pack_sparse(
    spec: &ModelSpec,
    params: &[Tensor],
    mask: &MaskSet,
    scratch: &mut AggScratch,
) -> SparseUpdate {
    let mut values = Vec::with_capacity(params.len());
    for (pi, t) in params.iter().enumerate() {
        let data = t.data();
        match group_of_param(spec, pi) {
            Some((gidx, span)) => {
                let cols = *spec.params[pi].shape.last().unwrap_or(&1);
                let n = spec.masks[gidx].size;
                scratch.cmap.clear();
                scratch.cmap.resize(cols, 0);
                let kept =
                    column_ranks(mask.tensors()[gidx].data(), cols, n, span, &mut scratch.cmap);
                let rows = data.len() / cols.max(1);
                let mut v = Vec::with_capacity(rows * kept);
                let mut c = 0usize;
                for &x in data {
                    if scratch.cmap[c] != u32::MAX {
                        v.push(x);
                    }
                    c += 1;
                    if c == cols {
                        c = 0;
                    }
                }
                values.push(v);
            }
            None => values.push(data.to_vec()),
        }
    }
    SparseUpdate { values }
}

/// Reconstruct dense tensors from a payload against the broadcast
/// `global` and the client's `mask`. Dense payloads pass through after
/// shape validation; sparse payloads restore dropped columns from the
/// global (exactly the invariant's value); q8 payloads dequantize
/// `global + scale * q`. Output tensors come from `scratch`'s recycle
/// pool. Wire data is untrusted, so every length is validated — any
/// mismatch is a clean `Err`.
pub fn unpack(
    payload: DeltaPayload,
    mask: &MaskSet,
    global: &[Tensor],
    spec: &ModelSpec,
    scratch: &mut AggScratch,
) -> crate::Result<Vec<Tensor>> {
    match payload {
        DeltaPayload::DenseF32(ts) => {
            if ts.len() != spec.params.len() {
                bail!("dense payload holds {} tensors, spec has {}", ts.len(), spec.params.len());
            }
            for (pi, t) in ts.iter().enumerate() {
                if t.shape() != &spec.params[pi].shape[..] {
                    bail!(
                        "dense payload tensor {pi} has shape {:?}, spec wants {:?}",
                        t.shape(),
                        spec.params[pi].shape
                    );
                }
            }
            Ok(ts)
        }
        DeltaPayload::SparseF32(s) => {
            unpack_packed(&s.values, None, mask, global, spec, scratch)
        }
        DeltaPayload::SparseQ8(q) => {
            if q.scales.len() != spec.params.len() {
                bail!("q8 payload holds {} scales, spec has {}", q.scales.len(), spec.params.len());
            }
            unpack_packed(&q.values, Some(&q.scales), mask, global, spec, scratch)
        }
    }
}

/// Shared reconstruction loop for the two packed representations: `V` is
/// `f32` (raw kept values) or `i8` (quantized deltas, `scales` present).
trait PackedValue: Copy {
    /// The dense f32 this packed element reconstructs to.
    fn expand(self, global: f32, scale: f32) -> f32;
}

impl PackedValue for f32 {
    #[inline]
    fn expand(self, _global: f32, _scale: f32) -> f32 {
        self
    }
}

impl PackedValue for i8 {
    #[inline]
    fn expand(self, global: f32, scale: f32) -> f32 {
        global + scale * self as f32
    }
}

fn unpack_packed<V: PackedValue>(
    values: &[Vec<V>],
    scales: Option<&[f32]>,
    mask: &MaskSet,
    global: &[Tensor],
    spec: &ModelSpec,
    scratch: &mut AggScratch,
) -> crate::Result<Vec<Tensor>> {
    if values.len() != spec.params.len() {
        bail!("packed payload holds {} params, spec has {}", values.len(), spec.params.len());
    }
    if global.len() != spec.params.len() {
        bail!("global holds {} params, spec has {}", global.len(), spec.params.len());
    }
    let mut outs = Vec::with_capacity(values.len());
    for (pi, vals) in values.iter().enumerate() {
        let g_t = &global[pi];
        let len = g_t.len();
        let scale = scales.map(|s| s[pi]).unwrap_or(0.0);
        let mut out = scratch.take_out(g_t.shape());
        {
            let o = out.data_mut();
            let g = g_t.data();
            match group_of_param(spec, pi) {
                Some((gidx, span)) => {
                    let cols = *spec.params[pi].shape.last().unwrap_or(&1);
                    let n = spec.masks[gidx].size;
                    scratch.cmap.clear();
                    scratch.cmap.resize(cols, 0);
                    let kept = column_ranks(
                        mask.tensors()[gidx].data(),
                        cols,
                        n,
                        span,
                        &mut scratch.cmap,
                    );
                    let rows = len / cols.max(1);
                    if vals.len() != rows * kept {
                        bail!(
                            "packed param {pi} holds {} values, mask wants {rows} x {kept}",
                            vals.len()
                        );
                    }
                    let mut c = 0usize;
                    let mut base = 0usize;
                    for (e, oj) in o.iter_mut().enumerate() {
                        let r = scratch.cmap[c];
                        *oj = if r != u32::MAX {
                            vals[base + r as usize].expand(g[e], scale)
                        } else {
                            g[e]
                        };
                        c += 1;
                        if c == cols {
                            c = 0;
                            base += kept;
                        }
                    }
                }
                None => {
                    if vals.len() != len {
                        bail!("packed param {pi} holds {} values, spec wants {len}", vals.len());
                    }
                    for ((oj, &v), &gj) in o.iter_mut().zip(vals).zip(g.iter()) {
                        *oj = v.expand(gj, scale);
                    }
                }
            }
        }
        outs.push(out);
    }
    Ok(outs)
}

// ---------------------------------------------------------------------
// the stateful engine-side codec
// ---------------------------------------------------------------------

/// Encode/decode seam between raw client tensors and [`DeltaPayload`]s.
/// `encode` is `&mut self` because q8 carries per-client error-feedback
/// residual state across rounds.
pub trait UpdateCodec {
    fn mode(&self) -> Compression;

    /// Consume a client's trained parameters and produce its payload.
    /// Dense mode moves the tensors through untouched; the compressed
    /// modes pack them and recycle the dense buffers into `scratch`.
    fn encode(
        &mut self,
        client: u64,
        params: Vec<Tensor>,
        mask: &MaskSet,
        global: &[Tensor],
        spec: &ModelSpec,
        scratch: &mut AggScratch,
    ) -> DeltaPayload;
}

/// The engine's codec: mode from `ExperimentConfig::compress`, plus the
/// q8 error-feedback residuals (one dense f32 set per client that has
/// ever encoded under q8, keyed by client id in a `BTreeMap` so
/// snapshot export is deterministically ordered).
pub struct Codec {
    mode: Compression,
    resid: BTreeMap<u64, Vec<Vec<f32>>>,
}

impl Codec {
    pub fn new(mode: Compression) -> Self {
        Self { mode, resid: BTreeMap::new() }
    }

    /// Residual state for the snapshot RESID section, sorted by client.
    pub fn export_resid(&self) -> Vec<(u64, Vec<Vec<f32>>)> {
        self.resid.iter().map(|(c, v)| (*c, v.clone())).collect()
    }

    /// Restore residual state from a snapshot, validating every tensor
    /// length against the spec before installing anything.
    pub fn import_resid(
        &mut self,
        entries: Vec<(u64, Vec<Vec<f32>>)>,
        spec: &ModelSpec,
    ) -> crate::Result<()> {
        let mut resid = BTreeMap::new();
        for (client, params) in entries {
            if params.len() != spec.params.len() {
                bail!(
                    "snapshot residuals for client {client} hold {} params, spec has {}",
                    params.len(),
                    spec.params.len()
                );
            }
            for (pi, r) in params.iter().enumerate() {
                let want: usize = spec.params[pi].shape.iter().product();
                if r.len() != want {
                    bail!(
                        "snapshot residual {pi} for client {client} holds {} elements, \
                         spec wants {want}",
                        r.len()
                    );
                }
            }
            resid.insert(client, params);
        }
        self.resid = resid;
        Ok(())
    }

    /// Quantize `params` against `global` under the client's residuals.
    /// Scales are symmetric per tensor over the *packed* shifted deltas
    /// (`x' = (param - global) + residual`); residuals advance on packed
    /// elements only (`x' - scale * q`), so dropped columns — whose true
    /// delta the invariant pins at zero — never accumulate phantom error.
    fn encode_q8(
        &mut self,
        client: u64,
        params: &[Tensor],
        mask: &MaskSet,
        global: &[Tensor],
        spec: &ModelSpec,
        scratch: &mut AggScratch,
    ) -> QuantUpdate {
        let resid = self
            .resid
            .entry(client)
            .or_insert_with(|| params.iter().map(|t| vec![0.0f32; t.len()]).collect());
        let mut scales = Vec::with_capacity(params.len());
        let mut values = Vec::with_capacity(params.len());
        for (pi, t) in params.iter().enumerate() {
            let data = t.data();
            let g = global[pi].data();
            let r = &mut resid[pi];
            let (cols, kept) = match group_of_param(spec, pi) {
                Some((gidx, span)) => {
                    let cols = *spec.params[pi].shape.last().unwrap_or(&1);
                    let n = spec.masks[gidx].size;
                    scratch.cmap.clear();
                    scratch.cmap.resize(cols, 0);
                    let kept = column_ranks(
                        mask.tensors()[gidx].data(),
                        cols,
                        n,
                        span,
                        &mut scratch.cmap,
                    );
                    (cols, kept)
                }
                None => {
                    // fully represented: every column "kept"
                    scratch.cmap.clear();
                    scratch.cmap.resize(1, 0);
                    (1, 1)
                }
            };
            let rows = data.len() / cols.max(1);
            // pass 1: symmetric max over the packed shifted deltas
            let mut max = 0.0f32;
            let mut c = 0usize;
            for (e, &x) in data.iter().enumerate() {
                if scratch.cmap[c] != u32::MAX {
                    let xp = (x - g[e]) + r[e];
                    max = max.max(xp.abs());
                }
                c += 1;
                if c == cols {
                    c = 0;
                }
            }
            let scale = if max > 0.0 && max.is_finite() { max / 127.0 } else { 0.0 };
            // pass 2: quantize packed elements, advance their residuals
            let mut v = Vec::with_capacity(rows * kept);
            let mut c = 0usize;
            for (e, &x) in data.iter().enumerate() {
                if scratch.cmap[c] != u32::MAX {
                    let xp = (x - g[e]) + r[e];
                    let q = if scale > 0.0 {
                        (xp / scale).round().clamp(-127.0, 127.0) as i8
                    } else {
                        0
                    };
                    r[e] = xp - scale * q as f32;
                    v.push(q);
                }
                c += 1;
                if c == cols {
                    c = 0;
                }
            }
            scales.push(scale);
            values.push(v);
        }
        QuantUpdate { scales, values }
    }
}

impl UpdateCodec for Codec {
    fn mode(&self) -> Compression {
        self.mode
    }

    fn encode(
        &mut self,
        client: u64,
        params: Vec<Tensor>,
        mask: &MaskSet,
        global: &[Tensor],
        spec: &ModelSpec,
        scratch: &mut AggScratch,
    ) -> DeltaPayload {
        match self.mode {
            Compression::Dense => DeltaPayload::DenseF32(params),
            Compression::Sparse => {
                let packed = pack_sparse(spec, &params, mask, scratch);
                scratch.recycle(params);
                DeltaPayload::SparseF32(packed)
            }
            Compression::Q8 => {
                let packed = self.encode_q8(client, &params, mask, global, spec, scratch);
                scratch.recycle(params);
                DeltaPayload::SparseQ8(packed)
            }
        }
    }
}

// ---------------------------------------------------------------------
// wire-side packing (stateless) and payload framing
// ---------------------------------------------------------------------

/// A shard-wire training result whose tensors travel as a payload
/// instead of dense f32 columns (`ShardMessage::Packed`).
#[derive(Clone, Debug)]
pub struct PackedResult {
    pub payload: DeltaPayload,
    pub mean_loss: f64,
    pub mean_acc: f64,
    pub steps: usize,
    pub weight: f64,
}

/// Pack one [`LocalResult`] for the shard wire. Compressed modes both
/// ship the **sparse** packing here: the wire must stay lossless and
/// stateless (q8's residuals live in the root engine's [`Codec`] — if
/// shard workers quantized, the error-feedback state would partition by
/// shard count and N→M resume could not be bit-identical). Dense mode
/// passes the tensors through untouched.
pub fn pack_result(
    res: LocalResult,
    mask: &MaskSet,
    spec: &ModelSpec,
    mode: Compression,
    scratch: &mut AggScratch,
) -> PackedResult {
    let payload = match mode {
        Compression::Dense => DeltaPayload::DenseF32(res.params),
        Compression::Sparse | Compression::Q8 => {
            let packed = pack_sparse(spec, &res.params, mask, scratch);
            scratch.recycle(res.params);
            DeltaPayload::SparseF32(packed)
        }
    };
    PackedResult {
        payload,
        mean_loss: res.mean_loss,
        mean_acc: res.mean_acc,
        steps: res.steps,
        weight: res.weight,
    }
}

/// Reconstruct the dense [`LocalResult`] a packed wire item stands for.
pub fn unpack_result(
    pr: PackedResult,
    mask: &MaskSet,
    global: &[Tensor],
    spec: &ModelSpec,
    scratch: &mut AggScratch,
) -> crate::Result<LocalResult> {
    let params = unpack(pr.payload, mask, global, spec, scratch)?;
    Ok(LocalResult {
        params,
        mean_loss: pr.mean_loss,
        mean_acc: pr.mean_acc,
        steps: pr.steps,
        weight: pr.weight,
    })
}

const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_Q8: u8 = 2;

/// Frame a payload into a wire writer. One encoder for all three
/// representations, built entirely from the shared `snapshot::codec`
/// bulk helpers — [`DeltaPayload`] framing is written exactly once.
pub fn put_payload(w: &mut Writer, p: &DeltaPayload) {
    match p {
        DeltaPayload::DenseF32(ts) => {
            w.put_u8(TAG_DENSE);
            w.put_usize(ts.len());
            for t in ts {
                codec::put_tensor_bulk(w, t);
            }
        }
        DeltaPayload::SparseF32(s) => {
            w.put_u8(TAG_SPARSE);
            w.put_usize(s.values.len());
            for v in &s.values {
                w.put_f32_bytes(v);
            }
        }
        DeltaPayload::SparseQ8(q) => {
            w.put_u8(TAG_Q8);
            w.put_usize(q.values.len());
            for (s, v) in q.scales.iter().zip(&q.values) {
                w.put_f32(*s);
                w.put_i8_bytes(v);
            }
        }
    }
}

/// Decode a [`put_payload`] framing. Dense tensors come out of
/// `scratch`'s recycle pool; packed value vectors allocate exactly their
/// own storage (O(packed), never O(dense)). Lengths are validated before
/// any allocation, so corrupt frames are a clean `Err`.
pub fn take_payload(r: &mut Reader<'_>, scratch: &mut AggScratch) -> crate::Result<DeltaPayload> {
    let tag = r.take_u8()?;
    let count = r.take_usize()?;
    if count > r.remaining() {
        bail!("wire payload claims {count} params in {} bytes", r.remaining());
    }
    match tag {
        TAG_DENSE => {
            let mut ts = Vec::with_capacity(count);
            for _ in 0..count {
                ts.push(codec::take_tensor_bulk(r, |shape| scratch.take_out(shape))?);
            }
            Ok(DeltaPayload::DenseF32(ts))
        }
        TAG_SPARSE => {
            let mut values = Vec::with_capacity(count);
            for _ in 0..count {
                values.push(r.take_f32_bytes()?);
            }
            Ok(DeltaPayload::SparseF32(SparseUpdate { values }))
        }
        TAG_Q8 => {
            let mut scales = Vec::with_capacity(count);
            let mut values = Vec::with_capacity(count);
            for _ in 0..count {
                scales.push(r.take_f32()?);
                values.push(r.take_i8_bytes()?);
            }
            Ok(DeltaPayload::SparseQ8(QuantUpdate { scales, values }))
        }
        other => bail!("unknown wire payload tag {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    fn half_mask(spec: &ModelSpec) -> MaskSet {
        // keep the first half of every group (fc1: 5 of 10, fc2: 3 of 6)
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| (0..m.size).map(|j| j < m.size / 2).collect())
            .collect();
        MaskSet::from_keep(spec, &keep)
    }

    /// Params that obey the invariant: kept columns trained away from
    /// the global, dropped columns bit-equal to it.
    fn invariant_params(spec: &ModelSpec, global: &[Tensor], mask: &MaskSet) -> Vec<Tensor> {
        let mut out = global.to_vec();
        for (pi, t) in out.iter_mut().enumerate() {
            let cols = *spec.params[pi].shape.last().unwrap_or(&1);
            if let Some((gidx, span)) = group_of_param(spec, pi) {
                let n = spec.masks[gidx].size;
                let m = mask.tensors()[gidx].data().to_vec();
                for (e, x) in t.data_mut().iter_mut().enumerate() {
                    if m[neuron_of(e % cols, cols, n, span)] == 1.0 {
                        *x += 0.25 + (e % 7) as f32 * 0.125;
                    }
                }
            } else {
                for (e, x) in t.data_mut().iter_mut().enumerate() {
                    *x += 0.5 + (e % 3) as f32 * 0.25;
                }
            }
        }
        out
    }

    #[test]
    fn compression_parses_flag_values() {
        assert_eq!(Compression::parse("dense"), Some(Compression::Dense));
        assert_eq!(Compression::parse("sparse"), Some(Compression::Sparse));
        assert_eq!(Compression::parse("q8"), Some(Compression::Q8));
        assert_eq!(Compression::parse("zstd"), None);
        assert_eq!(Compression::Q8.name(), "q8");
    }

    #[test]
    fn column_ranks_numbers_kept_columns_in_order() {
        // 6 neurons, first half kept, span 1
        let mask = [1.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        let mut map = vec![0u32; 6];
        let kept = column_ranks(&mask, 6, 6, 1, &mut map);
        assert_eq!(kept, 3);
        assert_eq!(map, vec![0, 1, 2, u32::MAX, u32::MAX, u32::MAX]);
        // LSTM gate span 4 over 2 neurons (cols = 8): neuron 1 dropped
        let mask = [1.0f32, 0.0];
        let mut map = vec![0u32; 8];
        let kept = column_ranks(&mask, 8, 2, 4, &mut map);
        assert_eq!(kept, 4);
        assert_eq!(map[0], 0);
        assert_eq!(map[1], u32::MAX);
        assert_eq!(map[2], 1);
        assert_eq!(map[6], 3);
        assert_eq!(map[7], u32::MAX);
    }

    #[test]
    fn sparse_round_trip_is_bit_exact_under_the_invariant() {
        let spec = tiny_spec();
        let global = spec.init_params(7);
        let mask = half_mask(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let packed = pack_sparse(&spec, &params, &mask, &mut scratch);
        // group params shrink to their kept columns, non-group stay full
        assert!(packed.values[0].len() < params[0].len());
        let back = unpack(
            DeltaPayload::SparseF32(packed),
            &mask,
            &global,
            &spec,
            &mut scratch,
        )
        .unwrap();
        for (a, b) in back.iter().zip(&params) {
            assert_eq!(a.shape(), b.shape());
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn full_mask_sparse_packing_matches_dense_layout() {
        let spec = tiny_spec();
        let global = spec.init_params(3);
        let mask = MaskSet::full(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let packed = pack_sparse(&spec, &params, &mask, &mut scratch);
        for (v, t) in packed.values.iter().zip(&params) {
            assert_eq!(v.len(), t.len());
        }
    }

    #[test]
    fn q8_error_is_bounded_by_half_scale() {
        let spec = tiny_spec();
        let global = spec.init_params(11);
        let mask = half_mask(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let mut codec = Codec::new(Compression::Q8);
        let payload = codec.encode(9, params.clone(), &mask, &global, &spec, &mut scratch);
        let scales = match &payload {
            DeltaPayload::SparseQ8(q) => q.scales.clone(),
            other => panic!("q8 codec produced {other:?}"),
        };
        let back = unpack(payload, &mask, &global, &spec, &mut scratch).unwrap();
        for (pi, (a, b)) in back.iter().zip(&params).enumerate() {
            let tol = scales[pi] * 0.5 + 1e-6;
            let cols = *spec.params[pi].shape.last().unwrap_or(&1);
            let packed_col = |e: usize| match group_of_param(&spec, pi) {
                Some((gidx, span)) => {
                    let n = spec.masks[gidx].size;
                    mask.tensors()[gidx].data()[neuron_of(e % cols, cols, n, span)] == 1.0
                }
                None => true,
            };
            for (e, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
                if packed_col(e) {
                    assert!(
                        (x - y).abs() <= tol,
                        "param {pi} elem {e}: |{x} - {y}| > {tol}"
                    );
                } else {
                    // dropped columns reconstruct the global exactly
                    assert_eq!(x.to_bits(), global[pi].data()[e].to_bits());
                }
            }
        }
    }

    #[test]
    fn codec_residuals_export_import_round_trip() {
        let spec = tiny_spec();
        let global = spec.init_params(5);
        let mask = half_mask(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let mut codec = Codec::new(Compression::Q8);
        codec.encode(3, params.clone(), &mask, &global, &spec, &mut scratch);
        codec.encode(1, params, &mask, &global, &spec, &mut scratch);
        let exported = codec.export_resid();
        assert_eq!(exported.len(), 2);
        assert!(exported[0].0 < exported[1].0, "export sorted by client id");
        let mut fresh = Codec::new(Compression::Q8);
        fresh.import_resid(exported.clone(), &spec).unwrap();
        assert_eq!(fresh.export_resid(), exported);
        // a residual tensor of the wrong length is rejected
        let mut bad = exported;
        bad[0].1[0].pop();
        assert!(fresh.import_resid(bad, &spec).is_err());
    }

    #[test]
    fn dense_mode_moves_tensors_through_unchanged() {
        let spec = tiny_spec();
        let global = spec.init_params(2);
        let params = spec.init_params(4);
        let want: Vec<Vec<u32>> = params
            .iter()
            .map(|t| t.data().iter().map(|x| x.to_bits()).collect())
            .collect();
        let mut scratch = AggScratch::new();
        let mut codec = Codec::new(Compression::Dense);
        let payload =
            codec.encode(0, params, &MaskSet::full(&spec), &global, &spec, &mut scratch);
        let back = unpack(payload, &MaskSet::full(&spec), &global, &spec, &mut scratch).unwrap();
        for (t, bits) in back.iter().zip(&want) {
            for (x, b) in t.data().iter().zip(bits) {
                assert_eq!(x.to_bits(), *b);
            }
        }
    }

    #[test]
    fn unpack_rejects_mismatched_lengths() {
        let spec = tiny_spec();
        let global = spec.init_params(1);
        let mask = half_mask(&spec);
        let mut scratch = AggScratch::new();
        let params = invariant_params(&spec, &global, &mask);
        let packed = pack_sparse(&spec, &params, &mask, &mut scratch);
        // drop one value: the rows x kept accounting must notice
        let mut short = packed.clone();
        short.values[0].pop();
        assert!(unpack(
            DeltaPayload::SparseF32(short),
            &mask,
            &global,
            &spec,
            &mut scratch
        )
        .is_err());
        // wrong param count
        let mut missing = packed;
        missing.values.pop();
        assert!(unpack(
            DeltaPayload::SparseF32(missing),
            &mask,
            &global,
            &spec,
            &mut scratch
        )
        .is_err());
    }

    #[test]
    fn payload_framing_round_trips_all_representations() {
        let spec = tiny_spec();
        let global = spec.init_params(6);
        let mask = half_mask(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let payloads = vec![
            DeltaPayload::DenseF32(params.clone()),
            DeltaPayload::SparseF32(pack_sparse(&spec, &params, &mask, &mut scratch)),
            DeltaPayload::SparseQ8(
                Codec::new(Compression::Q8)
                    .encode_q8(4, &params, &mask, &global, &spec, &mut scratch),
            ),
        ];
        for p in payloads {
            let mut w = Writer::new();
            put_payload(&mut w, &p);
            let bytes = w.into_bytes();
            assert_eq!(bytes.len(), p.wire_bytes(), "wire_bytes mirrors the framing");
            let mut r = Reader::new(&bytes);
            let back = take_payload(&mut r, &mut scratch).unwrap();
            assert!(r.is_done());
            let mut w2 = Writer::new();
            put_payload(&mut w2, &back);
            assert_eq!(w2.into_bytes(), bytes, "encode -> decode -> encode fixpoint");
        }
    }

    #[test]
    fn sparse_wire_bytes_shrink_with_the_mask() {
        let spec = tiny_spec();
        let global = spec.init_params(8);
        let mask = half_mask(&spec);
        let params = invariant_params(&spec, &global, &mask);
        let mut scratch = AggScratch::new();
        let dense = DeltaPayload::DenseF32(params.clone());
        let sparse = DeltaPayload::SparseF32(pack_sparse(&spec, &params, &mask, &mut scratch));
        assert!(sparse.wire_bytes() < dense.wire_bytes());
    }
}
