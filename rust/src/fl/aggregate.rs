//! Masked FedAvg aggregation.
//!
//! Stragglers train sub-models: their dropped-neuron weights come back
//! *exactly equal* to the broadcast values (zero gradient — verified by
//! the L2 tests). Two aggregation modes:
//!
//! * [`AggregateMode::Plain`] — classic example-weighted FedAvg over the
//!   full parameter vectors (what Flower does; dropped weights pull
//!   toward their stale broadcast value, which is a no-op since they
//!   *are* the broadcast value).
//! * [`AggregateMode::OwnershipWeighted`] — per-element denominators
//!   count only the clients whose sub-model actually *trained* the
//!   element (FjORD-style). For each maskable group we map weight/bias
//!   elements to their neuron: `{g}_w` columns and `{g}_b` entries
//!   (LSTM's 4H gate layout maps column c -> neuron c % H). Elements of
//!   non-group parameters (output layers, shortcuts) are trained by
//!   every client and use the full denominator.
//!
//! Both modes execute through [`fedavg_into`] — the allocation-free,
//! deterministically thread-parallel hot path over the
//! [`super::parallel`] substrate (DESIGN.md §7). Updates arrive as
//! [`DeltaPayload`]s (DESIGN.md §12): an all-dense round takes the
//! historical bit-exact sweep verbatim, while compressed payloads are
//! folded by a fused dequantize-accumulate sweep that reads packed
//! values in place — sparse and q8 updates are never expanded to dense
//! tensors before aggregation.

use super::codec::{column_ranks, DeltaPayload};
use super::parallel::{for_each_chunk2_mut, AggScratch, CHUNK};
use crate::dropout::MaskSet;
use crate::model::ModelSpec;
use crate::tensor::Tensor;

/// One client's contribution to a round.
pub struct ClientUpdate {
    /// The update's tensors, in whatever representation the
    /// experiment's codec produced. [`DeltaPayload::DenseF32`] is the
    /// bit-exact reference; compressed payloads aggregate in place.
    pub payload: DeltaPayload,
    /// FedAvg weight (number of local examples)
    pub weight: f64,
    pub mask: MaskSet,
    /// rounds elapsed since the global params this update was trained
    /// from were broadcast. 0 = synchronous (the usual case); > 0 for
    /// buffered semi-async updates that missed their round's barrier and
    /// fold into a later aggregation ([`staleness_discount`]).
    pub staleness: usize,
}

impl ClientUpdate {
    /// The dense tensors of a [`DeltaPayload::DenseF32`] update.
    /// Reference/test accessor: panics on compressed payloads.
    pub fn dense_params(&self) -> &[Tensor] {
        match &self.payload {
            DeltaPayload::DenseF32(ts) => ts,
            other => panic!("dense_params on a {:?} payload", other.mode()),
        }
    }
}

/// Staleness discount for semi-async aggregation: a polynomial decay
/// `1/sqrt(1+s)` (the FedBuff/FedAsync family's standard choice — gentle
/// enough that one-round-late updates still contribute, strong enough
/// that ancient updates cannot drag the global model back).
///
/// Exactly 1.0 at s = 0 so synchronous aggregation is untouched.
pub fn staleness_discount(staleness: usize) -> f64 {
    if staleness == 0 {
        1.0
    } else {
        1.0 / (1.0 + staleness as f64).sqrt()
    }
}

/// Effective FedAvg weight of an update after staleness discounting.
/// Skips the multiply entirely for fresh updates, so synchronous rounds
/// are bit-identical to pre-staleness aggregation.
fn effective_weight(u: &ClientUpdate) -> f64 {
    if u.staleness == 0 {
        u.weight
    } else {
        u.weight * staleness_discount(u.staleness)
    }
}

/// Apply a mitigation policy's `weigh()` multiplier to a FedAvg weight.
/// Skips the multiply entirely at 1.0, so every policy that does not
/// re-weight (the whole FLuID family) costs zero float ops here and the
/// pre-seam trajectories stay bit-identical.
pub fn policy_weight(base: f64, multiplier: f64) -> f64 {
    if multiplier == 1.0 {
        base
    } else {
        base * multiplier
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregateMode {
    Plain,
    OwnershipWeighted,
}

/// For parameter `p_idx`, return `(group_idx, per_neuron_span)` when its
/// elements map onto a maskable group:
/// * group weight `{g}_w`-like: trailing dim == group size (neuron = col)
///   or == 4x group size (LSTM gates, neuron = col % H)
/// * group bias: 1-D with the same correspondence
pub(crate) fn group_of_param(spec: &ModelSpec, p_idx: usize) -> Option<(usize, usize)> {
    let p = &spec.params[p_idx];
    let prefix: &str = p
        .name
        .rsplit_once('_')
        .map(|(a, _)| a)
        .unwrap_or(&p.name);
    let g = spec.mask_index(prefix)?;
    let n = spec.masks[g].size;
    let cols = *p.shape.last()?;
    if cols == n {
        Some((g, 1))
    } else if cols == 4 * n {
        Some((g, 4)) // LSTM i|f|g|o blocks of H
    } else {
        None
    }
}

/// neuron index for a flat element index of a param with trailing dim
/// `cols`, group size `n` and span (1 = direct, 4 = LSTM gates).
#[inline]
pub(crate) fn neuron_of(elem: usize, cols: usize, n: usize, span: usize) -> usize {
    let col = elem % cols;
    if span == 1 {
        col
    } else {
        col % n
    }
}

/// Aggregate client updates into new global parameters.
///
/// Serial convenience entry: a one-line delegation to [`fedavg_into`]
/// with a throwaway scratch arena and a single thread — bit-identical to
/// the engine's pooled path (pinned by the thread-count property test),
/// just slower. Round loops should hold an [`AggScratch`] and call
/// [`fedavg_into`].
pub fn fedavg(
    spec: &ModelSpec,
    global: &[Tensor],
    updates: &[ClientUpdate],
    mode: AggregateMode,
) -> Vec<Tensor> {
    fedavg_into(spec, global, updates, mode, 1, &mut AggScratch::new())
}

/// Masked FedAvg through the allocation-free, thread-parallel hot path
/// (DESIGN.md §7).
///
/// Dispatch: a round whose updates are all [`DeltaPayload::DenseF32`]
/// (every pinned trajectory) takes the historical dense sweep verbatim —
/// same chunking, same fold order, bit-identical. Any compressed payload
/// routes the whole round through the payload sweep, which computes each
/// element's f32 value from its packed representation (kept value,
/// `global + scale * q`, or the broadcast global for dropped columns)
/// and then accumulates in f64 **with the same expressions and update
/// order as the dense sweep** — aggregating payloads directly equals
/// aggregating their unpacked tensors, bit for bit (pinned in
/// `tests/properties.rs`).
///
/// Three structural changes over the historical per-element loop, all of
/// them bit-preserving:
///
/// * **Per-neuron denominator factorization** — an update's ownership of
///   an element depends only on the element's column, so the per-update
///   kept-column weight vector (`w` where kept, exactly `0.0` where
///   dropped, expanded across LSTM's 4H gate layout) is built once in
///   O(cols), and per-column denominators accumulate in O(cols) per
///   update instead of O(len). The element sweep then streams rows
///   against those vectors — no per-element neuron mapping, no mask
///   indirection; a dropped column is skipped exactly as the historical
///   loop skipped it (the skip tests the cached weight, so a degenerate
///   zero-weight update is skipped where the old loop added its exact
///   zero — indistinguishable for finite data).
/// * **Arena reuse** — accumulators, weight vectors and the output
///   tensors themselves come from `scratch`; after the first round the
///   inner path performs zero heap allocations (pinned by
///   `tests/alloc_gate.rs`).
/// * **Deterministic chunked parallelism** — the element sweep is split
///   at fixed row-aligned chunk boundaries ([`CHUNK`]-sized, independent
///   of `threads`); each chunk folds updates in order and finalizes its
///   own cache-hot f32 output in the same sweep, so the result is
///   bit-identical for every thread count.
///
/// Every element's additions happen in update order — the same f64
/// addition order as the historical implementation — so the classic
/// path's results are preserved exactly.
pub fn fedavg_into(
    spec: &ModelSpec,
    global: &[Tensor],
    updates: &[ClientUpdate],
    mode: AggregateMode,
    threads: usize,
    scratch: &mut AggScratch,
) -> Vec<Tensor> {
    assert!(!updates.is_empty(), "fedavg with no updates");
    if updates.iter().all(|u| u.payload.is_dense()) {
        fedavg_dense_into(spec, global, updates, mode, threads, scratch)
    } else {
        fedavg_payload_into(spec, global, updates, mode, threads, scratch)
    }
}

/// The dense tensors of an update on the all-dense fast path (the
/// dispatcher has already checked every payload).
#[inline]
fn dense(u: &ClientUpdate) -> &[Tensor] {
    match &u.payload {
        DeltaPayload::DenseF32(ts) => ts,
        _ => unreachable!("dense fast path requires DenseF32 payloads"),
    }
}

/// The historical all-dense sweep — the bit-exact determinism reference.
fn fedavg_dense_into(
    spec: &ModelSpec,
    global: &[Tensor],
    updates: &[ClientUpdate],
    mode: AggregateMode,
    threads: usize,
    scratch: &mut AggScratch,
) -> Vec<Tensor> {
    let mut outs: Vec<Tensor> = global.iter().map(|t| scratch.take_out(t.shape())).collect();
    let AggScratch { acc, kw, den, w, .. } = scratch;
    w.clear();
    w.extend(updates.iter().map(effective_weight));
    let total_w: f64 = w.iter().sum();
    assert!(total_w > 0.0);
    let w_s: &[f64] = &w[..];

    for (pi, (g_t, out_t)) in global.iter().zip(outs.iter_mut()).enumerate() {
        let len = g_t.len();
        if len == 0 {
            continue;
        }
        debug_assert!(updates.iter().all(|u| dense(u)[pi].len() == len));
        let cols = *spec.params[pi].shape.last().unwrap_or(&1);
        let group = match mode {
            AggregateMode::Plain => None,
            AggregateMode::OwnershipWeighted => group_of_param(spec, pi),
        };

        match group {
            None => {
                // every client trains every element: the denominator is
                // `total_w` (summed in update order, exactly as the
                // historical per-element accumulation added it). One
                // fused sweep per chunk: fold the updates into the f64
                // accumulator, then finalize that chunk's f32 output
                // while it is still cache-hot.
                acc.clear();
                acc.resize(len, 0.0);
                let o = out_t.data_mut();
                for_each_chunk2_mut(acc.as_mut_slice(), o, CHUNK, threads, |start, a, oc| {
                    for (u, upd) in updates.iter().enumerate() {
                        let d = &dense(upd)[pi].data()[start..start + a.len()];
                        let wu = w_s[u];
                        for (aj, &x) in a.iter_mut().zip(d) {
                            *aj += wu * x as f64;
                        }
                    }
                    for (oj, &aj) in oc.iter_mut().zip(a.iter()) {
                        *oj = (aj / total_w) as f32;
                    }
                });
            }
            Some((gidx, span)) => {
                let n = spec.masks[gidx].size;
                // per-update kept-column weights, expanded across the
                // gate layout: O(cols) per update, not O(len)
                kw.clear();
                kw.resize(updates.len() * cols, 0.0);
                for (u, upd) in updates.iter().enumerate() {
                    let m = upd.mask.tensors()[gidx].data();
                    debug_assert_eq!(m.len(), n);
                    let row = &mut kw[u * cols..(u + 1) * cols];
                    for (c, slot) in row.iter_mut().enumerate() {
                        *slot = if m[neuron_of(c, cols, n, span)] == 1.0 {
                            w_s[u]
                        } else {
                            0.0
                        };
                    }
                }
                // per-column denominators in update order
                den.clear();
                den.resize(cols, 0.0);
                for row in kw.chunks_exact(cols) {
                    for (dc, &k) in den.iter_mut().zip(row) {
                        *dc += k;
                    }
                }
                let kw_s: &[f64] = &kw[..];
                let den_s: &[f64] = &den[..];
                // Stream rows against the kept-weight vectors; chunks
                // are row-aligned so the column phase is always zero,
                // and each chunk finalizes its own f32 output right
                // after folding the updates (one sweep, cache-hot).
                // The `!= 0.0` guard reproduces the historical "skip
                // the masked-out element" exactly — including for
                // non-finite update values, which a `+= 0.0 * x` would
                // instead poison with NaN.
                let chunk = (CHUNK / cols).max(1) * cols;
                acc.clear();
                acc.resize(len, 0.0);
                let g_data = g_t.data();
                let o = out_t.data_mut();
                for_each_chunk2_mut(acc.as_mut_slice(), o, chunk, threads, |start, a, oc| {
                    for (u, upd) in updates.iter().enumerate() {
                        let d = &dense(upd)[pi].data()[start..start + a.len()];
                        let kwu = &kw_s[u * cols..(u + 1) * cols];
                        let mut c = 0usize;
                        for (aj, &x) in a.iter_mut().zip(d) {
                            let k = kwu[c];
                            if k != 0.0 {
                                *aj += k * x as f64;
                            }
                            c += 1;
                            if c == cols {
                                c = 0;
                            }
                        }
                    }
                    let mut c = 0usize;
                    for (k, (oj, &aj)) in oc.iter_mut().zip(a.iter()).enumerate() {
                        *oj = if den_s[c] > 0.0 {
                            (aj / den_s[c]) as f32
                        } else {
                            g_data[start + k] // nobody trained it: keep global
                        };
                        c += 1;
                        if c == cols {
                            c = 0;
                        }
                    }
                });
            }
        }
    }
    outs
}

/// The payload sweep: folds mixed dense / sparse / q8 updates without
/// expanding compressed payloads to dense tensors. Per chunk, each
/// element's f32 value is materialized from its packed representation —
/// a fused dequantize-accumulate — and added in f64 with exactly the
/// dense sweep's expressions and update order, so the result is bitwise
/// equal to running the dense sweep over the unpacked tensors. Packed
/// params sweep wider row-aligned lanes (4x [`CHUNK`]) to amortize the
/// per-chunk rank-map setup over more rows; chunk width cannot change
/// the result (each element's accumulator is touched only by its own
/// chunk).
fn fedavg_payload_into(
    spec: &ModelSpec,
    global: &[Tensor],
    updates: &[ClientUpdate],
    mode: AggregateMode,
    threads: usize,
    scratch: &mut AggScratch,
) -> Vec<Tensor> {
    let mut outs: Vec<Tensor> = global.iter().map(|t| scratch.take_out(t.shape())).collect();
    let AggScratch { acc, kw, den, w, cmap, kept, .. } = scratch;
    w.clear();
    w.extend(updates.iter().map(effective_weight));
    let total_w: f64 = w.iter().sum();
    assert!(total_w > 0.0);
    let w_s: &[f64] = &w[..];

    for (pi, (g_t, out_t)) in global.iter().zip(outs.iter_mut()).enumerate() {
        let len = g_t.len();
        if len == 0 {
            continue;
        }
        let cols = *spec.params[pi].shape.last().unwrap_or(&1);
        // Packed layout is a property of the parameter (kept columns of
        // its mask group), needed to *address* sparse values in every
        // mode; ownership weighting stays mode-gated like the dense path.
        let packing = group_of_param(spec, pi);
        let own = mode == AggregateMode::OwnershipWeighted && packing.is_some();

        if let Some((gidx, span)) = packing {
            let n = spec.masks[gidx].size;
            cmap.clear();
            cmap.resize(updates.len() * cols, 0);
            kept.clear();
            for (u, upd) in updates.iter().enumerate() {
                let m = upd.mask.tensors()[gidx].data();
                debug_assert_eq!(m.len(), n);
                let k = column_ranks(m, cols, n, span, &mut cmap[u * cols..(u + 1) * cols]);
                kept.push(k as u32);
            }
            if own {
                kw.clear();
                kw.resize(updates.len() * cols, 0.0);
                for (u, upd) in updates.iter().enumerate() {
                    let m = upd.mask.tensors()[gidx].data();
                    let row = &mut kw[u * cols..(u + 1) * cols];
                    for (c, slot) in row.iter_mut().enumerate() {
                        *slot = if m[neuron_of(c, cols, n, span)] == 1.0 {
                            w_s[u]
                        } else {
                            0.0
                        };
                    }
                }
                den.clear();
                den.resize(cols, 0.0);
                for row in kw.chunks_exact(cols) {
                    for (dc, &k) in den.iter_mut().zip(row) {
                        *dc += k;
                    }
                }
            }
        }
        let cmap_s: &[u32] = &cmap[..];
        let kept_s: &[u32] = &kept[..];
        let kw_s: &[f64] = &kw[..];
        let den_s: &[f64] = &den[..];

        acc.clear();
        acc.resize(len, 0.0);
        let g_data = g_t.data();
        let o = out_t.data_mut();
        let chunk = if packing.is_some() {
            ((4 * CHUNK) / cols).max(1) * cols // wider lanes, row-aligned
        } else {
            4 * CHUNK
        };
        for_each_chunk2_mut(acc.as_mut_slice(), o, chunk, threads, |start, a, oc| {
            for (u, upd) in updates.iter().enumerate() {
                let wu = w_s[u];
                match &upd.payload {
                    DeltaPayload::DenseF32(ts) => {
                        debug_assert_eq!(ts[pi].len(), len);
                        let d = &ts[pi].data()[start..start + a.len()];
                        if own {
                            let kwu = &kw_s[u * cols..(u + 1) * cols];
                            let mut c = 0usize;
                            for (aj, &x) in a.iter_mut().zip(d) {
                                let k = kwu[c];
                                if k != 0.0 {
                                    *aj += k * x as f64;
                                }
                                c += 1;
                                if c == cols {
                                    c = 0;
                                }
                            }
                        } else {
                            for (aj, &x) in a.iter_mut().zip(d) {
                                *aj += wu * x as f64;
                            }
                        }
                    }
                    DeltaPayload::SparseF32(s) => {
                        let vals = &s.values[pi][..];
                        if packing.is_some() {
                            let ranks = &cmap_s[u * cols..(u + 1) * cols];
                            let kept_u = kept_s[u] as usize;
                            debug_assert_eq!(vals.len(), (len / cols.max(1)) * kept_u);
                            let mut c = 0usize;
                            let mut base = (start / cols) * kept_u;
                            if own {
                                let kwu = &kw_s[u * cols..(u + 1) * cols];
                                for aj in a.iter_mut() {
                                    let k = kwu[c];
                                    if k != 0.0 {
                                        *aj += k * vals[base + ranks[c] as usize] as f64;
                                    }
                                    c += 1;
                                    if c == cols {
                                        c = 0;
                                        base += kept_u;
                                    }
                                }
                            } else {
                                for (e, aj) in a.iter_mut().enumerate() {
                                    let r = ranks[c];
                                    let x = if r != u32::MAX {
                                        vals[base + r as usize]
                                    } else {
                                        g_data[start + e] // dropped: the invariant's value
                                    };
                                    *aj += wu * x as f64;
                                    c += 1;
                                    if c == cols {
                                        c = 0;
                                        base += kept_u;
                                    }
                                }
                            }
                        } else {
                            debug_assert_eq!(vals.len(), len);
                            let d = &vals[start..start + a.len()];
                            for (aj, &x) in a.iter_mut().zip(d) {
                                *aj += wu * x as f64;
                            }
                        }
                    }
                    DeltaPayload::SparseQ8(q) => {
                        let vals = &q.values[pi][..];
                        let sc = q.scales[pi];
                        if packing.is_some() {
                            let ranks = &cmap_s[u * cols..(u + 1) * cols];
                            let kept_u = kept_s[u] as usize;
                            debug_assert_eq!(vals.len(), (len / cols.max(1)) * kept_u);
                            let mut c = 0usize;
                            let mut base = (start / cols) * kept_u;
                            if own {
                                let kwu = &kw_s[u * cols..(u + 1) * cols];
                                for (e, aj) in a.iter_mut().enumerate() {
                                    let k = kwu[c];
                                    if k != 0.0 {
                                        let qv = vals[base + ranks[c] as usize];
                                        let x = g_data[start + e] + sc * qv as f32;
                                        *aj += k * x as f64;
                                    }
                                    c += 1;
                                    if c == cols {
                                        c = 0;
                                        base += kept_u;
                                    }
                                }
                            } else {
                                for (e, aj) in a.iter_mut().enumerate() {
                                    let r = ranks[c];
                                    let x = if r != u32::MAX {
                                        g_data[start + e] + sc * vals[base + r as usize] as f32
                                    } else {
                                        g_data[start + e]
                                    };
                                    *aj += wu * x as f64;
                                    c += 1;
                                    if c == cols {
                                        c = 0;
                                        base += kept_u;
                                    }
                                }
                            }
                        } else {
                            debug_assert_eq!(vals.len(), len);
                            for (e, aj) in a.iter_mut().enumerate() {
                                let x = g_data[start + e] + sc * vals[start + e] as f32;
                                *aj += wu * x as f64;
                            }
                        }
                    }
                }
            }
            if own {
                let mut c = 0usize;
                for (e, (oj, &aj)) in oc.iter_mut().zip(a.iter()).enumerate() {
                    *oj = if den_s[c] > 0.0 {
                        (aj / den_s[c]) as f32
                    } else {
                        g_data[start + e] // nobody trained it: keep global
                    };
                    c += 1;
                    if c == cols {
                        c = 0;
                    }
                }
            } else {
                for (oj, &aj) in oc.iter_mut().zip(a.iter()) {
                    *oj = (aj / total_w) as f32;
                }
            }
        });
    }
    outs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    fn constant_params(spec: &ModelSpec, v: f32) -> Vec<Tensor> {
        spec.params
            .iter()
            .map(|p| Tensor::full(&p.shape, v))
            .collect()
    }

    #[test]
    fn plain_is_weighted_mean() {
        let spec = tiny_spec();
        let global = constant_params(&spec, 0.0);
        let updates = vec![
            ClientUpdate {
                payload: DeltaPayload::DenseF32(constant_params(&spec, 1.0)),
                weight: 1.0,
                mask: MaskSet::full(&spec),
                staleness: 0,
            },
            ClientUpdate {
                payload: DeltaPayload::DenseF32(constant_params(&spec, 4.0)),
                weight: 3.0,
                mask: MaskSet::full(&spec),
                staleness: 0,
            },
        ];
        let out = fedavg(&spec, &global, &updates, AggregateMode::Plain);
        for t in &out {
            for &x in t.data() {
                assert!((x - 3.25).abs() < 1e-6); // (1 + 12) / 4
            }
        }
    }

    #[test]
    fn ownership_excludes_masked_clients() {
        let spec = tiny_spec();
        let global = constant_params(&spec, 0.5);
        // client A trains everything to 1.0; client B is a straggler whose
        // mask drops fc1 neurons 5..10 — its fc1 columns 5..10 stay at the
        // broadcast 0.5, and must NOT dilute A's update.
        let mut keep = vec![vec![true; 10], vec![true; 6]];
        for k in keep[0].iter_mut().skip(5) {
            *k = false;
        }
        let b_mask = MaskSet::from_keep(&spec, &keep);
        let updates = vec![
            ClientUpdate {
                payload: DeltaPayload::DenseF32(constant_params(&spec, 1.0)),
                weight: 1.0,
                mask: MaskSet::full(&spec),
                staleness: 0,
            },
            ClientUpdate {
                payload: DeltaPayload::DenseF32({
                    // straggler: trained kept cols to 1.0, dropped cols
                    // still at broadcast 0.5
                    let mut ps = constant_params(&spec, 1.0);
                    let (rows, cols) = (8usize, 10usize);
                    let w = ps[0].data_mut();
                    for r in 0..rows {
                        for c in 5..cols {
                            w[r * cols + c] = 0.5;
                        }
                    }
                    let b = ps[1].data_mut();
                    for c in 5..10 {
                        b[c] = 0.5;
                    }
                    ps
                }),
                weight: 1.0,
                mask: b_mask,
                staleness: 0,
            },
        ];
        let out = fedavg(&spec, &global, &updates, AggregateMode::OwnershipWeighted);
        // fc1_w col 0 (both trained): mean(1, 1) = 1
        assert!((out[0].data()[0] - 1.0).abs() < 1e-6);
        // fc1_w col 7 (only A trained): 1.0, not (1+0.5)/2
        assert!((out[0].data()[7] - 1.0).abs() < 1e-6);
        // fc1_b entry 7 likewise
        assert!((out[1].data()[7] - 1.0).abs() < 1e-6);
        // compare: plain mode dilutes
        let plain = fedavg(&spec, &global, &updates, AggregateMode::Plain);
        assert!((plain[0].data()[7] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn nobody_trained_keeps_global() {
        let spec = tiny_spec();
        let global = constant_params(&spec, 0.5);
        let mut keep = vec![vec![true; 10], vec![true; 6]];
        keep[0][9] = false;
        let m = MaskSet::from_keep(&spec, &keep);
        let updates = vec![ClientUpdate {
            payload: DeltaPayload::DenseF32(constant_params(&spec, 2.0)),
            weight: 1.0,
            mask: m,
            staleness: 0,
        }];
        let out = fedavg(&spec, &global, &updates, AggregateMode::OwnershipWeighted);
        // col 9 untrained by the only client -> keep global 0.5
        assert!((out[0].data()[9] - 0.5).abs() < 1e-6);
        assert!((out[0].data()[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn group_mapping_detects_w_and_b() {
        let spec = tiny_spec();
        // fc1_w [8,10] -> group 0 span 1; fc1_b [10] -> group 0
        assert_eq!(group_of_param(&spec, 0), Some((0, 1)));
        assert_eq!(group_of_param(&spec, 1), Some((0, 1)));
        // fc2_w [10,6] -> group fc2
        assert_eq!(group_of_param(&spec, 2), Some((1, 1)));
        // out_w [6,3]: "out" is not a mask group
        assert_eq!(group_of_param(&spec, 4), None);
    }

    #[test]
    fn lstm_gate_span() {
        assert_eq!(neuron_of(0, 512, 128, 4), 0);
        assert_eq!(neuron_of(128, 512, 128, 4), 0); // f-gate col of neuron 0
        assert_eq!(neuron_of(130, 512, 128, 4), 2);
        assert_eq!(neuron_of(512 + 5, 512, 128, 4), 5); // next row
    }

    #[test]
    #[should_panic(expected = "no updates")]
    fn empty_updates_panics() {
        let spec = tiny_spec();
        let global = constant_params(&spec, 0.0);
        fedavg(&spec, &global, &[], AggregateMode::Plain);
    }

    #[test]
    fn staleness_discount_shape() {
        assert_eq!(staleness_discount(0), 1.0);
        let d1 = staleness_discount(1);
        let d4 = staleness_discount(4);
        assert!((d1 - 1.0 / 2f64.sqrt()).abs() < 1e-12);
        assert!(d4 < d1 && d1 < 1.0);
        assert!(d4 > 0.0);
    }

    #[test]
    fn stale_update_contributes_less_than_fresh() {
        let spec = tiny_spec();
        let global = constant_params(&spec, 0.0);
        let mk = |v: f32, staleness: usize| ClientUpdate {
            payload: DeltaPayload::DenseF32(constant_params(&spec, v)),
            weight: 1.0,
            mask: MaskSet::full(&spec),
            staleness,
        };
        // fresh at 0.0, stale at 4.0: a synchronous pair would average to
        // 2.0; discounting the stale half must land strictly below that.
        let out = fedavg(
            &spec,
            &global,
            &[mk(0.0, 0), mk(4.0, 3)],
            AggregateMode::Plain,
        );
        let d = staleness_discount(3);
        let want = (4.0 * d / (1.0 + d)) as f32;
        for t in &out {
            for &x in t.data() {
                assert!((x - want).abs() < 1e-5, "{x} vs {want}");
                assert!(x < 2.0);
            }
        }
        // staleness 0 everywhere reproduces the plain weighted mean
        let sync = fedavg(
            &spec,
            &global,
            &[mk(0.0, 0), mk(4.0, 0)],
            AggregateMode::Plain,
        );
        assert!((sync[0].data()[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn payload_fedavg_matches_dense_over_unpacked_tensors() {
        use super::super::codec::{pack_sparse, Codec, Compression, UpdateCodec};

        let spec = tiny_spec();
        let global = spec.init_params(42);
        // three clients: full, straggler (half mask), straggler (other mask)
        let full = MaskSet::full(&spec);
        let mut keep = vec![vec![true; 10], vec![true; 6]];
        for k in keep[0].iter_mut().skip(5) {
            *k = false;
        }
        keep[1][0] = false;
        let half = MaskSet::from_keep(&spec, &keep);
        let masks = [full, half.clone(), half];
        let mut scratch = AggScratch::new();
        let mut q8 = Codec::new(Compression::Q8);

        // params obey the invariant: dropped columns == broadcast global
        let mut dense_updates = Vec::new();
        let mut payload_updates = Vec::new();
        for (ci, mask) in masks.iter().enumerate() {
            let mut ps = global.clone();
            for (pi, t) in ps.iter_mut().enumerate() {
                let cols = *spec.params[pi].shape.last().unwrap_or(&1);
                let trained = |e: usize| match group_of_param(&spec, pi) {
                    Some((gidx, span)) => {
                        let n = spec.masks[gidx].size;
                        mask.tensors()[gidx].data()[neuron_of(e % cols, cols, n, span)] == 1.0
                    }
                    None => true,
                };
                for (e, x) in t.data_mut().iter_mut().enumerate() {
                    if trained(e) {
                        *x += 0.125 * (1 + (ci + e) % 5) as f32;
                    }
                }
            }
            // payloads: client 0 dense, 1 sparse, 2 q8 — a mixed round
            let payload = match ci {
                0 => DeltaPayload::DenseF32(ps.clone()),
                1 => DeltaPayload::SparseF32(pack_sparse(&spec, &ps, mask, &mut scratch)),
                _ => q8.encode(ci as u64, ps.clone(), mask, &global, &spec, &mut scratch),
            };
            // the dense reference aggregates the exact tensors each
            // payload reconstructs to
            let unpacked = super::super::codec::unpack(
                payload.clone(),
                mask,
                &global,
                &spec,
                &mut scratch,
            )
            .unwrap();
            dense_updates.push(ClientUpdate {
                payload: DeltaPayload::DenseF32(unpacked),
                weight: (ci + 1) as f64,
                mask: mask.clone(),
                staleness: ci % 2,
            });
            payload_updates.push(ClientUpdate {
                payload,
                weight: (ci + 1) as f64,
                mask: mask.clone(),
                staleness: ci % 2,
            });
        }

        for mode in [AggregateMode::Plain, AggregateMode::OwnershipWeighted] {
            for threads in [1usize, 4] {
                let want = fedavg_into(
                    &spec,
                    &global,
                    &dense_updates,
                    mode,
                    threads,
                    &mut AggScratch::new(),
                );
                let got = fedavg_into(
                    &spec,
                    &global,
                    &payload_updates,
                    mode,
                    threads,
                    &mut AggScratch::new(),
                );
                for (a, b) in got.iter().zip(&want) {
                    for (x, y) in a.data().iter().zip(b.data()) {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "mode {mode:?} threads {threads}"
                        );
                    }
                }
            }
        }
    }
}
