//! Sharded multi-aggregator execution behind the [`ClientExecutor`]
//! seam (DESIGN.md §11).
//!
//! [`ShardedExecutor`] splits each round's cohort into `N` contiguous
//! slices — shard `s` owns jobs `[s·n/N, (s+1)·n/N)` — and runs every
//! slice on its own scoped worker thread against the wrapped inner
//! executor. Each shard packages its slice's per-client outcomes into a
//! framed [`wire::ShardMessage`] and ships it to the root over a
//! [`wire::FrameTx`] channel; the root folds the shard messages through
//! [`tree_reduce`]'s fixed pairwise chunk order and hands the engine one
//! job-aligned result vector.
//!
//! **Bit-identity contract.** Per-client work is a pure function of
//! `(global params, job)` for every in-tree backend, so the finest
//! *exact-mergeable* partial a shard can contribute is its ordered slice
//! of per-client results — floats travel as raw bit patterns and
//! concatenation of contiguous slices is associative, which is what
//! makes the `tree_reduce` fold order-preserving at every shard count.
//! The floating-point reductions themselves (masked FedAvg, invariant
//! observation) then run at the root through the *same* fixed-CHUNK
//! engine code a single-engine run uses; a per-shard float pre-sum would
//! break bit-identity the moment the shard count changed, and is exactly
//! what this design refuses to do. Net effect: every report is
//! bit-identical across `--shards` ∈ {1, 2, 4, 8, …}, every `--threads`
//! value and every `SyncMode` (pinned by `tests/sharded_determinism.rs`).
//!
//! Because the engine above this seam is unchanged, snapshots carry no
//! shard state at all — a checkpoint taken under N shards resumes
//! bit-identically under M shards (the N→M rule, DESIGN.md §11).
//!
//! **Fault injection.** Two sources feed the same recovery machinery.
//! The legacy deterministic kill `crash = Some((shard, round))` fires
//! `crash_times` faults (default 1) the first time that shard starts
//! round ≥ `round`; a seeded [`ChaosPlan`] instead draws at most one
//! shard event per round in virtual slot space (`slot % shards`), so
//! the fault *schedule* is shard-count invariant. Either way the doomed
//! worker sends a [`wire::ShardMessage::Fault`] frame instead of
//! results, and the root resolves it against a bounded **retry budget**
//! (`--shard-retry-max`): each attempt re-checks the fault (a chaos
//! `Crash` kills the restarted worker once more; `StallOnce` recovers
//! on the first retry), accrues a deterministic virtual-time backoff
//! ([`chaos::retry_backoff_ms`], drained by the engine once per round
//! via [`ClientExecutor::drain_fault_retries`]) and finally
//! re-dispatches *only the dead shard's slice* on the root's own inner
//! executor — purity makes the retried slice bit-identical to what the
//! shard would have produced. A budget of 0, or exhaustion, fails the
//! slice cleanly: every slot surfaces a typed [`ShardFault`] error,
//! which the engine propagates *before* touching any global state, so
//! nothing partial leaks into the model.
//!
//! **Compressed slices.** Under `--compress sparse|q8` each worker ships
//! its slice as a [`wire::ShardMessage::Packed`] of kept-column sparse
//! payloads ([`crate::fl::pack_result`]) and the root reconstructs dense
//! results at decode. The shard wire always carries the *sparse* (not
//! quantized) packing: q8's error-feedback residuals live in the root
//! engine's codec, and keeping the wire stateless is what preserves the
//! N→M resume rule for compressed runs. On a retried slice the root
//! round-trips the re-run results through the same pack/unpack, so a
//! fault-retry round stays bit-identical to the wire path.

use crate::data::Split;
use crate::dropout::MaskSet;
use crate::fl::codec::{pack_result, unpack_result, Compression};
use crate::fl::parallel::tree_reduce;
use crate::fl::{AggScratch, Client, LocalResult};
use crate::model::ModelSpec;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use super::chaos::{self, ChaosPlan, ShardFaultKind};
use super::executor::{ClientExecutor, TrainJob};
use super::wire::{self, FrameRx, FrameTx, ShardMessage};

/// Marker error for shard-level fault injection: a shard was killed
/// mid-round and retry is disabled, so its slice of the round is lost.
/// The engine aborts the round before any aggregation or observation
/// runs; the `fluid` binary downcasts to this and exits 137, exactly
/// like [`super::FaultInjected`].
#[derive(Debug, Clone, Copy)]
pub struct ShardFault {
    /// which shard died
    pub shard: usize,
    /// the round it was executing
    pub round: usize,
}

impl std::fmt::Display for ShardFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shard {} killed mid-round {}: its cohort slice was lost",
            self.shard, self.round
        )
    }
}

impl std::error::Error for ShardFault {}

/// Per-shard reusable buffers: encode staging + finished frame on the
/// shard side, receive buffer + tensor-pool scratch on the root side.
/// One lane per shard keeps the root's parallel decode contention-free.
#[derive(Default)]
struct ShardLane {
    blob: Vec<u8>,
    frame: Vec<u8>,
    rx_buf: Vec<u8>,
    scratch: AggScratch,
}

/// Multi-aggregator tree over an inner [`ClientExecutor`]: N shard
/// workers, wire-framed shard→root messages, deterministic root fold.
pub struct ShardedExecutor<E> {
    inner: E,
    shards: usize,
    /// kill `(shard, round)`: that shard dies the first time it starts
    /// a round with index ≥ `round`
    crash: Option<(usize, usize)>,
    /// how many faults the injected `crash` has left to fire (default 1;
    /// [`Self::with_crash_times`] raises it to model a shard whose
    /// restart dies again)
    fires_left: AtomicUsize,
    /// bounded per-round retry budget: 0 fails a faulted slice outright,
    /// N re-dispatches it up to N times before surfacing [`ShardFault`]
    retry_budget: usize,
    /// seeded shard-event schedule (chaos `Crash`/`StallOnce`)
    chaos: Option<ChaosPlan>,
    /// per-round chaos fault bookkeeping: bits 8.. hold `round + 1`,
    /// bits 0..8 count fires consumed that round (resets on round change)
    chaos_fired: AtomicU64,
    /// slice re-dispatches since the last [`Self::drain_fault_retries`]
    retries: AtomicUsize,
    /// deterministic virtual-time backoff accrued since the last drain
    backoff_ms: AtomicU64,
    /// how workers represent their slices on the wire (`Dense` ships
    /// classic [`ShardMessage::Results`]; the compressed modes ship
    /// sparse [`ShardMessage::Packed`] slices)
    compression: Compression,
    lanes: Vec<Mutex<ShardLane>>,
}

/// Shard `s`'s contiguous slice of an `n`-job round under `shards`
/// shards. Depends only on `(n, shards, s)` — never on thread timing —
/// so the partition itself is deterministic.
fn slice_bounds(n: usize, shards: usize, s: usize) -> (usize, usize) {
    (s * n / shards, (s + 1) * n / shards)
}

/// A slice's worth of per-slot copies of one error.
fn err_slice<T, F: Fn() -> anyhow::Error>(len: usize, make: F) -> Vec<crate::Result<T>> {
    (0..len).map(|_| Err(make())).collect()
}

impl<E: ClientExecutor> ShardedExecutor<E> {
    pub fn new(inner: E, shards: usize) -> Self {
        Self::with_fault(inner, shards, None, false)
    }

    /// Build with shard-level fault injection (see the module docs).
    /// `retry` is the legacy single-shot switch: it seeds a retry budget
    /// of 1 ([`Self::with_retry_budget`] deepens it).
    pub fn with_fault(
        inner: E,
        shards: usize,
        crash_after: Option<(usize, usize)>,
        retry: bool,
    ) -> Self {
        let shards = shards.max(1);
        Self {
            inner,
            shards,
            crash: crash_after,
            fires_left: AtomicUsize::new(1),
            retry_budget: usize::from(retry),
            chaos: None,
            chaos_fired: AtomicU64::new(0),
            retries: AtomicUsize::new(0),
            backoff_ms: AtomicU64::new(0),
            compression: Compression::Dense,
            lanes: (0..shards).map(|_| Mutex::new(ShardLane::default())).collect(),
        }
    }

    /// Select the wire representation of shard slices (builder style).
    pub fn with_compression(mut self, compression: Compression) -> Self {
        self.compression = compression;
        self
    }

    /// Cap the per-round slice re-dispatch budget (builder style).
    /// `--shard-retry-max N` lands here; 0 disables retry entirely.
    pub fn with_retry_budget(mut self, budget: usize) -> Self {
        self.retry_budget = budget;
        self
    }

    /// Attach a seeded chaos schedule for shard events (builder style).
    pub fn with_chaos(mut self, plan: Option<ChaosPlan>) -> Self {
        self.chaos = plan;
        self
    }

    /// How many times the injected `crash` fires before the shard stays
    /// up (builder style; default 1). `times = 2` models a shard whose
    /// restart dies again — the double-fault regression case.
    pub fn with_crash_times(self, times: usize) -> Self {
        self.fires_left.store(times, Ordering::SeqCst);
        self
    }

    pub fn shard_count(&self) -> usize {
        self.shards
    }

    /// Does a fault fire for `shard` at `round`? Checked by the worker
    /// before it runs its slice and re-checked by the root on every
    /// retry attempt, so each call consumes one fire. The legacy crash
    /// burns down `fires_left`; chaos events budget their fires per
    /// round (`Crash` = 2 — the restarted worker dies once more —
    /// `StallOnce` = 1) and reset when the round changes.
    fn fault_fires(&self, shard: usize, round: Option<usize>) -> bool {
        if let (Some((cs, after)), Some(r)) = (self.crash, round) {
            if cs == shard
                && r >= after
                && self
                    .fires_left
                    .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| v.checked_sub(1))
                    .is_ok()
            {
                return true;
            }
        }
        self.chaos_fires(shard, round)
    }

    /// Chaos half of [`Self::fault_fires`]: does this round's seeded
    /// shard event (if any) land on `shard`, with fires left to spend?
    /// Only one shard per round can match (`slot % shards`), so the
    /// counter is effectively single-writer within a round.
    fn chaos_fires(&self, shard: usize, round: Option<usize>) -> bool {
        let (plan, r) = match (&self.chaos, round) {
            (Some(p), Some(r)) => (p, r),
            _ => return false,
        };
        let ev = match plan.shard_event(r) {
            Some(ev) => ev,
            None => return false,
        };
        if (ev.slot % self.shards as u64) as usize != shard {
            return false;
        }
        let fires: u64 = match ev.kind {
            ShardFaultKind::Crash => 2,
            ShardFaultKind::StallOnce => 1,
        };
        let key = (r as u64 + 1) << 8;
        self.chaos_fired
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                let used = if v & !0xff == key { v & 0xff } else { 0 };
                (used < fires).then_some(key | (used + 1))
            })
            .is_ok()
    }
}

impl<E: ClientExecutor> ClientExecutor for ShardedExecutor<E> {
    fn spec(&self) -> &ModelSpec {
        self.inner.spec()
    }

    fn threads(&self) -> usize {
        self.inner.threads()
    }

    fn drain_fault_retries(&self) -> (usize, u64) {
        (
            self.retries.swap(0, Ordering::SeqCst),
            self.backoff_ms.swap(0, Ordering::SeqCst),
        )
    }

    fn run_clients(
        &self,
        cohort: &[&Client],
        masks: &[&MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>> {
        let n = jobs.len();
        let shards = self.shards;
        let round = jobs.first().map(|j| j.round);

        // dispatch: one scoped worker + one frame channel per shard
        let mut txs = Vec::with_capacity(shards);
        let mut rxs = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = wire::mem_channel();
            txs.push(tx);
            rxs.push(rx);
        }
        std::thread::scope(|scope| {
            for (s, mut tx) in txs.into_iter().enumerate() {
                scope.spawn(move || {
                    let (lo, hi) = slice_bounds(n, shards, s);
                    // the lane is this shard's private buffer set; the
                    // root only touches it after every worker has joined
                    let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
                    let lane = &mut *lane;
                    let msg = if self.fault_fires(s, round) {
                        ShardMessage::Fault { shard: s, round: round.unwrap_or(0) }
                    } else if self.compression == Compression::Dense {
                        let items = self
                            .inner
                            .run_clients(&cohort[lo..hi], &masks[lo..hi], params, &jobs[lo..hi])
                            .into_iter()
                            .map(|r| r.map_err(|e| format!("{e:#}")))
                            .collect();
                        ShardMessage::Results {
                            shard: s,
                            round: round.unwrap_or(0),
                            base: lo,
                            items,
                        }
                    } else {
                        let items = self
                            .inner
                            .run_client_payloads(
                                &cohort[lo..hi],
                                &masks[lo..hi],
                                params,
                                &jobs[lo..hi],
                                self.compression,
                                &mut lane.scratch,
                            )
                            .into_iter()
                            .map(|r| r.map_err(|e| format!("{e:#}")))
                            .collect();
                        ShardMessage::Packed {
                            shard: s,
                            round: round.unwrap_or(0),
                            base: lo,
                            items,
                        }
                    };
                    wire::encode_message(&msg, &mut lane.blob, &mut lane.frame);
                    let _ = tx.send(&lane.frame);
                });
            }
        });

        // collect exactly one frame per shard into that shard's lane
        let mut recvs: Vec<crate::Result<()>> = Vec::with_capacity(shards);
        for (s, mut rx) in rxs.into_iter().enumerate() {
            let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
            recvs.push(rx.recv_into(&mut lane.rx_buf));
        }

        // root fold: decode each shard's slice and combine through the
        // fixed pairwise tree — ordered concatenation of contiguous
        // slices, so the output is job-aligned at every shard count
        let decode_shard = |s: usize| -> Vec<crate::Result<LocalResult>> {
            let (lo, hi) = slice_bounds(n, shards, s);
            let want = hi - lo;
            if let Err(e) = &recvs[s] {
                return err_slice(want, || anyhow::anyhow!("shard {s} transport failed: {e:#}"));
            }
            let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
            let lane = &mut *lane;
            match wire::decode_message(&lane.rx_buf, &mut lane.scratch) {
                Ok(ShardMessage::Results { base, items, .. })
                    if base == lo && items.len() == want =>
                {
                    items
                        .into_iter()
                        .map(|r| r.map_err(|e| anyhow::anyhow!(e)))
                        .collect()
                }
                Ok(ShardMessage::Packed { base, items, .. })
                    if base == lo && items.len() == want =>
                {
                    let spec = self.inner.spec();
                    items
                        .into_iter()
                        .enumerate()
                        .map(|(i, r)| match r {
                            Ok(pr) => {
                                unpack_result(pr, masks[lo + i], params, spec, &mut lane.scratch)
                            }
                            Err(e) => Err(anyhow::anyhow!(e)),
                        })
                        .collect()
                }
                Ok(ShardMessage::Fault { shard, round: fault_round }) => {
                    let mut attempts = 0usize;
                    loop {
                        if attempts >= self.retry_budget {
                            // budget exhausted (or zero): fail the slice
                            // cleanly with the typed error
                            break err_slice(want, || {
                                anyhow::Error::new(ShardFault { shard, round: fault_round })
                            });
                        }
                        attempts += 1;
                        self.retries.fetch_add(1, Ordering::SeqCst);
                        self.backoff_ms
                            .fetch_add(chaos::retry_backoff_ms(attempts), Ordering::SeqCst);
                        if self.fault_fires(shard, round) {
                            // the restarted worker died again: spend
                            // another attempt from the budget
                            continue;
                        }
                        // purity makes the retried slice bit-identical
                        // to what the dead shard would have sent
                        let rerun = self.inner.run_clients(
                            &cohort[lo..hi],
                            &masks[lo..hi],
                            params,
                            &jobs[lo..hi],
                        );
                        break if self.compression == Compression::Dense {
                            rerun
                        } else {
                            // round-trip through the codec so the retried
                            // slice is bit-identical to the wire path's
                            // pack → frame → unpack reconstruction
                            let spec = self.inner.spec();
                            rerun
                                .into_iter()
                                .enumerate()
                                .map(|(i, r)| {
                                    r.and_then(|res| {
                                        let pr = pack_result(
                                            res,
                                            masks[lo + i],
                                            spec,
                                            self.compression,
                                            &mut lane.scratch,
                                        );
                                        unpack_result(
                                            pr,
                                            masks[lo + i],
                                            params,
                                            spec,
                                            &mut lane.scratch,
                                        )
                                    })
                                })
                                .collect()
                        };
                    }
                }
                Ok(_) => err_slice(want, || anyhow::anyhow!("shard {s} sent a malformed slice")),
                Err(e) => err_slice(want, || anyhow::anyhow!("shard {s} frame rejected: {e:#}")),
            }
        };
        let parts = tree_reduce(
            shards,
            1,
            self.inner.threads(),
            |s, _| vec![(slice_bounds(n, shards, s).0, decode_shard(s))],
            |mut a, mut b| {
                a.append(&mut b);
                a
            },
        )
        .unwrap_or_default();

        let mut out = Vec::with_capacity(n);
        for (base, items) in parts {
            debug_assert_eq!(base, out.len(), "shard slices must concatenate in order");
            out.extend(items);
        }
        debug_assert_eq!(out.len(), n, "every job produced exactly one slot");
        out
    }

    fn run_deltas(&self, old: &[Tensor], news: &[&[Tensor]]) -> Vec<crate::Result<Vec<Tensor>>> {
        let n = news.len();
        let shards = self.shards;

        let mut txs = Vec::with_capacity(shards);
        let mut rxs = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = wire::mem_channel();
            txs.push(tx);
            rxs.push(rx);
        }
        std::thread::scope(|scope| {
            for (s, mut tx) in txs.into_iter().enumerate() {
                scope.spawn(move || {
                    let (lo, hi) = slice_bounds(n, shards, s);
                    let items = self
                        .inner
                        .run_deltas(old, &news[lo..hi])
                        .into_iter()
                        .map(|r| r.map_err(|e| format!("{e:#}")))
                        .collect();
                    let msg = ShardMessage::Deltas { shard: s, base: lo, items };
                    let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
                    let lane = &mut *lane;
                    wire::encode_message(&msg, &mut lane.blob, &mut lane.frame);
                    let _ = tx.send(&lane.frame);
                });
            }
        });

        let mut recvs: Vec<crate::Result<()>> = Vec::with_capacity(shards);
        for (s, mut rx) in rxs.into_iter().enumerate() {
            let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
            recvs.push(rx.recv_into(&mut lane.rx_buf));
        }

        let decode_shard = |s: usize| -> Vec<crate::Result<Vec<Tensor>>> {
            let (lo, hi) = slice_bounds(n, shards, s);
            let want = hi - lo;
            if let Err(e) = &recvs[s] {
                return err_slice(want, || anyhow::anyhow!("shard {s} transport failed: {e:#}"));
            }
            let mut lane = self.lanes[s].lock().expect("shard lane poisoned");
            let lane = &mut *lane;
            match wire::decode_message(&lane.rx_buf, &mut lane.scratch) {
                Ok(ShardMessage::Deltas { base, items, .. })
                    if base == lo && items.len() == want =>
                {
                    items
                        .into_iter()
                        .map(|r| r.map_err(|e| anyhow::anyhow!(e)))
                        .collect()
                }
                Ok(_) => err_slice(want, || anyhow::anyhow!("shard {s} sent a malformed slice")),
                Err(e) => err_slice(want, || anyhow::anyhow!("shard {s} frame rejected: {e:#}")),
            }
        };
        let parts = tree_reduce(
            shards,
            1,
            self.inner.threads(),
            |s, _| vec![(slice_bounds(n, shards, s).0, decode_shard(s))],
            |mut a, mut b| {
                a.append(&mut b);
                a
            },
        )
        .unwrap_or_default();

        let mut out = Vec::with_capacity(n);
        for (base, items) in parts {
            debug_assert_eq!(base, out.len(), "shard slices must concatenate in order");
            out.extend(items);
        }
        debug_assert_eq!(out.len(), n, "every voter produced exactly one slot");
        out
    }

    fn evaluate(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        split: &Split,
    ) -> crate::Result<(f64, f64)> {
        self.inner.evaluate(params, masks, split)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::XStore;
    use crate::engine::chaos::ChaosConfig;
    use crate::engine::executor::SimExecutor;
    use crate::model::sim_spec;

    fn sim_cohort(n: usize) -> Vec<Client> {
        (0..n)
            .map(|i| {
                Client::new(
                    i * 5 + 1,
                    0,
                    Split {
                        xs: XStore::F32(vec![0.0; 4 * (i + 2)]),
                        ys: vec![0; i + 2],
                        feature_len: 4,
                    },
                )
            })
            .collect()
    }

    struct Round<'a> {
        cohort: Vec<&'a Client>,
        masks: Vec<&'a MaskSet>,
        jobs: Vec<TrainJob>,
    }

    fn round<'a>(clients: &'a [Client], full: &'a MaskSet, round_idx: usize) -> Round<'a> {
        Round {
            cohort: clients.iter().collect(),
            masks: clients.iter().map(|_| full).collect(),
            jobs: clients
                .iter()
                .map(|c| TrainJob {
                    client: c.id,
                    round: round_idx,
                    steps: 2,
                    lr: 0.05,
                    seed: 1234,
                    use_fused: false,
                })
                .collect(),
        }
    }

    fn assert_same_results(a: &[crate::Result<LocalResult>], b: &[crate::Result<LocalResult>]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
            assert_eq!(x.params, y.params);
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits());
            assert_eq!(x.mean_acc.to_bits(), y.mean_acc.to_bits());
            assert_eq!(x.steps, y.steps);
            assert_eq!(x.weight.to_bits(), y.weight.to_bits());
        }
    }

    #[test]
    fn sharded_matches_plain_executor_at_every_shard_count() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(11);
        let r = round(&clients, &full, 3);
        let plain_ex = SimExecutor::new(spec.clone(), 2);
        let plain = plain_ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        for shards in [1usize, 2, 3, 4, 8, 16] {
            let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), shards);
            let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
            assert_same_results(&plain, &got);
            // second round through the same lanes: buffers are reused
            let again = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
            assert_same_results(&plain, &again);
        }
    }

    #[test]
    fn sharded_deltas_match_plain_executor() {
        let spec = sim_spec("femnist_cnn");
        let old = spec.init_params(3);
        let mut newer = old.clone();
        for t in &mut newer {
            for v in t.data_mut() {
                *v += 0.25;
            }
        }
        let news: Vec<&[Tensor]> = (0..5).map(|_| newer.as_slice()).collect();
        let plain = SimExecutor::new(spec.clone(), 1).run_deltas(&old, &news);
        for shards in [1usize, 2, 4, 8] {
            let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), shards);
            let got = ex.run_deltas(&old, &news);
            assert_eq!(plain.len(), got.len());
            for (x, y) in plain.iter().zip(&got) {
                assert_eq!(x.as_ref().unwrap(), y.as_ref().unwrap());
            }
        }
    }

    #[test]
    fn killed_shard_fails_only_its_slice_with_shard_fault() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(8);
        let r = round(&clients, &full, 5);
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec, 1), 4, Some((1, 5)), false);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        // shard 1 of 4 over 8 jobs owns slots 2..4
        for (i, slot) in got.iter().enumerate() {
            if (2..4).contains(&i) {
                let err = slot.as_ref().err().expect("doomed slice must fail");
                let fault = err.downcast_ref::<ShardFault>().expect("typed ShardFault");
                assert_eq!((fault.shard, fault.round), (1, 5));
            } else {
                assert!(slot.is_ok(), "slot {i} outside the dead shard must survive");
            }
        }
    }

    #[test]
    fn fault_does_not_fire_before_its_round() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(2);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(6);
        let early = round(&clients, &full, 1);
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec, 1), 2, Some((0, 3)), false);
        let before = ex.run_clients(&early.cohort, &early.masks, &params, &early.jobs);
        assert!(before.iter().all(|r| r.is_ok()));
        let due = round(&clients, &full, 3);
        let got = ex.run_clients(&due.cohort, &due.masks, &params, &due.jobs);
        assert!(got[0].is_err(), "fault fires once its round arrives");
        // fire-once: the "restarted" shard works on the next round
        let after = round(&clients, &full, 4);
        let resumed = ex.run_clients(&after.cohort, &after.masks, &params, &after.jobs);
        assert!(resumed.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn double_fault_exhausts_budget_one_but_completes_under_two() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(10);
        let r = round(&clients, &full, 2);
        let plain = SimExecutor::new(spec.clone(), 2)
            .run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        // the restarted shard dies again: the legacy single-shot retry
        // (--shard-retry) must fail the slice with the typed error...
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec.clone(), 2), 4, Some((2, 2)), true)
            .with_crash_times(2);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        // shard 2 of 4 over 10 jobs owns slots 5..7
        for (i, slot) in got.iter().enumerate() {
            if (5..7).contains(&i) {
                let err = slot.as_ref().err().expect("doomed slice must fail");
                let fault = err.downcast_ref::<ShardFault>().expect("typed ShardFault");
                assert_eq!((fault.shard, fault.round), (2, 2));
            } else {
                assert!(slot.is_ok(), "slot {i} outside the dead shard must survive");
            }
        }
        assert_eq!(ex.drain_fault_retries(), (1, 50), "one attempt was spent");
        // ...while --shard-retry-max 2 absorbs the double fault
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec, 2), 4, Some((2, 2)), true)
            .with_crash_times(2)
            .with_retry_budget(2);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&plain, &got);
        assert_eq!(ex.drain_fault_retries(), (2, 150), "50ms + 100ms backoff");
        assert_eq!(ex.drain_fault_retries(), (0, 0), "drain resets the counters");
    }

    #[test]
    fn chaos_shard_events_recover_within_budget() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(9);
        let r = round(&clients, &full, 1);
        let plain = SimExecutor::new(spec.clone(), 2)
            .run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        let cfg = ChaosConfig {
            name: "crash".into(),
            vanish: 0.0,
            hang: 0.0,
            corrupt: 0.0,
            nan_poison: 0.0,
            shard_crash: 1.0,
            shard_stall: 0.0,
            deadline_mult: 1.5,
        };
        // a chaos Crash kills the worker *and* its restart: budget 2 recovers
        let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), 4)
            .with_chaos(Some(ChaosPlan::new(cfg.clone(), 77)))
            .with_retry_budget(2);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&plain, &got);
        assert_eq!(ex.drain_fault_retries(), (2, 150));
        // budget 1 exhausts: only the victim shard's slice fails, typed
        let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), 4)
            .with_chaos(Some(ChaosPlan::new(cfg.clone(), 77)))
            .with_retry_budget(1);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        let ev = ChaosPlan::new(cfg.clone(), 77).shard_event(1).expect("rate 1.0 always fires");
        let victim = (ev.slot % 4) as usize;
        let (lo, hi) = slice_bounds(9, 4, victim);
        for (i, slot) in got.iter().enumerate() {
            if (lo..hi).contains(&i) {
                let err = slot.as_ref().err().expect("victim slice must fail");
                assert!(err.downcast_ref::<ShardFault>().is_some(), "typed ShardFault");
            } else {
                assert!(slot.is_ok(), "slot {i} outside the victim shard must survive");
            }
        }
        // a StallOnce recovers on the first retry
        let stall = ChaosConfig { shard_crash: 0.0, shard_stall: 1.0, ..cfg };
        let ex = ShardedExecutor::new(SimExecutor::new(spec, 2), 4)
            .with_chaos(Some(ChaosPlan::new(stall, 77)))
            .with_retry_budget(1);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&plain, &got);
        assert_eq!(ex.drain_fault_retries(), (1, 50));
    }

    #[test]
    fn retry_redispatches_the_dead_slice_bit_identically() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(10);
        let r = round(&clients, &full, 2);
        let plain_ex = SimExecutor::new(spec.clone(), 2);
        let plain = plain_ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec, 2), 4, Some((2, 2)), true);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&plain, &got);
    }

    #[test]
    fn compressed_wire_matches_dense_under_full_masks() {
        // full masks pack every column, so the sparse wire packing is
        // lossless even for the sim backend: the packed path must be
        // bit-identical to the dense wire at every shard count. (Q8 mode
        // also ships sparse on the wire — quantization lives in the root
        // engine's codec, not here.)
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(9);
        let r = round(&clients, &full, 4);
        let plain = SimExecutor::new(spec.clone(), 2)
            .run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        for mode in [Compression::Sparse, Compression::Q8] {
            for shards in [1usize, 2, 4] {
                let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), shards)
                    .with_compression(mode);
                let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
                assert_same_results(&plain, &got);
                let again = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
                assert_same_results(&plain, &again);
            }
        }
    }

    #[test]
    fn compressed_wire_is_shard_count_invariant_under_partial_masks() {
        // partial masks: the sim backend perturbs dropped columns too, so
        // the packed wire *enforces* the invariant at unpack (dropped
        // columns reconstruct the broadcast global). That reconstruction
        // must not depend on the shard count.
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(3);
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| (0..m.size).map(|j| j % 2 == 0).collect())
            .collect();
        let half = MaskSet::from_keep(&spec, &keep);
        let clients = sim_cohort(10);
        let r = round(&clients, &half, 2);
        let reference = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), 1)
            .with_compression(Compression::Sparse)
            .run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        for shards in [2usize, 4, 8] {
            let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), shards)
                .with_compression(Compression::Sparse);
            let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
            assert_same_results(&reference, &got);
        }
        // and every dropped column did come back as the broadcast value
        let res = reference[0].as_ref().unwrap();
        let gidx = 0usize;
        let m = half.tensors()[gidx].data();
        for (pi, t) in res.params.iter().enumerate() {
            if let Some((g, span)) = crate::fl::aggregate::group_of_param(&spec, pi) {
                if g != gidx {
                    continue;
                }
                let cols = *spec.params[pi].shape.last().unwrap();
                let n = spec.masks[g].size;
                for (e, x) in t.data().iter().enumerate() {
                    let neuron = crate::fl::aggregate::neuron_of(e, cols, n, span);
                    if m[neuron] == 0.0 {
                        assert_eq!(
                            x.to_bits(),
                            params[pi].data()[e].to_bits(),
                            "dropped col must reconstruct the broadcast global"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn retry_under_compression_matches_the_packed_wire_path() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| (0..m.size).map(|j| j % 3 != 0).collect())
            .collect();
        let half = MaskSet::from_keep(&spec, &keep);
        let clients = sim_cohort(10);
        let r = round(&clients, &half, 2);
        let clean = ShardedExecutor::new(SimExecutor::new(spec.clone(), 2), 4)
            .with_compression(Compression::Sparse)
            .run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        let ex = ShardedExecutor::with_fault(SimExecutor::new(spec, 2), 4, Some((2, 2)), true)
            .with_compression(Compression::Sparse);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&clean, &got);
    }

    #[test]
    fn empty_cohort_and_more_shards_than_jobs_are_fine() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(1);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(2);
        let r = round(&clients, &full, 0);
        let ex = ShardedExecutor::new(SimExecutor::new(spec.clone(), 1), 8);
        let got = ex.run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_eq!(got.len(), 2);
        let none = ex.run_clients(&[], &[], &params, &[]);
        assert!(none.is_empty());
        let plain = SimExecutor::new(spec, 1).run_clients(&r.cohort, &r.masks, &params, &r.jobs);
        assert_same_results(&plain, &got);
    }
}
