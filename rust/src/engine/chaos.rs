//! Seeded chaos injection and the graceful-degradation substrate.
//!
//! FLuID's premise is that real fleets misbehave — yet until this module
//! the engine only modelled *slow* clients. A [`ChaosConfig`] is the
//! declarative, replayable fault script (named presets or `name:rate`
//! overrides on the CLI, exactly like `scenario.rs` compiles churn): it
//! binds to the experiment seed as a [`ChaosPlan`] whose every draw runs
//! on a dedicated PCG stream keyed by `(round, client)` — so a chaos run
//! replays bit-identically across `--threads` and `--shards`, and the
//! zero-chaos path consumes no randomness at all.
//!
//! The degradation side lives here too:
//!
//! * [`UpdateValidator`] — always-on, allocation-free admission check for
//!   client updates (finite values, matching shapes, a relative L2 norm
//!   bound). Chaos merely *exercises* it; a poisoned update is caught by
//!   the same code path that guards production rounds.
//! * [`QuarantineLedger`] — strike-escalating bar list for clients whose
//!   updates failed validation, with deterministic decay-based
//!   re-admission. It rides an optional snapshot section so kill/resume
//!   preserves it.
//! * [`QuorumFailed`] — the typed error a round raises when too few fresh
//!   updates survive the barrier; never a panic, never a silent
//!   half-round.

use crate::fl::LocalResult;
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;

/// Relative-L2 admission bound for [`UpdateValidator`] — generous by
/// design: a legitimate local-SGD update moves a small fraction of the
/// broadcast norm, while a corrupted or diverged payload lands orders of
/// magnitude out (property-tested in `tests/properties.rs`).
pub const DEFAULT_NORM_BOUND: f64 = 1e3;

/// First quarantine bar length in rounds; doubles per strike.
pub const QUAR_BAR_BASE: usize = 2;
/// Strike cap on bar doubling (longest bar: `QUAR_BAR_BASE << 6` rounds).
const QUAR_BAR_CAP: u32 = 6;
/// A clean streak this long forgives one strike.
pub const QUAR_DECAY_EVERY: usize = 16;

/// Base of the deterministic virtual-time backoff a shard-slice retry
/// costs (doubles per attempt, capped — see [`retry_backoff_ms`]).
const BACKOFF_BASE_MS: u64 = 50;

/// Declarative description of one chaos script. All rates are per-round
/// probabilities; the client-fault rates stack (their sum must stay
/// within [0, 1]), as must the shard-fault rates.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosConfig {
    /// preset name (diagnostics / reports)
    pub name: String,
    /// client disappears mid-round: no arrival, no update
    pub vanish: f64,
    /// client hangs past the round deadline; dropped at the deadline
    pub hang: f64,
    /// client's payload fails wire decode and is quarantined
    pub corrupt: f64,
    /// client's update carries a seeded non-finite value
    pub nan_poison: f64,
    /// one shard worker crashes this round (slice re-dispatched)
    pub shard_crash: f64,
    /// one shard worker stalls once past its deadline
    pub shard_stall: f64,
    /// round deadline as a multiple of the barrier target — how long the
    /// server waits for a hung client before dropping it
    pub deadline_mult: f64,
}

impl ChaosConfig {
    fn preset(name: &str) -> Option<ChaosConfig> {
        let calm = ChaosConfig {
            name: name.to_string(),
            vanish: 0.0,
            hang: 0.0,
            corrupt: 0.0,
            nan_poison: 0.0,
            shard_crash: 0.0,
            shard_stall: 0.0,
            deadline_mult: 1.5,
        };
        Some(match name {
            // clients disappear mid-round, nothing else
            "vanish" => ChaosConfig {
                vanish: 0.05,
                ..calm
            },
            // clients hang past the deadline
            "hang" => ChaosConfig { hang: 0.05, ..calm },
            // payloads fail wire decode
            "corrupt" => ChaosConfig {
                corrupt: 0.05,
                ..calm
            },
            // updates carry seeded non-finite values
            "nan" => ChaosConfig {
                nan_poison: 0.05,
                ..calm
            },
            // shard workers crash / stall
            "shards" => ChaosConfig {
                shard_crash: 0.05,
                shard_stall: 0.05,
                ..calm
            },
            // everything at once
            "storm" => ChaosConfig {
                vanish: 0.04,
                hang: 0.02,
                corrupt: 0.02,
                nan_poison: 0.01,
                shard_crash: 0.03,
                shard_stall: 0.02,
                ..calm
            },
            _ => return None,
        })
    }

    /// Parse a CLI chaos spec: `none`, a preset name, or `preset:rate`
    /// where `rate` overrides the preset's headline knob (the vanish rate
    /// for `vanish`/`storm`, the hang/corrupt/nan rate for those presets,
    /// the shard-crash rate for `shards`).
    pub fn parse(spec: &str) -> Result<Option<ChaosConfig>, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(None);
        }
        let (name, rate) = match spec.split_once(':') {
            Some((n, r)) => {
                let rate: f64 = r
                    .parse()
                    .map_err(|_| format!("chaos rate {r:?} is not a number"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(format!("chaos rate {rate} outside [0, 1]"));
                }
                (n, Some(rate))
            }
            None => (spec, None),
        };
        let mut cfg = ChaosConfig::preset(name).ok_or_else(|| {
            format!("unknown chaos {name:?} (none|vanish|hang|corrupt|nan|shards|storm[:rate])")
        })?;
        if let Some(rate) = rate {
            match name {
                "vanish" | "storm" => cfg.vanish = rate,
                "hang" => cfg.hang = rate,
                "corrupt" => cfg.corrupt = rate,
                "nan" => cfg.nan_poison = rate,
                "shards" => cfg.shard_crash = rate,
                _ => {}
            }
        }
        cfg.validate()?;
        Ok(Some(cfg))
    }

    /// Structural sanity: every rate a probability, the stacked draws
    /// within [0, 1], the deadline multiple usable.
    pub fn validate(&self) -> Result<(), String> {
        for (knob, v) in [
            ("vanish", self.vanish),
            ("hang", self.hang),
            ("corrupt", self.corrupt),
            ("nan", self.nan_poison),
            ("shard-crash", self.shard_crash),
            ("shard-stall", self.shard_stall),
        ] {
            if !v.is_finite() || !(0.0..=1.0).contains(&v) {
                return Err(format!("chaos {knob} rate {v} outside [0, 1]"));
            }
        }
        let client = self.vanish + self.hang + self.corrupt + self.nan_poison;
        if client > 1.0 {
            return Err(format!("stacked client fault rates sum to {client} > 1"));
        }
        let shard = self.shard_crash + self.shard_stall;
        if shard > 1.0 {
            return Err(format!("stacked shard fault rates sum to {shard} > 1"));
        }
        if !self.deadline_mult.is_finite() || self.deadline_mult < 1.0 {
            return Err(format!(
                "chaos deadline multiple {} must be >= 1",
                self.deadline_mult
            ));
        }
        Ok(())
    }

    /// Does this script ever fault a client?
    pub fn has_client_faults(&self) -> bool {
        self.vanish + self.hang + self.corrupt + self.nan_poison > 0.0
    }

    /// Does this script ever fault a shard worker? (Decides whether the
    /// run must route through the sharded tree even at `--shards 1`.)
    pub fn has_shard_faults(&self) -> bool {
        self.shard_crash + self.shard_stall > 0.0
    }
}

/// One injected client-level fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClientFault {
    /// disappears mid-round: no arrival, no update, nothing observed
    Vanish,
    /// alive but past the deadline: dropped, the server waits out the
    /// deadline (`deadline_mult` x the barrier target)
    Hang,
    /// payload fails wire decode — straight to quarantine
    Corrupt,
    /// update carries a seeded NaN — caught by the validator
    NanPoison,
}

/// One injected shard-worker fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardFaultKind {
    /// the worker dies; its slice must be re-dispatched
    Crash,
    /// the worker misses its deadline once, then recovers
    StallOnce,
}

/// A shard fault drawn in *virtual slot space*: the event exists (or
/// not) per round independent of the shard count, and maps onto an
/// actual shard as `slot % shards` — so fault counts and retry telemetry
/// are shard-count invariant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardEvent {
    pub slot: u64,
    pub kind: ShardFaultKind,
}

/// A chaos script bound to an experiment seed — the replayable executor
/// of a [`ChaosConfig`]. Every query opens a fresh PCG stream keyed by
/// `(round, client)`, so draws are order-free: any thread, any shard,
/// any replay sees the same faults.
#[derive(Clone, Debug)]
pub struct ChaosPlan {
    cfg: ChaosConfig,
    seed: u64,
}

impl ChaosPlan {
    pub fn new(cfg: ChaosConfig, experiment_seed: u64) -> Self {
        Self {
            cfg,
            seed: experiment_seed ^ 0xC4A0_57A7,
        }
    }

    pub fn cfg(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// The fault `client` suffers in `round`, if any. Pure in
    /// `(plan, round, client)`.
    pub fn client_fault(&self, round: usize, client: usize) -> Option<ClientFault> {
        let c = &self.cfg;
        if !c.has_client_faults() {
            return None;
        }
        let mut rng = Pcg32::new(self.seed ^ ((round as u64) << 32), client as u64);
        let x = rng.next_f64();
        if x < c.vanish {
            Some(ClientFault::Vanish)
        } else if x < c.vanish + c.hang {
            Some(ClientFault::Hang)
        } else if x < c.vanish + c.hang + c.corrupt {
            Some(ClientFault::Corrupt)
        } else if x < c.vanish + c.hang + c.corrupt + c.nan_poison {
            Some(ClientFault::NanPoison)
        } else {
            None
        }
    }

    /// Which parameter element a NanPoison fault lands on, for an update
    /// tensor of `len` elements.
    pub fn poison_index(&self, round: usize, client: usize, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        let mut rng = Pcg32::new(
            self.seed ^ 0x9015_0000 ^ ((round as u64) << 32),
            client as u64,
        );
        rng.below_usize(len)
    }

    /// The shard fault drawn for `round`, if any — in virtual slot
    /// space, shard-count independent (see [`ShardEvent`]).
    pub fn shard_event(&self, round: usize) -> Option<ShardEvent> {
        let c = &self.cfg;
        if !c.has_shard_faults() {
            return None;
        }
        let mut rng = Pcg32::new(self.seed ^ ((round as u64) << 32), 0x5AD_E);
        let x = rng.next_f64();
        let slot = rng.next_u64();
        if x < c.shard_crash {
            Some(ShardEvent {
                slot,
                kind: ShardFaultKind::Crash,
            })
        } else if x < c.shard_crash + c.shard_stall {
            Some(ShardEvent {
                slot,
                kind: ShardFaultKind::StallOnce,
            })
        } else {
            None
        }
    }
}

/// Deterministic virtual-time cost of shard-slice retry `attempt`
/// (1-based): doubles per attempt, capped so a deep budget cannot run
/// the virtual clock away. Telemetry/vtime only — never wall clock.
pub fn retry_backoff_ms(attempt: usize) -> u64 {
    BACKOFF_BASE_MS << (attempt.saturating_sub(1).min(6) as u32)
}

/// The typed error a round raises when fewer than the configured quorum
/// fraction of its participants delivered a fresh, valid, on-time
/// update. The engine raises it *before* aggregation mutates any state,
/// so the last checkpoint remains a clean resume point.
#[derive(Debug, Clone, Copy)]
pub struct QuorumFailed {
    pub round: usize,
    /// fresh valid on-time updates that survived the barrier
    pub arrived: usize,
    /// participants the round dispatched
    pub expected: usize,
    /// the configured quorum fraction
    pub quorum: f64,
}

impl std::fmt::Display for QuorumFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "quorum failed at round {}: {}/{} fresh updates (need fraction {})",
            self.round, self.arrived, self.expected, self.quorum
        )
    }
}

impl std::error::Error for QuorumFailed {}

/// Why an update was refused admission.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Violation {
    /// payload failed wire decode / checksum
    Decode,
    /// tensor count or shape disagrees with the broadcast model
    Shape,
    /// a parameter or metric value is not finite
    NonFinite,
    /// relative L2 distance from the broadcast exceeded the bound
    NormBound { ratio: f64 },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Violation::Decode => write!(f, "payload failed decode"),
            Violation::Shape => write!(f, "shape mismatch with broadcast model"),
            Violation::NonFinite => write!(f, "non-finite value"),
            Violation::NormBound { ratio } => {
                write!(f, "update norm {ratio:.3e}x the broadcast bound")
            }
        }
    }
}

/// Always-on admission check for client updates. Allocation-free on the
/// clean path (gated in `tests/alloc_gate.rs`): plain loops accumulating
/// in f64, no intermediate tensors.
#[derive(Clone, Copy, Debug)]
pub struct UpdateValidator {
    /// relative L2 bound: reject when
    /// `||update - broadcast|| > bound * (1 + ||broadcast||)`
    pub norm_bound: f64,
}

impl Default for UpdateValidator {
    fn default() -> Self {
        Self {
            norm_bound: DEFAULT_NORM_BOUND,
        }
    }
}

impl UpdateValidator {
    pub fn new(norm_bound: f64) -> Self {
        Self { norm_bound }
    }

    /// Admit or refuse one local result against the broadcast model it
    /// started from.
    pub fn validate(&self, result: &LocalResult, broadcast: &[Tensor]) -> Result<(), Violation> {
        if !result.mean_loss.is_finite() || !result.mean_acc.is_finite() {
            return Err(Violation::NonFinite);
        }
        if result.params.len() != broadcast.len() {
            return Err(Violation::Shape);
        }
        let mut diff2 = 0.0f64;
        let mut base2 = 0.0f64;
        for (u, b) in result.params.iter().zip(broadcast) {
            if u.shape() != b.shape() {
                return Err(Violation::Shape);
            }
            for (&x, &y) in u.data().iter().zip(b.data()) {
                if !x.is_finite() {
                    return Err(Violation::NonFinite);
                }
                let d = (x - y) as f64;
                diff2 += d * d;
                base2 += (y as f64) * (y as f64);
            }
        }
        let ratio = diff2.sqrt() / (1.0 + base2.sqrt());
        if ratio > self.norm_bound {
            return Err(Violation::NormBound { ratio });
        }
        Ok(())
    }
}

/// One quarantined client's record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuarEntry {
    pub client: usize,
    /// validation failures on record (>= 1 while the entry lives)
    pub strikes: u32,
    /// first round the client may participate again
    pub barred_until: usize,
    /// round of the most recent strike (decay anchor)
    pub last_strike: usize,
}

/// Strike-escalating quarantine bar list, sorted by client id. Every
/// validation failure extends the bar exponentially (capped); a clean
/// streak of [`QUAR_DECAY_EVERY`] rounds forgives one strike, and an
/// entry with no strikes left is dropped — decay-based re-admission.
/// Persisted through the optional `QUAR` snapshot section so kill/resume
/// preserves it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct QuarantineLedger {
    /// sorted by client id, strikes >= 1
    entries: Vec<QuarEntry>,
}

impl QuarantineLedger {
    fn bar_len(strikes: u32) -> usize {
        QUAR_BAR_BASE << strikes.saturating_sub(1).min(QUAR_BAR_CAP)
    }

    /// Register a validation failure for `client` in `round`.
    pub fn record(&mut self, client: usize, round: usize) {
        match self.entries.binary_search_by_key(&client, |e| e.client) {
            Ok(i) => {
                let e = &mut self.entries[i];
                e.strikes = e.strikes.saturating_add(1);
                e.last_strike = round;
                e.barred_until = round + Self::bar_len(e.strikes);
            }
            Err(i) => self.entries.insert(
                i,
                QuarEntry {
                    client,
                    strikes: 1,
                    barred_until: round + Self::bar_len(1),
                    last_strike: round,
                },
            ),
        }
    }

    /// Is `client` barred from participating in `round`? O(log entries),
    /// allocation-free.
    pub fn is_barred(&self, client: usize, round: usize) -> bool {
        match self.entries.binary_search_by_key(&client, |e| e.client) {
            Ok(i) => round < self.entries[i].barred_until,
            Err(_) => false,
        }
    }

    /// Advance decay to `round`: each full clean [`QUAR_DECAY_EVERY`]
    /// streak since the last strike forgives one strike; strike-free
    /// entries drop out. Deterministic in `round`, allocation-free.
    pub fn decay(&mut self, round: usize) {
        self.entries.retain_mut(|e| {
            while e.strikes > 0 && round >= e.last_strike + QUAR_DECAY_EVERY {
                e.strikes -= 1;
                e.last_strike += QUAR_DECAY_EVERY;
            }
            e.strikes > 0
        });
    }

    pub fn entries(&self) -> &[QuarEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Snapshot export — the raw sorted entry list.
    pub fn export(&self) -> Vec<QuarEntry> {
        self.entries.clone()
    }

    /// Rebuild from a snapshot section, validating the sort/dedup/strike
    /// invariants a hand-edited or corrupted snapshot could break.
    pub fn from_entries(entries: Vec<QuarEntry>) -> Result<QuarantineLedger, String> {
        for w in entries.windows(2) {
            if w[0].client >= w[1].client {
                return Err("quarantine ledger not sorted by client".into());
            }
        }
        if entries.iter().any(|e| e.strikes == 0) {
            return Err("quarantine entry with zero strikes".into());
        }
        Ok(QuarantineLedger { entries })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_none_is_none() {
        assert_eq!(ChaosConfig::parse("none").unwrap(), None);
        assert_eq!(ChaosConfig::parse("").unwrap(), None);
        for name in ["vanish", "hang", "corrupt", "nan", "shards", "storm"] {
            let c = ChaosConfig::parse(name).unwrap().unwrap();
            assert_eq!(c.name, name);
            c.validate().unwrap();
        }
        assert!(ChaosConfig::parse("bogus").is_err());
        assert!(ChaosConfig::parse("vanish:2.0").is_err());
        assert!(ChaosConfig::parse("vanish:x").is_err());
    }

    #[test]
    fn rate_override_hits_the_headline_knob() {
        assert_eq!(ChaosConfig::parse("vanish:0.2").unwrap().unwrap().vanish, 0.2);
        assert_eq!(ChaosConfig::parse("hang:0.3").unwrap().unwrap().hang, 0.3);
        assert_eq!(ChaosConfig::parse("corrupt:0.1").unwrap().unwrap().corrupt, 0.1);
        assert_eq!(ChaosConfig::parse("nan:0.1").unwrap().unwrap().nan_poison, 0.1);
        assert_eq!(
            ChaosConfig::parse("shards:0.4").unwrap().unwrap().shard_crash,
            0.4
        );
        assert_eq!(ChaosConfig::parse("storm:0.5").unwrap().unwrap().vanish, 0.5);
    }

    #[test]
    fn validate_rejects_overstacked_rates() {
        let mut c = ChaosConfig::parse("storm").unwrap().unwrap();
        c.vanish = 0.6;
        c.hang = 0.6;
        assert!(c.validate().is_err());
        let mut c = ChaosConfig::parse("shards").unwrap().unwrap();
        c.deadline_mult = 0.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn client_faults_are_replayable_and_rate_bounded() {
        let cfg = ChaosConfig::parse("storm").unwrap().unwrap();
        let a = ChaosPlan::new(cfg.clone(), 42);
        let b = ChaosPlan::new(cfg.clone(), 42);
        let mut fired = 0usize;
        let mut total = 0usize;
        for round in 0..50 {
            for client in 0..200 {
                let fa = a.client_fault(round, client);
                assert_eq!(fa, b.client_fault(round, client), "r{round} c{client}");
                total += 1;
                fired += fa.is_some() as usize;
            }
        }
        let rate = fired as f64 / total as f64;
        let expect = cfg.vanish + cfg.hang + cfg.corrupt + cfg.nan_poison;
        assert!((rate - expect).abs() < 0.02, "fault rate {rate} vs {expect}");
        // a different seed draws a different fault pattern
        let c = ChaosPlan::new(cfg, 43);
        let differs = (0..50)
            .flat_map(|r| (0..200).map(move |cl| (r, cl)))
            .any(|(r, cl)| a.client_fault(r, cl) != c.client_fault(r, cl));
        assert!(differs);
    }

    #[test]
    fn zero_rate_plan_never_fires() {
        let mut cfg = ChaosConfig::parse("storm").unwrap().unwrap();
        cfg.vanish = 0.0;
        cfg.hang = 0.0;
        cfg.corrupt = 0.0;
        cfg.nan_poison = 0.0;
        cfg.shard_crash = 0.0;
        cfg.shard_stall = 0.0;
        let p = ChaosPlan::new(cfg, 7);
        for round in 0..20 {
            assert_eq!(p.shard_event(round), None);
            for client in 0..50 {
                assert_eq!(p.client_fault(round, client), None);
            }
        }
    }

    #[test]
    fn shard_events_are_shard_count_independent() {
        let cfg = ChaosConfig::parse("shards").unwrap().unwrap();
        let p = ChaosPlan::new(cfg, 11);
        let mut fired = 0usize;
        for round in 0..200 {
            // the *event* is drawn before any shard-count mapping
            let ev = p.shard_event(round);
            assert_eq!(ev, p.shard_event(round));
            if let Some(ev) = ev {
                fired += 1;
                // maps onto every topology
                for shards in [1usize, 2, 4, 8] {
                    assert!(((ev.slot % shards as u64) as usize) < shards);
                }
            }
        }
        assert!(fired > 5, "shard events too rare: {fired}/200");
        assert!(fired < 60, "shard events too common: {fired}/200");
    }

    #[test]
    fn retry_backoff_doubles_and_caps() {
        assert_eq!(retry_backoff_ms(1), 50);
        assert_eq!(retry_backoff_ms(2), 100);
        assert_eq!(retry_backoff_ms(3), 200);
        assert_eq!(retry_backoff_ms(7), 3200);
        assert_eq!(retry_backoff_ms(100), 3200, "backoff must cap");
    }

    fn clean_result(broadcast: &[Tensor]) -> LocalResult {
        LocalResult {
            params: broadcast
                .iter()
                .map(|t| {
                    let mut t = t.clone();
                    for x in t.data_mut() {
                        *x += 0.01;
                    }
                    t
                })
                .collect(),
            mean_loss: 0.7,
            mean_acc: 0.5,
            steps: 2,
            weight: 8.0,
        }
    }

    #[test]
    fn validator_accepts_clean_and_rejects_poisoned() {
        let broadcast = vec![Tensor::full(&[4, 3], 0.5), Tensor::zeros(&[3])];
        let v = UpdateValidator::default();
        assert_eq!(v.validate(&clean_result(&broadcast), &broadcast), Ok(()));

        let mut nan = clean_result(&broadcast);
        nan.params[1].data_mut()[1] = f32::NAN;
        assert_eq!(v.validate(&nan, &broadcast), Err(Violation::NonFinite));

        let mut inf_loss = clean_result(&broadcast);
        inf_loss.mean_loss = f64::INFINITY;
        assert_eq!(v.validate(&inf_loss, &broadcast), Err(Violation::NonFinite));

        let mut huge = clean_result(&broadcast);
        huge.params[0].data_mut()[0] = 1e9;
        assert!(matches!(
            v.validate(&huge, &broadcast),
            Err(Violation::NormBound { .. })
        ));

        let mut wrong = clean_result(&broadcast);
        wrong.params.pop();
        assert_eq!(v.validate(&wrong, &broadcast), Err(Violation::Shape));
    }

    #[test]
    fn poison_index_is_deterministic_and_in_bounds() {
        let cfg = ChaosConfig::parse("nan").unwrap().unwrap();
        let p = ChaosPlan::new(cfg, 3);
        for round in 0..10 {
            for client in 0..10 {
                let i = p.poison_index(round, client, 577);
                assert!(i < 577);
                assert_eq!(i, p.poison_index(round, client, 577));
            }
        }
        assert_eq!(p.poison_index(1, 1, 0), 0);
    }

    #[test]
    fn ledger_bars_escalate_and_decay_readmits() {
        let mut q = QuarantineLedger::default();
        assert!(!q.is_barred(7, 0));
        q.record(7, 10);
        assert!(q.is_barred(7, 10));
        assert!(q.is_barred(7, 11));
        assert!(!q.is_barred(7, 10 + QUAR_BAR_BASE), "first bar expires");
        // a second strike bars twice as long
        q.record(7, 20);
        assert!(q.is_barred(7, 20 + QUAR_BAR_BASE));
        assert!(!q.is_barred(7, 20 + 2 * QUAR_BAR_BASE));
        // decay forgives one strike per clean streak, then drops the entry
        q.decay(20 + QUAR_DECAY_EVERY);
        assert_eq!(q.entries()[0].strikes, 1);
        q.decay(20 + 2 * QUAR_DECAY_EVERY);
        assert!(q.is_empty(), "fully decayed entry drops out");
        // the bar length caps
        let mut q = QuarantineLedger::default();
        for s in 0..40 {
            q.record(3, s);
        }
        let e = q.entries()[0];
        assert_eq!(e.strikes, 40);
        assert_eq!(e.barred_until - e.last_strike, QUAR_BAR_BASE << 6);
    }

    #[test]
    fn ledger_round_trips_and_rejects_bad_sections() {
        let mut q = QuarantineLedger::default();
        q.record(3, 5);
        q.record(99, 6);
        q.record(3, 8);
        let back = QuarantineLedger::from_entries(q.export()).unwrap();
        assert_eq!(back, q);
        assert!(QuarantineLedger::from_entries(vec![
            QuarEntry { client: 5, strikes: 1, barred_until: 9, last_strike: 7 },
            QuarEntry { client: 5, strikes: 1, barred_until: 9, last_strike: 7 },
        ])
        .is_err());
        assert!(QuarantineLedger::from_entries(vec![QuarEntry {
            client: 5,
            strikes: 0,
            barred_until: 9,
            last_strike: 7
        }])
        .is_err());
    }

    #[test]
    fn quorum_failed_formats_and_is_an_error() {
        let q = QuorumFailed {
            round: 12,
            arrived: 3,
            expected: 16,
            quorum: 0.5,
        };
        let msg = format!("{q}");
        assert!(msg.contains("round 12"));
        assert!(msg.contains("3/16"));
        let _: &dyn std::error::Error = &q;
    }
}
