//! Process-agnostic wire framing for shard → root messages
//! (DESIGN.md §11).
//!
//! The sharded executor ([`crate::engine::sharded`]) moves a shard's
//! per-round output to the root reducer as one *frame*: the snapshot
//! container's section framing ([`crate::snapshot::codec`] conventions —
//! little-endian, length-prefixed, raw float bits) re-applied to a
//! message instead of a file. A frame is
//!
//! ```text
//! magic "FLWM" | version u32 | payload_len u64
//! | section_count u32 | (id u32, start u64, len u64) x count
//! | section blob | fnv1a-64 checksum u64
//! ```
//!
//! where `payload_len` covers everything between itself and the
//! checksum, section `start`/`len` index into the blob, and the checksum
//! runs over every preceding byte. Readers look sections up *by id*, so
//! a frame carrying sections this version does not know is still
//! decodable (unknown sections are simply never read) — the same
//! forward-compatibility rule the snapshot container follows. Decoding
//! never panics: magic, version, lengths and the checksum are all
//! validated before anything is interpreted or allocated, so a
//! truncated or corrupted frame surfaces as a clean `Err`.
//!
//! Transport is behind the [`FrameTx`] / [`FrameRx`] pair so the message
//! layer stays process-agnostic: [`mem_channel`] is the in-memory
//! (scoped-thread) impl the executor uses today, [`StreamTx`] /
//! [`StreamRx`] run the identical frames over any byte stream (pipes,
//! sockets — see [`unix_pair`]), which is the seam a true multi-process
//! deployment plugs into.
//!
//! Bit-exactness contract: tensors travel as raw IEEE-754 bit patterns
//! ([`Writer::put_f32_bytes`]), and per-client errors travel as plain
//! strings, so encode → decode → encode is a byte-for-byte fixpoint
//! (pinned by the wire properties in `tests/properties.rs`).
//!
//! Compressed experiments ship their slices as [`ShardMessage::Packed`]:
//! the items are [`PackedResult`]s whose tensors travel as
//! [`crate::fl::DeltaPayload`] framings (written once, in
//! `fl::codec::put_payload`, from the same shared `snapshot::codec` bulk
//! helpers every other tensor byte in the repo uses) inside their own
//! [`SEC_PAYLOAD`] section — an old reader skips the unknown section id
//! instead of misparsing dense items.

use crate::fl::codec::{put_payload, take_payload};
use crate::fl::{AggScratch, LocalResult, PackedResult};
use crate::snapshot::codec::{put_tensor_bulk, take_tensor_bulk};
use crate::snapshot::{fnv1a, Reader, Writer};
use crate::tensor::Tensor;
use anyhow::{bail, Context};

/// Frame magic: **FL**uID **W**ire **M**essage.
pub const WIRE_MAGIC: [u8; 4] = *b"FLWM";
/// Wire format version. Readers reject frames from a different version;
/// *within* a version, unknown section ids are skipped.
pub const WIRE_VERSION: u32 = 1;

/// Section id: message header (kind, shard, round, base, item count).
pub const SEC_HEAD: u32 = 1;
/// Section id: the per-client item payloads.
pub const SEC_ITEMS: u32 = 2;
/// Section id: per-client items carried as `DeltaPayload` framings
/// ([`ShardMessage::Packed`]). A separate id from [`SEC_ITEMS`] so a
/// reader that predates payloads skips the section instead of
/// misparsing it as dense items.
pub const SEC_PAYLOAD: u32 = 3;

const KIND_RESULTS: u8 = 1;
const KIND_DELTAS: u8 = 2;
const KIND_FAULT: u8 = 3;
const KIND_PACKED: u8 = 4;

/// magic + version + payload_len … section_count … checksum
const FRAME_OVERHEAD: usize = 4 + 4 + 8 + 4 + 8;
/// bytes per section-table entry
const TABLE_ENTRY: usize = 4 + 8 + 8;

/// Hard cap a [`StreamRx`] enforces on the length prefix before
/// allocating — a corrupted stream cannot trigger a huge reservation.
pub const MAX_FRAME_BYTES: u64 = 1 << 32;

// ---------------------------------------------------------------------
// frame container
// ---------------------------------------------------------------------

/// Assemble a checksummed frame from `(section id, bytes)` pairs into
/// `out` (cleared first; capacity is reused across rounds).
pub fn encode_frame(sections: &[(u32, &[u8])], out: &mut Vec<u8>) {
    out.clear();
    let blob_len: usize = sections.iter().map(|(_, b)| b.len()).sum();
    let payload = 4 + TABLE_ENTRY * sections.len() + blob_len;
    out.reserve(FRAME_OVERHEAD - 4 + payload);
    out.extend_from_slice(&WIRE_MAGIC);
    out.extend_from_slice(&WIRE_VERSION.to_le_bytes());
    out.extend_from_slice(&(payload as u64).to_le_bytes());
    out.extend_from_slice(&(sections.len() as u32).to_le_bytes());
    let mut start = 0u64;
    for (id, bytes) in sections {
        out.extend_from_slice(&id.to_le_bytes());
        out.extend_from_slice(&start.to_le_bytes());
        out.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
        start += bytes.len() as u64;
    }
    for (_, bytes) in sections {
        out.extend_from_slice(bytes);
    }
    let sum = fnv1a(out);
    out.extend_from_slice(&sum.to_le_bytes());
}

/// A decoded frame: validated sections, looked up by id.
pub struct Frame<'a> {
    sections: Vec<(u32, &'a [u8])>,
}

impl<'a> Frame<'a> {
    /// The bytes of section `id`, if the frame carries it.
    pub fn section(&self, id: u32) -> Option<&'a [u8]> {
        self.sections
            .iter()
            .find(|(sid, _)| *sid == id)
            .map(|(_, b)| *b)
    }

    /// Every `(id, bytes)` pair, in frame order.
    pub fn sections(&self) -> &[(u32, &'a [u8])] {
        &self.sections
    }
}

/// Validate and index a frame. Every failure mode — short input, bad
/// magic, version mismatch, checksum mismatch, lying lengths — is a
/// clean `Err`; nothing is interpreted before the checksum passes.
pub fn decode_frame(bytes: &[u8]) -> crate::Result<Frame<'_>> {
    if bytes.len() < FRAME_OVERHEAD {
        bail!(
            "wire frame truncated: {} bytes, header+checksum need {FRAME_OVERHEAD}",
            bytes.len()
        );
    }
    let body = &bytes[..bytes.len() - 8];
    let mut trailer = [0u8; 8];
    trailer.copy_from_slice(&bytes[bytes.len() - 8..]);
    let want = u64::from_le_bytes(trailer);
    let got = fnv1a(body);
    if got != want {
        bail!("wire frame checksum mismatch: computed {got:#018x}, frame says {want:#018x}");
    }
    let mut r = Reader::new(body);
    let mut magic = [0u8; 4];
    for m in &mut magic {
        *m = r.take_u8()?;
    }
    if magic != WIRE_MAGIC {
        bail!("bad wire frame magic {magic:02x?}");
    }
    let version = r.take_u32()?;
    if version != WIRE_VERSION {
        bail!("unsupported wire frame version {version} (this build speaks {WIRE_VERSION})");
    }
    let payload_len = r.take_u64()?;
    if payload_len != r.remaining() as u64 {
        bail!(
            "wire frame payload length {payload_len} disagrees with the {} bytes present",
            r.remaining()
        );
    }
    let count = r.take_u32()? as usize;
    let table_bytes = count
        .checked_mul(TABLE_ENTRY)
        .context("section count overflows")?;
    if table_bytes > r.remaining() {
        bail!("wire frame claims {count} sections, table does not fit");
    }
    let mut table = Vec::with_capacity(count);
    for _ in 0..count {
        let id = r.take_u32()?;
        let start = r.take_usize()?;
        let len = r.take_usize()?;
        table.push((id, start, len));
    }
    let blob = &body[body.len() - r.remaining()..];
    let mut sections = Vec::with_capacity(count);
    for (id, start, len) in table {
        let end = start
            .checked_add(len)
            .with_context(|| format!("section {id} range overflows"))?;
        if end > blob.len() {
            bail!(
                "section {id} spans {start}..{end}, blob holds {} bytes",
                blob.len()
            );
        }
        sections.push((id, &blob[start..end]));
    }
    Ok(Frame { sections })
}

// ---------------------------------------------------------------------
// shard messages
// ---------------------------------------------------------------------

/// What a shard sends the root reducer. Per-client failures are carried
/// as plain strings (not live error values) so the message is a pure
/// byte-level value: encode → decode → encode is a fixpoint.
#[derive(Debug)]
pub enum ShardMessage {
    /// The shard's slice of per-client training results, job-aligned
    /// with cohort positions `base .. base + items.len()`.
    Results {
        shard: usize,
        round: usize,
        base: usize,
        items: Vec<Result<LocalResult, String>>,
    },
    /// The shard's slice of invariant delta-kernel outputs.
    Deltas {
        shard: usize,
        base: usize,
        items: Vec<Result<Vec<Tensor>, String>>,
    },
    /// The shard died mid-round (shard-level fault injection) before
    /// producing its slice.
    Fault { shard: usize, round: usize },
    /// The shard's slice of training results with tensors carried as
    /// `DeltaPayload` framings (compressed experiments) — job-aligned
    /// like [`ShardMessage::Results`], framed into [`SEC_PAYLOAD`].
    Packed {
        shard: usize,
        round: usize,
        base: usize,
        items: Vec<Result<PackedResult, String>>,
    },
}

/// Decode one tensor, reusing a pooled buffer from `scratch` when a
/// matching shape was recycled. Thin seam over the shared
/// [`take_tensor_bulk`] framing; the claimed element count is validated
/// against the remaining frame bytes *before* any tensor is produced.
fn take_wire_tensor(r: &mut Reader<'_>, scratch: &mut AggScratch) -> crate::Result<Tensor> {
    take_tensor_bulk(r, |shape| scratch.take_out(shape))
}

/// Encode `msg` into the frame buffer `out`, staging section bytes in
/// `blob`. Both buffers are cleared and refilled; their capacity is what
/// a steady-state round reuses (the allocation gate pins this).
pub fn encode_message(msg: &ShardMessage, blob: &mut Vec<u8>, out: &mut Vec<u8>) {
    let mut w = Writer::from_vec(std::mem::take(blob));
    let (kind, shard, round, base, count) = match msg {
        ShardMessage::Results { shard, round, base, items } => {
            (KIND_RESULTS, *shard, *round, *base, items.len())
        }
        ShardMessage::Deltas { shard, base, items } => {
            (KIND_DELTAS, *shard, 0, *base, items.len())
        }
        ShardMessage::Fault { shard, round } => (KIND_FAULT, *shard, *round, 0, 0),
        ShardMessage::Packed { shard, round, base, items } => {
            (KIND_PACKED, *shard, *round, *base, items.len())
        }
    };
    w.put_u8(kind);
    w.put_usize(shard);
    w.put_usize(round);
    w.put_usize(base);
    w.put_usize(count);
    let head_len = w.len();
    match msg {
        ShardMessage::Results { items, .. } => {
            for item in items {
                match item {
                    Ok(res) => {
                        w.put_bool(true);
                        w.put_usize(res.params.len());
                        for t in &res.params {
                            put_tensor_bulk(&mut w, t);
                        }
                        w.put_f64(res.mean_loss);
                        w.put_f64(res.mean_acc);
                        w.put_usize(res.steps);
                        w.put_f64(res.weight);
                    }
                    Err(e) => {
                        w.put_bool(false);
                        w.put_str(e);
                    }
                }
            }
        }
        ShardMessage::Deltas { items, .. } => {
            for item in items {
                match item {
                    Ok(tensors) => {
                        w.put_bool(true);
                        w.put_usize(tensors.len());
                        for t in tensors {
                            put_tensor_bulk(&mut w, t);
                        }
                    }
                    Err(e) => {
                        w.put_bool(false);
                        w.put_str(e);
                    }
                }
            }
        }
        ShardMessage::Fault { .. } => {}
        ShardMessage::Packed { items, .. } => {
            for item in items {
                match item {
                    Ok(pr) => {
                        w.put_bool(true);
                        put_payload(&mut w, &pr.payload);
                        w.put_f64(pr.mean_loss);
                        w.put_f64(pr.mean_acc);
                        w.put_usize(pr.steps);
                        w.put_f64(pr.weight);
                    }
                    Err(e) => {
                        w.put_bool(false);
                        w.put_str(e);
                    }
                }
            }
        }
    }
    *blob = w.into_bytes();
    let items_sec = if matches!(msg, ShardMessage::Packed { .. }) {
        SEC_PAYLOAD
    } else {
        SEC_ITEMS
    };
    encode_frame(
        &[(SEC_HEAD, &blob[..head_len]), (items_sec, &blob[head_len..])],
        out,
    );
}

/// Decode a frame back into a [`ShardMessage`]. Tensor buffers come from
/// `scratch`'s recycle pool when shapes match, so a steady-state decode
/// allocates O(message) at worst and nothing per column. Corrupted or
/// truncated input is a clean `Err`, never a panic.
pub fn decode_message(bytes: &[u8], scratch: &mut AggScratch) -> crate::Result<ShardMessage> {
    let frame = decode_frame(bytes)?;
    let head = frame
        .section(SEC_HEAD)
        .context("wire frame is missing the HEAD section")?;
    let mut r = Reader::new(head);
    let kind = r.take_u8()?;
    let shard = r.take_usize()?;
    let round = r.take_usize()?;
    let base = r.take_usize()?;
    let count = r.take_usize()?;
    if kind == KIND_FAULT {
        return Ok(ShardMessage::Fault { shard, round });
    }
    let items_bytes = if kind == KIND_PACKED {
        frame
            .section(SEC_PAYLOAD)
            .context("wire frame is missing the PAYLOAD section")?
    } else {
        frame
            .section(SEC_ITEMS)
            .context("wire frame is missing the ITEMS section")?
    };
    // every item costs at least its ok/err byte, so a lying count cannot
    // drive the Vec reservation past the frame size
    if count > items_bytes.len() {
        bail!("wire message claims {count} items in {} bytes", items_bytes.len());
    }
    let mut r = Reader::new(items_bytes);
    match kind {
        KIND_RESULTS => {
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(if r.take_bool()? {
                    let nparams = r.take_usize()?;
                    if nparams > r.remaining() {
                        bail!("wire result claims {nparams} params, frame too short");
                    }
                    let mut params = Vec::with_capacity(nparams);
                    for _ in 0..nparams {
                        params.push(take_wire_tensor(&mut r, scratch)?);
                    }
                    let mean_loss = r.take_f64()?;
                    let mean_acc = r.take_f64()?;
                    let steps = r.take_usize()?;
                    let weight = r.take_f64()?;
                    Ok(LocalResult { params, mean_loss, mean_acc, steps, weight })
                } else {
                    Err(take_wire_err(&mut r, scratch)?)
                });
            }
            Ok(ShardMessage::Results { shard, round, base, items })
        }
        KIND_DELTAS => {
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(if r.take_bool()? {
                    let ntensors = r.take_usize()?;
                    if ntensors > r.remaining() {
                        bail!("wire deltas claim {ntensors} tensors, frame too short");
                    }
                    let mut tensors = Vec::with_capacity(ntensors);
                    for _ in 0..ntensors {
                        tensors.push(take_wire_tensor(&mut r, scratch)?);
                    }
                    Ok(tensors)
                } else {
                    Err(take_wire_err(&mut r, scratch)?)
                });
            }
            Ok(ShardMessage::Deltas { shard, base, items })
        }
        KIND_PACKED => {
            let mut items = Vec::with_capacity(count);
            for _ in 0..count {
                items.push(if r.take_bool()? {
                    let payload = take_payload(&mut r, scratch)?;
                    let mean_loss = r.take_f64()?;
                    let mean_acc = r.take_f64()?;
                    let steps = r.take_usize()?;
                    let weight = r.take_f64()?;
                    Ok(PackedResult { payload, mean_loss, mean_acc, steps, weight })
                } else {
                    Err(take_wire_err(&mut r, scratch)?)
                });
            }
            Ok(ShardMessage::Packed { shard, round, base, items })
        }
        other => bail!("unknown shard message kind {other}"),
    }
}

/// Decode one per-client error string into a pooled `String` from
/// `scratch`, so steady-state decode reuses error-shell capacity instead
/// of allocating a fresh `String` per failed client every frame.
fn take_wire_err(r: &mut Reader<'_>, scratch: &mut AggScratch) -> crate::Result<String> {
    let mut e = scratch.take_err();
    r.take_str_into(&mut e)?;
    Ok(e)
}

// ---------------------------------------------------------------------
// transport
// ---------------------------------------------------------------------

/// Sending half of a byte-frame channel. Implementations deliver each
/// `send` as one whole frame on the receiving side.
pub trait FrameTx: Send {
    fn send(&mut self, frame: &[u8]) -> crate::Result<()>;
}

/// Receiving half: blocks for the next frame and leaves it in `buf`
/// (cleared first; capacity is reused).
pub trait FrameRx {
    fn recv_into(&mut self, buf: &mut Vec<u8>) -> crate::Result<()>;
}

/// In-memory transport over `std::sync::mpsc` — the scoped-thread
/// deployment. One owned `Vec<u8>` per frame: O(message), nothing per
/// element beyond the copy.
pub struct MemTx(std::sync::mpsc::Sender<Vec<u8>>);
/// Receiving half of [`mem_channel`].
pub struct MemRx(std::sync::mpsc::Receiver<Vec<u8>>);

/// Build a connected in-memory frame channel.
pub fn mem_channel() -> (MemTx, MemRx) {
    let (tx, rx) = std::sync::mpsc::channel();
    (MemTx(tx), MemRx(rx))
}

impl FrameTx for MemTx {
    fn send(&mut self, frame: &[u8]) -> crate::Result<()> {
        self.0
            .send(frame.to_vec())
            .map_err(|_| anyhow::anyhow!("shard frame channel closed"))
    }
}

impl FrameRx for MemRx {
    fn recv_into(&mut self, buf: &mut Vec<u8>) -> crate::Result<()> {
        let frame = self
            .0
            .recv()
            .map_err(|_| anyhow::anyhow!("shard frame channel closed before a frame arrived"))?;
        buf.clear();
        buf.extend_from_slice(&frame);
        Ok(())
    }
}

/// Length-prefixed framing over any byte stream (pipe, socket): each
/// frame travels as a `u64` little-endian byte count followed by the
/// frame bytes. This is the process-boundary deployment of the same
/// message layer the in-memory channel carries.
pub struct StreamTx<W: std::io::Write + Send> {
    w: W,
}

/// Receiving half of the stream transport.
pub struct StreamRx<R: std::io::Read> {
    r: R,
}

impl<W: std::io::Write + Send> StreamTx<W> {
    pub fn new(w: W) -> Self {
        Self { w }
    }
}

impl<R: std::io::Read> StreamRx<R> {
    pub fn new(r: R) -> Self {
        Self { r }
    }
}

impl<W: std::io::Write + Send> FrameTx for StreamTx<W> {
    fn send(&mut self, frame: &[u8]) -> crate::Result<()> {
        self.w.write_all(&(frame.len() as u64).to_le_bytes())?;
        self.w.write_all(frame)?;
        self.w.flush()?;
        Ok(())
    }
}

impl<R: std::io::Read> FrameRx for StreamRx<R> {
    fn recv_into(&mut self, buf: &mut Vec<u8>) -> crate::Result<()> {
        let mut len_bytes = [0u8; 8];
        self.r
            .read_exact(&mut len_bytes)
            .context("reading shard frame length")?;
        let len = u64::from_le_bytes(len_bytes);
        if len > MAX_FRAME_BYTES {
            bail!("shard frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap");
        }
        buf.clear();
        buf.resize(len as usize, 0);
        self.r
            .read_exact(buf)
            .context("reading shard frame body")?;
        Ok(())
    }
}

/// A connected [`StreamTx`] / [`StreamRx`] pair over an anonymous unix
/// socket pair — one shard side, one root side, across a real OS
/// descriptor (so the byte-stream transport is exercised end-to-end even
/// in single-process tests).
#[cfg(unix)]
pub fn unix_pair() -> crate::Result<(
    StreamTx<std::os::unix::net::UnixStream>,
    StreamRx<std::os::unix::net::UnixStream>,
)> {
    let (a, b) = std::os::unix::net::UnixStream::pair().context("creating unix socket pair")?;
    Ok((StreamTx::new(a), StreamRx::new(b)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_results() -> ShardMessage {
        ShardMessage::Results {
            shard: 2,
            round: 7,
            base: 5,
            items: vec![
                Ok(LocalResult {
                    params: vec![
                        Tensor::from_vec(&[2, 3], vec![1.0, -0.0, 2.5, f32::NAN, 4.0, -9.75]),
                        Tensor::from_vec(&[2], vec![0.125, 7.0]),
                    ],
                    mean_loss: 0.75,
                    mean_acc: 0.5,
                    steps: 3,
                    weight: 12.0,
                }),
                Err("client 9 exploded".to_string()),
            ],
        }
    }

    fn round_trip_fixpoint(msg: &ShardMessage) {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(msg, &mut blob, &mut frame);
        let mut scratch = AggScratch::new();
        let decoded = decode_message(&frame, &mut scratch).unwrap();
        let (mut blob2, mut frame2) = (Vec::new(), Vec::new());
        encode_message(&decoded, &mut blob2, &mut frame2);
        assert_eq!(frame, frame2, "encode -> decode -> encode is a fixpoint");
    }

    fn sample_packed() -> ShardMessage {
        use crate::fl::{DeltaPayload, QuantUpdate, SparseUpdate};
        ShardMessage::Packed {
            shard: 1,
            round: 9,
            base: 3,
            items: vec![
                Ok(PackedResult {
                    payload: DeltaPayload::SparseF32(SparseUpdate {
                        values: vec![vec![1.0, -0.0, f32::NAN], vec![], vec![2.5]],
                    }),
                    mean_loss: 0.5,
                    mean_acc: 0.25,
                    steps: 2,
                    weight: 8.0,
                }),
                Ok(PackedResult {
                    payload: DeltaPayload::SparseQ8(QuantUpdate {
                        scales: vec![0.125, 0.0],
                        values: vec![vec![-128, -1, 0, 127], vec![]],
                    }),
                    mean_loss: 0.75,
                    mean_acc: 0.5,
                    steps: 3,
                    weight: 4.0,
                }),
                Ok(PackedResult {
                    payload: DeltaPayload::DenseF32(vec![Tensor::from_vec(
                        &[2],
                        vec![0.125, -9.75],
                    )]),
                    mean_loss: 0.0,
                    mean_acc: 1.0,
                    steps: 1,
                    weight: 2.0,
                }),
                Err("client 4 exploded".to_string()),
            ],
        }
    }

    #[test]
    fn every_message_kind_round_trips_to_a_byte_fixpoint() {
        round_trip_fixpoint(&sample_results());
        round_trip_fixpoint(&ShardMessage::Deltas {
            shard: 0,
            base: 0,
            items: vec![
                Ok(vec![Tensor::from_vec(&[3], vec![0.0, 1.0, f32::INFINITY])]),
                Err("voter timed out".to_string()),
                Ok(vec![]),
            ],
        });
        round_trip_fixpoint(&ShardMessage::Fault { shard: 3, round: 11 });
        round_trip_fixpoint(&sample_packed());
    }

    #[test]
    fn packed_messages_travel_in_their_own_section() {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_packed(), &mut blob, &mut frame);
        let parsed = decode_frame(&frame).unwrap();
        assert!(parsed.section(SEC_PAYLOAD).is_some());
        assert!(parsed.section(SEC_ITEMS).is_none());
        let mut scratch = AggScratch::new();
        match decode_message(&frame, &mut scratch).unwrap() {
            ShardMessage::Packed { shard, round, base, items } => {
                assert_eq!((shard, round, base, items.len()), (1, 9, 3, 4));
                assert!(items[3].is_err());
            }
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    #[test]
    fn packed_corruption_and_truncation_are_clean_errors() {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_packed(), &mut blob, &mut frame);
        let mut scratch = AggScratch::new();
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0xA5;
            assert!(decode_message(&bad, &mut scratch).is_err(), "flip at {i} accepted");
        }
        for cut in 0..frame.len() {
            assert!(
                decode_message(&frame[..cut], &mut scratch).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn decode_survives_unknown_sections() {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_results(), &mut blob, &mut frame);
        // rebuild the frame with an extra section a future version might add
        let parsed = decode_frame(&frame).unwrap();
        let head = parsed.section(SEC_HEAD).unwrap().to_vec();
        let items = parsed.section(SEC_ITEMS).unwrap().to_vec();
        let mut extended = Vec::new();
        encode_frame(
            &[(SEC_HEAD, &head), (99, b"from the future"), (SEC_ITEMS, &items)],
            &mut extended,
        );
        let mut scratch = AggScratch::new();
        let decoded = decode_message(&extended, &mut scratch).unwrap();
        match decoded {
            ShardMessage::Results { shard, round, base, items } => {
                assert_eq!((shard, round, base, items.len()), (2, 7, 5, 2));
            }
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    #[test]
    fn corruption_and_truncation_are_clean_errors() {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_results(), &mut blob, &mut frame);
        let mut scratch = AggScratch::new();
        // flip every byte in turn: the checksum (or, for trailer bytes,
        // the compare against it) must reject each corruption cleanly
        for i in 0..frame.len() {
            let mut bad = frame.clone();
            bad[i] ^= 0xA5;
            assert!(decode_message(&bad, &mut scratch).is_err(), "flip at {i} accepted");
        }
        // every truncation point errors too
        for cut in 0..frame.len() {
            assert!(
                decode_message(&frame[..cut], &mut scratch).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn decode_reuses_pooled_tensor_buffers() {
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_results(), &mut blob, &mut frame);
        let mut scratch = AggScratch::new();
        let first = decode_message(&frame, &mut scratch).unwrap();
        if let ShardMessage::Results { items, .. } = first {
            for res in items.into_iter().flatten() {
                scratch.recycle(res.params);
            }
        }
        // second decode draws the same shapes back out of the pool
        let second = decode_message(&frame, &mut scratch).unwrap();
        match second {
            ShardMessage::Results { items, .. } => {
                let res = items[0].as_ref().unwrap();
                assert_eq!(res.params[0].shape(), &[2, 3]);
                assert_eq!(res.params[0].data()[0], 1.0);
                assert!(res.params[0].data()[3].is_nan());
            }
            other => panic!("decoded wrong kind: {other:?}"),
        }
    }

    #[test]
    fn mem_channel_delivers_whole_frames() {
        let (mut tx, mut rx) = mem_channel();
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&ShardMessage::Fault { shard: 1, round: 4 }, &mut blob, &mut frame);
        tx.send(&frame).unwrap();
        let mut buf = Vec::new();
        rx.recv_into(&mut buf).unwrap();
        assert_eq!(buf, frame);
    }

    #[cfg(unix)]
    #[test]
    fn unix_stream_transport_carries_identical_frames() {
        let (mut tx, mut rx) = unix_pair().unwrap();
        let (mut blob, mut frame) = (Vec::new(), Vec::new());
        encode_message(&sample_results(), &mut blob, &mut frame);
        let sent = frame.clone();
        let writer = std::thread::spawn(move || {
            tx.send(&frame).unwrap();
        });
        let mut buf = Vec::new();
        rx.recv_into(&mut buf).unwrap();
        writer.join().unwrap();
        assert_eq!(buf, sent);
        let mut scratch = AggScratch::new();
        let decoded = decode_message(&buf, &mut scratch).unwrap();
        let (mut blob2, mut frame2) = (Vec::new(), Vec::new());
        encode_message(&decoded, &mut blob2, &mut frame2);
        assert_eq!(frame2, sent, "fixpoint survives the stream transport");
    }

    #[cfg(unix)]
    #[test]
    fn stream_rx_rejects_absurd_length_prefix_before_allocating() {
        use std::io::Write;
        let (mut a, b) = std::os::unix::net::UnixStream::pair().unwrap();
        a.write_all(&u64::MAX.to_le_bytes()).unwrap();
        let mut rx = StreamRx::new(b);
        let mut buf = Vec::new();
        assert!(rx.recv_into(&mut buf).is_err());
        assert!(buf.capacity() < 1024, "no huge reservation happened");
    }
}
