//! The round engine — Algorithm 1 decomposed into composable layers.
//!
//! The historical coordinator ran one ~300-line function that hard-coded
//! a fully synchronous barrier. This module splits that loop along five
//! seams so that round *policy* and round *mechanics* evolve separately:
//!
//! * [`ClientExecutor`] — where per-client work executes, and the only
//!   layer that touches a runtime: [`LocalExecutor`] is the PJRT-backed
//!   in-process thread-pool backend, [`SimExecutor`] the runtime-free
//!   deterministic simulation backend (fleet-scale determinism suite),
//!   and [`ShardedExecutor`] the multi-aggregator tree that fans a
//!   round's cohort out to N shards over the [`wire`] framing and folds
//!   the slices back in `tree_reduce`'s fixed order — bit-identical to
//!   the single-engine path at every shard count (DESIGN.md §11).
//! * [`EventScheduler`] — the virtual-time model: per-client latencies
//!   become arrival *events*, and each [`SyncMode`] resolves those events
//!   into a barrier decision instead of an implicit `fold(max)`.
//! * [`RoundPlan`] / [`RoundOutcome`] — the narrow calibration interface
//!   through which the [`crate::policy::MitigationPolicy`] seam drives
//!   the engine (DESIGN.md §14).
//! * [`SyncMode`] — the round-synchronization policy: classic full
//!   barrier (bit-identical to the historical loop), SALF-style deadline
//!   rounds, or FedBuff-style buffered semi-async rounds.
//! * the **fleet seam** — with `ExperimentConfig::fleet_size` set, the
//!   engine holds a [`Fleet`] of lightweight descriptors, samples a
//!   per-round cohort through [`crate::fl::sample_cohort`], hydrates only
//!   that cohort's shards ([`crate::data::ShardSource`]), and lets a
//!   seeded [`scenario::ScenarioSim`] script churn / straggler drift /
//!   speed fluctuation. Peak resident data tracks the cohort, never the
//!   fleet.
//!
//! * the **snapshot seam** — [`RoundEngine::snapshot_at`] captures every
//!   piece of cross-round state at a round boundary and
//!   [`RoundEngine::restore`] reinstalls it, so a run killed mid-flight
//!   resumes bit-identically (`crate::snapshot`, DESIGN.md §6).
//!   [`RoundEngine::run`] honors `ExperimentConfig::{checkpoint_every,
//!   checkpoint_dir, resume_from}`.
//!
//! * the **chaos seam** — a seeded [`chaos::ChaosPlan`] (compiled from
//!   `--chaos` specs exactly like scenarios) injects client vanish/hang/
//!   corrupt/NaN faults and shard crashes on dedicated PCG streams; the
//!   engine degrades gracefully through deadline drops, the always-on
//!   [`UpdateValidator`] + [`QuarantineLedger`], a `--quorum` floor
//!   ([`QuorumFailed`] is typed, never a panic), and the sharded tree's
//!   bounded retry budget (DESIGN.md §13).
//!
//! * the **hot-path seam** — the engine owns an [`AggScratch`] arena and
//!   mirrors the executor's thread budget ([`ClientExecutor::threads`])
//!   into the allocation-free parallel aggregation
//!   ([`crate::fl::fedavg_into`]) and the fused invariant-observation
//!   sweep; results are bit-identical at every thread count
//!   (DESIGN.md §7).
//!
//! See DESIGN.md §3 and §5 for the layering diagram, the exact SyncMode
//! semantics and the RNG-stream layout.

pub mod chaos;
pub mod executor;
pub mod plan;
pub mod scenario;
pub mod sched;
pub mod sharded;
pub mod wire;

pub use chaos::{
    ChaosConfig, ChaosPlan, ClientFault, QuarEntry, QuarantineLedger, QuorumFailed,
    ShardEvent, ShardFaultKind, UpdateValidator,
};
pub use executor::{ClientExecutor, LocalExecutor, SimExecutor, TrainJob};
pub use plan::{MaskTable, RateTable, RoundOutcome, RoundPlan};
pub use scenario::{ScenarioConfig, ScenarioSim};
pub use sched::{ClientArrival, EventScheduler, Resolution};
pub use sharded::{ShardFault, ShardedExecutor};

use crate::coordinator::{ExperimentConfig, ExperimentResult, RoundRecord};
use crate::data::{partition, FlData, ShardSizes, ShardSource, Split};
use crate::dropout::MaskSet;
use crate::fl::{
    self, fedavg_into, policy_weight, sample_cohort, staleness_discount, AggScratch, Client,
    ClientUpdate, Codec, DeltaPayload, Fleet, UpdateCodec,
};
use crate::model::ModelSpec;
use crate::policy::{MitigationPolicy, MitigationState, PlanCtx, UpdateCtx};
use crate::snapshot::{config_fingerprint, Snapshot, SnapshotStore, StaleEntry};
use crate::straggler::{FluctuationSchedule, PerfModel};
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;
use crate::util::stats;
use std::time::Instant;

/// Cap on how many non-stragglers vote on invariance per calibration —
/// the information saturates quickly and each voter costs one
/// `delta_step` execution (documented server-side optimization).
const MAX_DELTA_VOTERS: usize = 16;

/// Fleets at or above this size get a *streaming* shard-size table
/// (`ShardSizes::Lognormal`): sizes are computed per index on demand, so
/// descriptor memory stays sub-linear in the fleet. Smaller fleets keep
/// the historical materialized table — its sequential PRNG stream is not
/// per-index addressable, and every existing ≤100k trajectory is pinned
/// to it bit-for-bit.
const STREAMING_FLEET_MIN: usize = 200_000;

/// Marker error for `ExperimentConfig::crash_after` fault injection:
/// the run stopped *by request* after a checkpointed round boundary.
/// The engine never kills the process itself (it may be embedded in a
/// larger harness); the `fluid` binary downcasts to this and exits 137,
/// as if SIGKILLed — which is what the kill/resume soak asserts on.
#[derive(Debug)]
pub struct FaultInjected {
    /// rounds completed when the injected crash fired
    pub after_rounds: usize,
}

impl std::fmt::Display for FaultInjected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fault injection: run aborted after {} completed round(s)",
            self.after_rounds
        )
    }
}

impl std::error::Error for FaultInjected {}

/// Round-synchronization policy: when does a round end, and what happens
/// to updates that arrive after it does?
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum SyncMode {
    /// Wait for every participant (the paper's protocol, and the
    /// pre-engine behavior bit-for-bit).
    #[default]
    FullBarrier,
    /// SALF-style deadline round: aggregate whatever arrived by
    /// `multiple_of_t_target · T_target`; late updates are discarded and
    /// their clients start fresh next round.
    Deadline { multiple_of_t_target: f64 },
    /// FedBuff-style semi-async round: aggregate as soon as `k` updates
    /// arrive. Late updates are buffered and fold into a later
    /// aggregation with a staleness-discounted weight; their clients stay
    /// busy (skip participation) until the update lands.
    Buffered { k: usize },
}

impl SyncMode {
    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::FullBarrier => "full-barrier",
            SyncMode::Deadline { .. } => "deadline",
            SyncMode::Buffered { .. } => "buffered",
        }
    }
}

/// A buffered late update awaiting a future aggregation (Buffered mode).
struct StaleUpdate {
    result: fl::LocalResult,
    mask: MaskSet,
    /// absolute virtual time the update lands at the server
    arrives_at: f64,
    /// round whose broadcast params the update was trained from
    born_round: usize,
    /// the client that produced it (per-client staleness admission)
    client: usize,
}

/// Where client shards live.
///
/// Classic runs materialize every client once (the pre-fleet behavior,
/// bit-identical); fleet runs hydrate the sampled cohort per round and
/// drop it at round end.
enum ClientStore {
    Eager(Vec<Client>),
    Lazy(Box<dyn ShardSource>),
}

/// The layered round loop: owns all cross-round state and executes
/// [`ExperimentConfig::rounds`] rounds through an executor and the event
/// scheduler.
pub struct RoundEngine<'a, E: ClientExecutor> {
    cfg: &'a ExperimentConfig,
    executor: E,
    spec: ModelSpec,
    /// population size: `fleet_size` in fleet mode, `cfg.clients` classic
    n: usize,
    fleet: Fleet,
    store: ClientStore,
    test_split: Split,
    scheduler: EventScheduler,
    scenario: Option<ScenarioSim>,
    /// the mitigation seam (`policy/`): who is a straggler and what
    /// each one gets — dropout masks (the FLuID family), elastic
    /// aggregation, lag-tolerant admission, or soft training (the zoo).
    /// Round mechanics never reach around it into policy state.
    mitigation: Box<dyn MitigationPolicy + 'a>,
    params: Vec<Tensor>,
    full_mask: MaskSet,
    /// actual end-to-end latency each client last reported (under its
    /// assigned sub-model) — `straggler_time` reads the last-known value
    /// even for stragglers not sampled this round, as the pre-engine
    /// loop did
    last_latencies: Vec<f64>,
    /// full-model-normalized latency each client last reported — the
    /// profile straggler detection reads (see `PerfModel::client_timing`)
    last_full_latencies: Vec<f64>,
    vtime: f64,
    calib_total: f64,
    train_wall: f64,
    /// buffered late updates (Buffered mode only)
    stale: Vec<StaleUpdate>,
    /// absolute virtual time each client becomes free; a client busy past
    /// a round's start skips that round's participation
    free_at: Vec<f64>,
    /// server-side worker budget, mirrored from the executor seam —
    /// drives parallel aggregation and the fused observation sweep
    threads: usize,
    /// reusable arena for the aggregation / observation / snapshot hot
    /// paths (DESIGN.md §7): grown on the first round, allocation-free
    /// afterwards
    scratch: AggScratch,
    /// the update codec (`ExperimentConfig::compress`): dense passthrough
    /// by default, mask-sparse or int8-quantized payloads otherwise. Owns
    /// the per-client q8 error-feedback residuals, which snapshot/restore
    /// carry in the RESID section (DESIGN.md §12)
    codec: Codec,
    /// the bound chaos script (`ExperimentConfig::chaos`): every fault
    /// draw is a pure function of (plan, round, client) on a dedicated
    /// PCG stream, so `None` consumes no randomness and a faulted run
    /// replays bit-identically across thread and shard counts
    /// (DESIGN.md §13)
    chaos: Option<ChaosPlan>,
    /// always-on admission check for client updates (finite values,
    /// matching shapes, relative norm bound) — allocation-free on the
    /// clean path
    validator: UpdateValidator,
    /// strike-escalating bar list for clients whose updates failed
    /// validation; persisted through the optional QUAR snapshot section
    quarantine: QuarantineLedger,
}

impl<'a, E: ClientExecutor> RoundEngine<'a, E> {
    pub fn new(cfg: &'a ExperimentConfig, executor: E) -> crate::Result<Self> {
        let source = if let Some(n) = cfg.fleet_size {
            let base = cfg.samples_per_client.max(2);
            let sizes = if n >= STREAMING_FLEET_MIN {
                // million-client regime: O(1) memory, sizes computed per
                // index on demand (different draw stream than the
                // materialized table, but only engaged above the
                // threshold where no pinned trajectory exists)
                ShardSizes::lognormal(n, base, 0.45, cfg.seed)
            } else {
                ShardSizes::from(partition::lognormal_shard_sizes(
                    n, base, 0.45, cfg.seed,
                ))
            };
            Some(crate::data::shard_source_for_model(&cfg.model, sizes, cfg.seed))
        } else {
            None
        };
        Self::build(cfg, executor, source)
    }

    /// Fleet-mode constructor with an explicit shard source (tests wrap
    /// the built-in sources to observe hydration).
    pub fn with_shard_source(
        cfg: &'a ExperimentConfig,
        executor: E,
        source: Box<dyn ShardSource>,
    ) -> crate::Result<Self> {
        anyhow::ensure!(
            cfg.fleet_size.is_some(),
            "with_shard_source requires fleet mode (fleet_size set)"
        );
        Self::build(cfg, executor, Some(source))
    }

    fn build(
        cfg: &'a ExperimentConfig,
        executor: E,
        source: Option<Box<dyn ShardSource>>,
    ) -> crate::Result<Self> {
        cfg.validate()?;
        let spec = executor.spec().clone();
        let n = cfg.fleet_size.unwrap_or(cfg.clients);
        anyhow::ensure!(n > 0, "experiment needs at least one client");

        // fleet + data + clients ---------------------------------------------
        let (fleet, store, test_split) = match source {
            Some(src) => {
                anyhow::ensure!(
                    src.num_shards() == n,
                    "shard source has {} shards for a fleet of {n}",
                    src.num_shards()
                );
                let mut fleet = Fleet::synthetic_pool(n, cfg.seed ^ 0xF1EE7);
                // client c's shard is shard_of(c); one O(n) bulk install
                // into the weighted sampler's Fenwick tree
                let lens: Vec<usize> =
                    (0..n).map(|c| src.shard_len(fleet.shard_of(c))).collect();
                fleet.set_data_lens(lens.into_iter());
                let test = src.test().clone();
                (fleet, ClientStore::Lazy(src), test)
            }
            None => {
                let fleet = Fleet::classic(n, cfg.mobile_fleet, cfg.seed ^ 0xF1EE7);
                let data =
                    FlData::for_model(&cfg.model, n, cfg.samples_per_client, cfg.seed);
                let test = data.test.clone();
                let clients: Vec<Client> = data
                    .clients
                    .iter()
                    .enumerate()
                    .map(|(i, split)| Client::new(i, fleet.device_of(i), split.clone()))
                    .collect();
                (fleet, ClientStore::Eager(clients), test)
            }
        };

        let perf = PerfModel::new(&cfg.model, spec.size_bytes());
        // the natural straggler is the slowest base device — excluded from
        // the fluctuation protocol so that the straggler identity really
        // changes
        let natural_straggler = fleet.slowest(&cfg.model);
        let scenario = cfg
            .scenario
            .as_ref()
            .map(|sc| ScenarioSim::new(sc.clone(), cfg.seed ^ 0x5CE0));
        let fluct = if let Some(sim) = &scenario {
            sim.fluctuation()
        } else if cfg.fluctuation {
            FluctuationSchedule::paper_marks(n, natural_straggler, cfg.seed ^ 0xF1C)
        } else {
            FluctuationSchedule::none()
        };

        let mitigation = crate::policy::build(cfg, &spec, n);
        let params = spec.init_params(cfg.seed);
        let full_mask = MaskSet::full(&spec);
        let threads = executor.threads();

        Ok(Self {
            cfg,
            executor,
            spec,
            n,
            fleet,
            store,
            test_split,
            scheduler: EventScheduler::new(perf, fluct),
            scenario,
            mitigation,
            params,
            full_mask,
            last_latencies: vec![0.0; n],
            last_full_latencies: vec![0.0; n],
            vtime: 0.0,
            calib_total: 0.0,
            train_wall: 0.0,
            stale: Vec::new(),
            free_at: vec![0.0; n],
            threads,
            scratch: AggScratch::new(),
            codec: Codec::new(cfg.compress),
            chaos: cfg
                .chaos
                .as_ref()
                .map(|c| ChaosPlan::new(c.clone(), cfg.seed)),
            validator: UpdateValidator::default(),
            quarantine: QuarantineLedger::default(),
        })
    }

    fn fleet_mode(&self) -> bool {
        self.cfg.fleet_size.is_some()
    }

    /// Run every round to completion, honoring the checkpoint/resume
    /// config: `resume_from` restores a snapshot before the first round,
    /// `checkpoint_every`/`checkpoint_dir` persist one at matching round
    /// boundaries, and `crash_after` is the soak suite's fault injection.
    pub fn run(mut self) -> crate::Result<ExperimentResult> {
        let cfg = self.cfg;
        let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
        let mut start_round = 0usize;
        if let Some(path) = &cfg.resume_from {
            let snap = SnapshotStore::load_resume(path)?;
            let (next, history) = self.restore(snap)?;
            start_round = next;
            records = history;
        }
        let store = if cfg.checkpoint_every > 0 {
            let dir = cfg.checkpoint_dir.as_ref().ok_or_else(|| {
                anyhow::anyhow!("checkpoint_every is set but checkpoint_dir is not")
            })?;
            Some(SnapshotStore::new(dir, cfg.checkpoint_keep)?)
        } else {
            None
        };
        for round in start_round..cfg.rounds {
            let plan = self.plan_round(round);
            let o = self.run_round(&plan)?;
            self.calib_total += o.calibration_secs;
            records.push(RoundRecord {
                round,
                round_time: o.round_time,
                vtime: self.vtime,
                cohort: plan.selected.clone(),
                straggler_ids: plan.straggler_ids.clone(),
                straggler_rates: plan.straggler_ids.iter().map(|&c| plan.rate(c)).collect(),
                t_target: o.t_target,
                straggler_time: o.straggler_time,
                train_loss: o.train_loss,
                train_acc: o.train_acc,
                test_loss: o.test_loss,
                test_acc: o.test_acc,
                invariant_fraction: o.invariant_fraction,
                calibration_secs: o.calibration_secs,
                aggregated: o.aggregated,
                dropped_updates: o.dropped_updates,
                stale_folded: o.stale_folded,
                update_bytes: o.update_bytes,
                vanished: o.vanished,
                quarantined: o.quarantined,
                shard_retries: o.shard_retries,
                quorum_fraction: o.quorum_fraction,
                straggler_wait: o.straggler_wait,
                admitted_stale: o.admitted_stale,
                soft_fraction: o.soft_fraction,
            });
            if let Some(store) = &store {
                if (round + 1) % cfg.checkpoint_every == 0 {
                    // encode through the scratch arena: steady-state
                    // checkpoint writes reuse the same buffers
                    let snap = self.snapshot_at(round + 1, &records);
                    store.save_with(
                        &snap,
                        &mut self.scratch.snap_blob,
                        &mut self.scratch.snap_bytes,
                    )?;
                }
            }
            if let Some(limit) = cfg.crash_after {
                if round + 1 >= limit {
                    return Err(anyhow::Error::new(FaultInjected {
                        after_rounds: round + 1,
                    }));
                }
            }
        }

        let last_eval = records
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| (r.test_loss, r.test_acc))
            .unwrap_or((f64::NAN, f64::NAN));

        Ok(ExperimentResult {
            model: cfg.model.clone(),
            policy: cfg.policy,
            mitigation: cfg.mitigation,
            records,
            final_test_acc: last_eval.1,
            final_test_loss: last_eval.0,
            total_vtime: self.vtime,
            calibration_total: self.calib_total,
            seed: cfg.seed,
            train_wall_total: self.train_wall,
        })
    }

    /// Capture the full resumable state at a round boundary: `next_round`
    /// rounds have completed (and produced `records`), and the returned
    /// snapshot replays the rest bit-identically through [`Self::restore`].
    pub fn snapshot_at(&self, next_round: usize, records: &[RoundRecord]) -> Snapshot {
        // one dispatch site: the policy exports its own evolving state
        // (dropout PRNG / thresholds, detection, controller, zoo ledger)
        let mit = self.mitigation.snapshot_state();
        Snapshot {
            fingerprint: config_fingerprint(self.cfg),
            next_round,
            vtime: self.vtime,
            calib_total: self.calib_total,
            train_wall: self.train_wall,
            params: self.params.clone(),
            policy: mit.policy,
            availability: self.fleet.availability(),
            detection: mit.detection,
            ctrl: mit.ctrl,
            zoo: mit.zoo,
            last_latencies: self.last_latencies.clone(),
            last_full_latencies: self.last_full_latencies.clone(),
            free_at: self.free_at.clone(),
            stale: self
                .stale
                .iter()
                .map(|s| StaleEntry {
                    params: s.result.params.clone(),
                    weight: s.result.weight,
                    mean_loss: s.result.mean_loss,
                    mean_acc: s.result.mean_acc,
                    steps: s.result.steps,
                    mask: s.mask.tensors().to_vec(),
                    arrives_at: s.arrives_at,
                    born_round: s.born_round,
                    client: s.client,
                })
                .collect(),
            resid: self.codec.export_resid(),
            quarantine: self.quarantine.export(),
            records: records.to_vec(),
        }
    }

    /// Install a snapshot's state into a freshly-built engine. Validates
    /// the config fingerprint and every per-client table length before
    /// touching any state, so a mismatched snapshot cannot half-apply.
    /// Returns `(next_round, completed-round history)`.
    pub fn restore(
        &mut self,
        snap: Snapshot,
    ) -> crate::Result<(usize, Vec<RoundRecord>)> {
        let fp = config_fingerprint(self.cfg);
        anyhow::ensure!(
            snap.fingerprint == fp,
            "snapshot was taken under a different experiment configuration\n  \
             snapshot: {}\n  current:  {fp}",
            snap.fingerprint
        );
        anyhow::ensure!(
            snap.next_round <= self.cfg.rounds,
            "snapshot round cursor {} exceeds configured rounds {}",
            snap.next_round,
            self.cfg.rounds
        );
        anyhow::ensure!(
            snap.records.len() == snap.next_round,
            "snapshot history has {} records for round cursor {}",
            snap.records.len(),
            snap.next_round
        );
        let n = self.n;
        anyhow::ensure!(
            snap.availability.len() == n
                && snap.last_latencies.len() == n
                && snap.last_full_latencies.len() == n
                && snap.free_at.len() == n,
            "snapshot population tables sized for {} clients, engine has {n}",
            snap.availability.len()
        );
        anyhow::ensure!(
            snap.params.len() == self.params.len(),
            "snapshot has {} parameter tensors, model has {}",
            snap.params.len(),
            self.params.len()
        );
        for (i, (a, b)) in snap.params.iter().zip(&self.params).enumerate() {
            anyhow::ensure!(
                a.shape() == b.shape(),
                "parameter {i}: snapshot shape {:?} vs model {:?}",
                a.shape(),
                b.shape()
            );
        }
        // Semantic validation of the scheduler section: the codec only
        // guarantees well-formed *encoding*, so a hand-crafted snapshot
        // could still carry out-of-range ids or mismatched shapes that
        // would panic rounds later. Reject them here instead.
        if let Some(d) = &snap.detection {
            anyhow::ensure!(
                d.stragglers.iter().all(|&c| c < n),
                "snapshot detection names client ids outside the {n}-client population"
            );
            anyhow::ensure!(
                d.rates.len() == d.stragglers.len()
                    && d.speedups.len() == d.stragglers.len(),
                "snapshot detection tables misaligned: {} stragglers, {} rates, {} speedups",
                d.stragglers.len(),
                d.rates.len(),
                d.speedups.len()
            );
        }
        // CTRL is optional: snapshots from pre-controller writers carry
        // none, and the controller then starts fresh (paper mode keeps
        // its whole calibration in the SCHED detection anyway).
        if let Some(ctrl) = &snap.ctrl {
            anyhow::ensure!(
                ctrl.profile.len() == n && ctrl.measured.len() == n && ctrl.rates.len() == n,
                "snapshot controller tables sized for {} clients, engine has {n}",
                ctrl.profile.len()
            );
            anyhow::ensure!(
                ctrl.rates.iter().all(|r| r.is_finite() && *r > 0.0 && *r <= 1.0),
                "snapshot controller carries keep-rates outside (0, 1]"
            );
        }
        let groups = self.full_mask.num_groups();
        for (i, s) in snap.stale.iter().enumerate() {
            anyhow::ensure!(
                s.params.len() == self.params.len()
                    && s.params
                        .iter()
                        .zip(&self.params)
                        .all(|(a, b)| a.shape() == b.shape()),
                "stale update {i}: parameter tensors do not match the model"
            );
            anyhow::ensure!(
                s.mask.len() == groups
                    && s.mask
                        .iter()
                        .zip(self.full_mask.tensors())
                        .all(|(a, b)| a.shape() == b.shape()),
                "stale update {i}: mask tensors do not match the model's {groups} groups"
            );
            anyhow::ensure!(
                s.born_round < snap.next_round,
                "stale update {i}: born in round {} but only {} rounds completed",
                s.born_round,
                snap.next_round
            );
            anyhow::ensure!(
                s.client < n,
                "stale update {i}: client {} is outside the {n}-client population",
                s.client
            );
        }
        // QUAR is optional: snapshots from pre-chaos writers carry none
        // and the ledger starts empty. `from_entries` re-validates the
        // sort/strike invariants a corrupted section could break.
        let quarantine = QuarantineLedger::from_entries(snap.quarantine)
            .map_err(|e| anyhow::anyhow!("snapshot quarantine section: {e}"))?;
        anyhow::ensure!(
            quarantine.entries().iter().all(|e| e.client < n),
            "snapshot quarantine ledger names client ids outside the {n}-client population"
        );
        // One dispatch site: the policy validates its own state pairing
        // (a mismatched PolicyState/ZooState variant is still a clean
        // fingerprint-style error) and installs detection + controller.
        self.mitigation.restore_state(MitigationState {
            policy: snap.policy,
            detection: snap.detection,
            ctrl: snap.ctrl,
            zoo: snap.zoo,
        })?;
        // RESID validates inside import_resid (per-client tensor counts
        // and lengths against the spec) before any state is installed
        self.codec.import_resid(snap.resid, &self.spec)?;
        self.fleet.set_availability(&snap.availability);
        self.stale = snap
            .stale
            .into_iter()
            .map(|s| StaleUpdate {
                result: fl::LocalResult {
                    params: s.params,
                    mean_loss: s.mean_loss,
                    mean_acc: s.mean_acc,
                    steps: s.steps,
                    weight: s.weight,
                },
                mask: MaskSet::from_tensors(s.mask),
                arrives_at: s.arrives_at,
                born_round: s.born_round,
                client: s.client,
            })
            .collect();
        self.quarantine = quarantine;
        self.params = snap.params;
        self.last_latencies = snap.last_latencies;
        self.last_full_latencies = snap.last_full_latencies;
        self.free_at = snap.free_at;
        self.vtime = snap.vtime;
        self.calib_total = snap.calib_total;
        self.train_wall = snap.train_wall;
        Ok((snap.next_round, snap.records))
    }

    /// Server-side planning: scenario tick, sampling, straggler
    /// recalibration, and sub-model assignment (Algorithm 1 lines 18-22).
    fn plan_round(&mut self, round: usize) -> RoundPlan {
        let cfg = self.cfg;
        let n = self.n;
        let t_frac = round as f64 / cfg.rounds.max(1) as f64;
        let round_seed = cfg.seed ^ ((round as u64) << 32);

        // --- scenario tick (fleet dynamics) ---------------------------------
        // churn applies as sparse deltas: O(expected flips), not O(fleet)
        if let Some(sim) = &self.scenario {
            sim.apply_churn(round, &mut self.fleet);
        }

        // --- client sampling (A.6 / fleet cohort) ---------------------------
        let selected: Vec<usize> = if self.fleet_mode() {
            let k = cfg.sample_k.clamp(1, n);
            let mut rng = Pcg32::new(cfg.seed ^ 0x5A_3917, round as u64);
            let mut s = sample_cohort(&mut self.fleet, cfg.sampler, k, &mut rng);
            s.sort_unstable();
            s
        } else if cfg.sample_fraction >= 1.0 {
            (0..n).collect()
        } else {
            let mut rng = Pcg32::new(cfg.seed ^ 0xA0_0000, round as u64);
            let k = ((n as f64 * cfg.sample_fraction).ceil() as usize).clamp(1, n);
            let mut s = rng.sample_indices(n, k);
            s.sort_unstable();
            s
        };

        // --- mitigation planning (recalibration + assignment) ---------------
        // The seam: the policy recalibrates its detection and decides who
        // is a straggler and what each one gets — a sub-model mask (the
        // FLuID family), a trimmed step budget (Helios), or nothing but
        // membership (FedProx / SAFA). The engine only executes.
        let calib_start = Instant::now();
        let assignments = self.mitigation.plan(PlanCtx {
            round,
            selected: &selected,
            fleet_mode: cfg.fleet_size.is_some(),
            last_full_latencies: &self.last_full_latencies,
            spec: &self.spec,
            full_mask: &self.full_mask,
        });
        let crate::policy::Assignments {
            straggler_ids,
            rates,
            masks,
            train_frac,
            t_target,
            exclude_stragglers,
        } = assignments;
        let masks = masks.unwrap_or_else(|| MaskTable::new(self.full_mask.clone()));
        let mut straggler_sorted = straggler_ids.clone();
        straggler_sorted.sort_unstable();
        let calib_secs = calib_start.elapsed().as_secs_f64();

        // --- participation --------------------------------------------------
        // A selected client sits a round out when it churned away (fleet
        // scenarios), is still busy finishing a previous semi-async
        // round, or is serving a quarantine bar; its buffered update
        // folds in when it lands. Classic synchronous runs mark nobody
        // unavailable or busy, and a clean run's ledger stays empty.
        self.quarantine.decay(round);
        let round_start = self.vtime;
        let active: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|&c| {
                self.fleet.is_available(c)
                    && self.free_at[c] <= round_start
                    && !self.quarantine.is_barred(c, round)
            })
            .collect();
        // Exclude policy: stragglers neither train nor aggregate.
        let participants: Vec<usize> = active
            .iter()
            .copied()
            .filter(|&c| {
                !exclude_stragglers || straggler_sorted.binary_search(&c).is_err()
            })
            .collect();

        RoundPlan {
            round,
            t_frac,
            round_seed,
            selected,
            active,
            participants,
            straggler_ids,
            straggler_sorted,
            rates,
            masks,
            t_target,
            is_calib_round: round % cfg.recalibrate_every == 0,
            calib_secs,
            train_frac,
        }
    }

    /// Execute one planned round: hydrate the cohort, train, schedule
    /// arrivals, resolve the barrier, aggregate (folding matured stale
    /// updates), observe deltas, evaluate.
    fn run_round(&mut self, plan: &RoundPlan) -> crate::Result<RoundOutcome> {
        let cfg = self.cfg;

        let mut calib_secs = plan.calib_secs;

        // --- local training (through the executor seam) ---------------------
        let jobs: Vec<TrainJob> = plan
            .participants
            .iter()
            .map(|&c| TrainJob {
                client: c,
                round: plan.round,
                steps: plan.train_steps(c, cfg.local_steps),
                lr: cfg.lr,
                seed: plan.round_seed,
                use_fused: cfg.use_fused_steps,
            })
            .collect();
        // fleet mode: only the sampled cohort's shards become data, and
        // they are dropped again at the end of the round
        let cohort_owned: Vec<Client> = match &self.store {
            ClientStore::Lazy(src) => plan
                .participants
                .iter()
                // hydrate through the descriptor's shard id — client id
                // and shard id coincide for the built-in fleets but the
                // indirection is part of the descriptor contract
                .map(|&c| {
                    Client::new(
                        c,
                        self.fleet.device_of(c),
                        src.hydrate(self.fleet.shard_of(c)),
                    )
                })
                .collect(),
            ClientStore::Eager(_) => Vec::new(),
        };
        let cohort: Vec<&Client> = match &self.store {
            ClientStore::Eager(clients) => {
                plan.participants.iter().map(|&c| &clients[c]).collect()
            }
            ClientStore::Lazy(_) => cohort_owned.iter().collect(),
        };
        let cohort_masks: Vec<&MaskSet> = plan
            .participants
            .iter()
            .map(|&c| plan.masks.get(c))
            .collect();
        let t0 = Instant::now();
        let results = self
            .executor
            .run_clients(&cohort, &cohort_masks, &self.params, &jobs);
        self.train_wall += t0.elapsed().as_secs_f64();
        drop(cohort);
        drop(cohort_owned);
        // shard-slice re-dispatches the executor performed for this
        // round (chaos shard faults or `--shard-crash-after` under a
        // retry budget), plus their deterministic virtual backoff
        let (shard_retries, retry_backoff_ms) = self.executor.drain_fault_retries();

        // --- fault injection + admission ------------------------------------
        // Client faults are drawn here at the root on dedicated
        // per-(round, client) PCG streams — pure data, independent of
        // thread and shard topology. A vanished/hung client is excluded
        // now, *before* any observation or aggregation mutates state; a
        // corrupted payload goes straight to quarantine (it failed wire
        // decode, there is nothing to validate); a NaN-poisoned update
        // flows on so the always-on validator catches it.
        let mut updates: Vec<(usize, fl::LocalResult)> = Vec::with_capacity(results.len());
        let mut vanished_sorted: Vec<usize> = Vec::new();
        let mut hung = 0usize;
        let mut quarantined = 0usize;
        for (i, r) in results.into_iter().enumerate() {
            let c = plan.participants[i];
            let mut u = r?;
            match self.chaos.as_ref().and_then(|p| p.client_fault(plan.round, c)) {
                Some(ClientFault::Vanish) => {
                    vanished_sorted.push(c);
                    continue;
                }
                Some(ClientFault::Hang) => {
                    vanished_sorted.push(c);
                    hung += 1;
                    continue;
                }
                Some(ClientFault::Corrupt) => {
                    self.quarantine.record(c, plan.round);
                    quarantined += 1;
                    continue;
                }
                Some(ClientFault::NanPoison) => {
                    let p = self.chaos.as_ref().expect("fault implies a plan");
                    if let Some(t) = u.params.first_mut() {
                        if !t.is_empty() {
                            let idx = p.poison_index(plan.round, c, t.len());
                            t.data_mut()[idx] = f32::NAN;
                        }
                    }
                }
                None => {}
            }
            if self.validator.validate(&u, &self.params).is_err() {
                self.quarantine.record(c, plan.round);
                quarantined += 1;
                continue;
            }
            updates.push((c, u));
        }

        // --- virtual-time arrival events ------------------------------------
        // cohort-aligned rate / comm-fraction slices: `active[i]` trains
        // under rates[i] and transmits comm_fractions[i] of the model —
        // O(cohort), no per-fleet table anywhere (non-stragglers transmit
        // the full model: fraction 1.0)
        let active_rates: Vec<f64> =
            plan.active.iter().map(|&c| plan.rate(c)).collect();
        let comm_fractions: Vec<f64> = plan
            .active
            .iter()
            .map(|&c| plan.masks.override_for(c).map_or(1.0, |m| m.comm_fraction()))
            .collect();
        let arrivals = self.scheduler.arrivals(
            &self.fleet,
            &plan.active,
            &active_rates,
            &comm_fractions,
            plan.t_frac,
            plan.round_seed,
        );

        // membership structures are cohort-sized and sorted — binary
        // searches instead of the former O(fleet) bitmaps per round
        // (`plan.participants` is already sorted: it filters the sorted
        // `selected` list, and `vanished_sorted` filters participants in
        // order)
        debug_assert!(plan.participants.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(vanished_sorted.windows(2).all(|w| w[0] < w[1]));

        // the barrier only waits on clients that actually train; with the
        // Exclude policy the round advances as soon as participants
        // finish, and a vanished/hung client's arrival never comes
        let participant_arrivals: Vec<ClientArrival> = arrivals
            .iter()
            .filter(|a| {
                plan.participants.binary_search(&a.client).is_ok()
                    && vanished_sorted.binary_search(&a.client).is_err()
            })
            .copied()
            .collect();
        let res = EventScheduler::resolve(cfg.sync_mode, &participant_arrivals, plan.t_target);
        // `res.on_time` is in arrival order (Buffered mode), not id order
        let mut on_time_sorted = res.on_time.clone();
        on_time_sorted.sort_unstable();
        let mut late_sorted: Vec<(usize, f64)> =
            res.late.iter().map(|a| (a.client, a.at)).collect();
        late_sorted.sort_unstable_by_key(|&(c, _)| c);

        // --- quorum ---------------------------------------------------------
        // Enough fresh, valid, on-time updates must survive the barrier,
        // or the round is refused *before* observation or aggregation
        // mutate any state — a typed error, never a silent half-round.
        // (Stale folds don't count: they are yesterday's evidence.)
        let fresh_on_time = updates
            .iter()
            .filter(|(c, _)| on_time_sorted.binary_search(c).is_ok())
            .count();
        let quorum_fraction = if plan.participants.is_empty() {
            1.0
        } else {
            fresh_on_time as f64 / plan.participants.len() as f64
        };
        if cfg.quorum > 0.0 && !plan.participants.is_empty() && quorum_fraction < cfg.quorum {
            return Err(anyhow::Error::new(QuorumFailed {
                round: plan.round,
                arrived: fresh_on_time,
                expected: plan.participants.len(),
                quorum: cfg.quorum,
            }));
        }

        for (a, &rate) in arrivals.iter().zip(&active_rates) {
            // a vanished/hung client reports nothing: no latency sample,
            // no controller evidence
            if vanished_sorted.binary_search(&a.client).is_ok() {
                continue;
            }
            self.last_latencies[a.client] = a.at;
            self.last_full_latencies[a.client] = a.full_latency;
            // close the loop through the seam: every policy sees the
            // arrivals (the FLuID family feeds its rate controller; the
            // applied rate rides along so evidence from a full-model
            // fallback round can never drive a feedback step)
            self.mitigation.observe(a.client, a.at, a.full_latency, rate);
        }

        let round_start = self.vtime;
        let mut round_time = res.round_time;
        if plan.participants.is_empty() {
            // degenerate semi-async corner: everyone is busy. Advance the
            // clock to the earliest buffered arrival so time still moves
            // and the buffer drains.
            if let Some(earliest) = self
                .stale
                .iter()
                .map(|s| s.arrives_at)
                .min_by(f64::total_cmp)
            {
                round_time = (earliest - round_start).max(0.0);
            }
        }
        if hung > 0 {
            // the server waits out the hung clients' deadline
            // (`deadline_mult` x the barrier target) before abandoning
            // them — a hang costs the round real virtual time
            let mult = self.chaos.as_ref().map_or(1.0, |p| p.cfg().deadline_mult);
            round_time = round_time.max(mult * plan.t_target.unwrap_or(res.round_time));
        }
        if self.chaos.is_some() && retry_backoff_ms > 0 {
            // shard-slice retries cost their deterministic virtual
            // backoff; gated on chaos so the legacy one-shot
            // `--shard-crash-after --shard-retry` trajectories stay
            // bit-identical to their pins
            round_time += retry_backoff_ms as f64 / 1e3;
        }
        let round_end = round_start + round_time;
        self.vtime = round_end;

        // last-known straggler latency, whether or not the straggler was
        // sampled this round (the pre-engine convention)
        let straggler_time = plan
            .straggler_ids
            .iter()
            .map(|&c| self.last_latencies[c])
            .fold(0.0f64, f64::max);
        let t_target = plan.t_target.unwrap_or(round_time);

        // --- invariant observation (non-straggler deltas, L1 kernel) --------
        // Runs before the aggregation set is assembled so that set can
        // take ownership of the update parameters instead of cloning
        // them; the observation only needs shared borrows and the
        // pre-aggregation globals either way.
        let mut calib_extra = 0.0f64;
        if plan.is_calib_round && self.mitigation.wants_delta_observations() {
            let t0 = Instant::now();
            let voters: Vec<&[Tensor]> = updates
                .iter()
                .filter(|(c, _)| {
                    on_time_sorted.binary_search(c).is_ok() && !plan.is_straggler(*c)
                })
                .take(MAX_DELTA_VOTERS)
                .map(|(_, u)| u.params.as_slice())
                .collect();
            let per_client = self.executor.run_deltas(&self.params, &voters);
            let per_client = per_client
                .into_iter()
                .collect::<crate::Result<Vec<_>>>()?;
            self.mitigation
                .observe_deltas(&per_client, self.threads, &mut self.scratch);
            calib_extra = t0.elapsed().as_secs_f64();
        }
        calib_secs += calib_extra;

        // --- aggregation set: fresh on-time updates, then matured stale ------
        // Fresh updates flow through the engine's codec: dense mode is a
        // pure passthrough (the bit-exact reference), sparse/q8 re-encode
        // into mask-packed payloads here at the root — `update_bytes`
        // sums what each payload costs on the wire.
        let mut agg: Vec<ClientUpdate> = Vec::with_capacity(updates.len());
        let mut losses: Vec<f64> = Vec::new();
        let mut accs: Vec<f64> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        let mut dropped_updates = 0usize;
        let mut update_bytes = 0usize;
        for (c, u) in updates {
            if on_time_sorted.binary_search(&c).is_ok() {
                // the policy may reweigh the update; `policy_weight` is a
                // pure passthrough at the default multiplier of 1.0, so
                // the FLuID family's weights stay bit-identical
                let m = self.mitigation.weigh(&UpdateCtx {
                    client: c,
                    staleness: 0,
                    is_straggler: plan.is_straggler(c),
                });
                let w = policy_weight(u.weight, m);
                losses.push(u.mean_loss);
                accs.push(u.mean_acc);
                weights.push(w);
                let mask = plan.masks.get(c).clone();
                let payload = self.codec.encode(
                    c as u64,
                    u.params,
                    &mask,
                    &self.params,
                    &self.spec,
                    &mut self.scratch,
                );
                update_bytes += payload.wire_bytes();
                agg.push(ClientUpdate {
                    payload,
                    weight: w,
                    mask,
                    staleness: 0,
                });
                self.mitigation.record_contribution(c, plan.round);
            } else {
                match cfg.sync_mode {
                    // late under a deadline: the update is discarded and
                    // the client abandons the round (free immediately)
                    SyncMode::Deadline { .. } => dropped_updates += 1,
                    // late under buffering: the update keeps computing
                    // and the client stays busy until it lands
                    SyncMode::Buffered { .. } => {
                        let at = late_sorted
                            .binary_search_by_key(&c, |&(lc, _)| lc)
                            .map(|i| late_sorted[i].1)
                            .expect("late participant has an arrival");
                        if !at.is_finite() {
                            // broken timing measurement: a NaN/inf busy
                            // clock would strand the client (and its
                            // update) forever — drop the update and
                            // leave the client free instead
                            dropped_updates += 1;
                        } else {
                            self.free_at[c] = round_start + at;
                            self.stale.push(StaleUpdate {
                                client: c,
                                result: u,
                                mask: plan.masks.get(c).clone(),
                                arrives_at: round_start + at,
                                born_round: plan.round,
                            });
                        }
                    }
                    // a full barrier never produces late arrivals
                    SyncMode::FullBarrier => unreachable!(),
                }
            }
        }

        // fold in previously-buffered updates that landed by round_end;
        // this round's lates were pushed above but cannot mature yet
        // (their arrival is past this round's own barrier)
        let mut stale_folded = 0usize;
        let mut still: Vec<StaleUpdate> = Vec::with_capacity(self.stale.len());
        for s in std::mem::take(&mut self.stale) {
            if s.born_round < plan.round && s.arrives_at <= round_end {
                let staleness = plan.round - s.born_round;
                // lag-tolerant policies gate admission on staleness
                // (SAFA's version lag); everyone else admits everything,
                // exactly as before the seam
                if !self.mitigation.admit_stale(s.client, staleness) {
                    dropped_updates += 1;
                    continue;
                }
                let m = self.mitigation.weigh(&UpdateCtx {
                    client: s.client,
                    staleness,
                    is_straggler: plan.is_straggler(s.client),
                });
                let w = policy_weight(s.result.weight, m);
                // metrics carry the same staleness-discounted weight
                // the aggregation applies
                losses.push(s.result.mean_loss);
                accs.push(s.result.mean_acc);
                weights.push(w * staleness_discount(staleness));
                // buffered folds stay dense: they were encoded against a
                // *previous* round's globals, so a sparse/q8 re-encode
                // against today's params would shift their reference
                // point. They never re-cross the wire anyway.
                let payload = DeltaPayload::DenseF32(s.result.params);
                update_bytes += payload.wire_bytes();
                agg.push(ClientUpdate {
                    payload,
                    weight: w,
                    mask: s.mask,
                    staleness,
                });
                self.mitigation.record_contribution(s.client, plan.round);
                stale_folded += 1;
            } else {
                still.push(s);
            }
        }
        self.stale = still;

        // --- metrics + masked FedAvg ----------------------------------------
        // example-weighted train metrics, matching FedAvg's weighting
        // (uniform shards reduce to the historical unweighted mean)
        let (train_loss, train_acc) = if agg.is_empty() {
            (f64::NAN, f64::NAN)
        } else {
            (
                stats::weighted_mean(&losses, &weights),
                stats::weighted_mean(&accs, &weights),
            )
        };
        let aggregated = agg.len();
        let mut new_params = if agg.is_empty() {
            self.params.clone()
        } else {
            // the allocation-free parallel hot path: accumulators and
            // output tensors come from the engine-owned arena
            fedavg_into(
                &self.spec,
                &self.params,
                &agg,
                cfg.aggregate,
                self.threads,
                &mut self.scratch,
            )
        };
        drop(agg);
        // elastic (FedProx-style) server step: pull the FedAvg proposal
        // back toward the previous globals. λ = 1.0 (every FLuID path)
        // skips the loop entirely, so pinned trajectories see no float op
        let lam = self.mitigation.elastic_lambda();
        if lam != 1.0 && aggregated > 0 {
            let l = lam as f32;
            for (np, op) in new_params.iter_mut().zip(&self.params) {
                for (x, &o) in np.data_mut().iter_mut().zip(op.data()) {
                    *x = l * *x + (1.0 - l) * o;
                }
            }
        }
        // retire the previous globals into the arena so next round's
        // aggregation writes into their buffers instead of allocating
        let prev = std::mem::replace(&mut self.params, new_params);
        self.scratch.recycle(prev);

        // --- evaluation -----------------------------------------------------
        let (test_loss, test_acc) =
            if plan.round % cfg.eval_every == 0 || plan.round + 1 == cfg.rounds {
                self.executor.evaluate(
                    &self.params,
                    self.full_mask.tensors(),
                    &self.test_split,
                )?
            } else {
                (f64::NAN, f64::NAN)
            };

        let invariant_fraction = self.mitigation.invariant_fraction();
        // mitigation-facing metrics: how long the round waited on its
        // slowest straggler past the target, and how much local work the
        // soft-training path actually scheduled
        let straggler_wait = (straggler_time - t_target).max(0.0);
        let soft_fraction = if plan.train_frac.is_empty() || plan.participants.is_empty() {
            1.0
        } else {
            plan.participants
                .iter()
                .map(|&c| plan.train_fraction(c))
                .sum::<f64>()
                / plan.participants.len() as f64
        };

        Ok(RoundOutcome {
            round_time,
            t_target,
            straggler_time,
            train_loss,
            train_acc,
            test_loss,
            test_acc,
            invariant_fraction,
            aggregated,
            dropped_updates,
            stale_folded,
            update_bytes,
            calibration_secs: calib_secs,
            vanished: vanished_sorted.len(),
            quarantined,
            shard_retries,
            quorum_fraction,
            straggler_wait,
            admitted_stale: stale_folded,
            soft_fraction,
        })
    }
}
