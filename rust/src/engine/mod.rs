//! The round engine — Algorithm 1 decomposed into composable layers.
//!
//! The historical coordinator ran one ~300-line function that hard-coded
//! a fully synchronous barrier. This module splits that loop along four
//! seams so that round *policy* and round *mechanics* evolve separately:
//!
//! * [`ClientExecutor`] — where per-client work executes
//!   ([`LocalExecutor`] is the in-process thread-pool backend; sharded /
//!   remote backends plug in here).
//! * [`EventScheduler`] — the virtual-time model: per-client latencies
//!   become arrival *events*, and each [`SyncMode`] resolves those events
//!   into a barrier decision instead of an implicit `fold(max)`.
//! * [`RoundPlan`] / [`RoundOutcome`] — the narrow calibration interface
//!   through which `dropout::Policy` and `straggler::detect` drive the
//!   engine.
//! * [`SyncMode`] — the round-synchronization policy: classic full
//!   barrier (bit-identical to the historical loop), SALF-style deadline
//!   rounds, or FedBuff-style buffered semi-async rounds.
//!
//! See DESIGN.md §3 for the layering diagram and the exact SyncMode
//! semantics.

pub mod executor;
pub mod plan;
pub mod sched;

pub use executor::{ClientExecutor, LocalExecutor, TrainJob};
pub use plan::{RoundOutcome, RoundPlan};
pub use sched::{ClientArrival, EventScheduler, Resolution};

use crate::coordinator::{ExperimentConfig, ExperimentResult, RoundRecord};
use crate::data::{FlData, Split};
use crate::dropout::{InvariantConfig, MaskSet, Policy, PolicyKind};
use crate::fl::{self, fedavg, staleness_discount, Client, ClientUpdate};
use crate::runtime::StepRunner;
use crate::straggler::{
    detect_stragglers, mobile_fleet, snap_rate, synthetic_fleet, Detection, DeviceProfile,
    FluctuationSchedule, PerfModel,
};
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;
use crate::util::stats;
use std::time::Instant;

/// Cap on how many non-stragglers vote on invariance per calibration —
/// the information saturates quickly and each voter costs one
/// `delta_step` execution (documented server-side optimization).
const MAX_DELTA_VOTERS: usize = 16;

/// Round-synchronization policy: when does a round end, and what happens
/// to updates that arrive after it does?
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum SyncMode {
    /// Wait for every participant (the paper's protocol, and the
    /// pre-engine behavior bit-for-bit).
    #[default]
    FullBarrier,
    /// SALF-style deadline round: aggregate whatever arrived by
    /// `multiple_of_t_target · T_target`; late updates are discarded and
    /// their clients start fresh next round.
    Deadline { multiple_of_t_target: f64 },
    /// FedBuff-style semi-async round: aggregate as soon as `k` updates
    /// arrive. Late updates are buffered and fold into a later
    /// aggregation with a staleness-discounted weight; their clients stay
    /// busy (skip participation) until the update lands.
    Buffered { k: usize },
}

impl SyncMode {
    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::FullBarrier => "full-barrier",
            SyncMode::Deadline { .. } => "deadline",
            SyncMode::Buffered { .. } => "buffered",
        }
    }
}

/// A buffered late update awaiting a future aggregation (Buffered mode).
struct StaleUpdate {
    result: fl::LocalResult,
    mask: MaskSet,
    /// absolute virtual time the update lands at the server
    arrives_at: f64,
    /// round whose broadcast params the update was trained from
    born_round: usize,
}

/// The layered round loop: owns all cross-round state and executes
/// [`ExperimentConfig::rounds`] rounds through an executor and the event
/// scheduler.
pub struct RoundEngine<'a, E: ClientExecutor> {
    cfg: &'a ExperimentConfig,
    runner: &'a StepRunner,
    executor: E,
    fleet: Vec<DeviceProfile>,
    device_of: Vec<usize>,
    clients: Vec<Client>,
    test_split: Split,
    scheduler: EventScheduler,
    policy: Policy,
    detection: Option<Detection>,
    params: Vec<Tensor>,
    full_mask: MaskSet,
    /// actual end-to-end latency each client last reported (under its
    /// assigned sub-model) — `straggler_time` reads the last-known value
    /// even for stragglers not sampled this round, as the pre-engine
    /// loop did
    last_latencies: Vec<f64>,
    /// full-model-normalized latency each client last reported — the
    /// profile straggler detection reads (see `PerfModel::client_timing`)
    last_full_latencies: Vec<f64>,
    vtime: f64,
    calib_total: f64,
    train_wall: f64,
    /// buffered late updates (Buffered mode only)
    stale: Vec<StaleUpdate>,
    /// absolute virtual time each client becomes free; a client busy past
    /// a round's start skips that round's participation
    free_at: Vec<f64>,
}

impl<'a, E: ClientExecutor> RoundEngine<'a, E> {
    pub fn new(
        runner: &'a StepRunner,
        cfg: &'a ExperimentConfig,
        executor: E,
    ) -> crate::Result<Self> {
        let spec = &runner.spec;

        // fleet + data + clients ---------------------------------------------
        let fleet = if cfg.mobile_fleet {
            let base = mobile_fleet();
            (0..cfg.clients)
                .map(|i| base[i % base.len()].clone())
                .collect::<Vec<_>>()
        } else {
            synthetic_fleet(cfg.clients, cfg.seed ^ 0xF1EE7)
        };
        let data = FlData::for_model(&cfg.model, cfg.clients, cfg.samples_per_client, cfg.seed);
        let test_split = data.test.clone();
        let clients: Vec<Client> = data
            .clients
            .iter()
            .enumerate()
            .map(|(i, split)| Client::new(i, i % fleet.len(), split.clone()))
            .collect();
        let device_of: Vec<usize> = clients.iter().map(|c| c.device).collect();

        let perf = PerfModel::new(&cfg.model, spec.size_bytes());
        // the natural straggler is the slowest base device — excluded from
        // the fluctuation protocol so that the straggler identity really
        // changes
        let natural_straggler = (0..cfg.clients)
            .max_by(|&a, &b| {
                fleet[a % fleet.len()]
                    .base_time(&cfg.model)
                    .partial_cmp(&fleet[b % fleet.len()].base_time(&cfg.model))
                    .unwrap()
            })
            .unwrap_or(0);
        let fluct = if cfg.fluctuation {
            FluctuationSchedule::paper_marks(cfg.clients, natural_straggler, cfg.seed ^ 0xF1C)
        } else {
            FluctuationSchedule::none()
        };

        let inv_cfg = InvariantConfig {
            th_override: cfg.invariant_th_override,
            ..Default::default()
        };
        let policy = Policy::new_with(cfg.policy, spec, cfg.seed ^ 0xD20, inv_cfg);
        let params = spec.init_params(cfg.seed);
        let full_mask = MaskSet::full(spec);

        Ok(Self {
            cfg,
            runner,
            executor,
            fleet,
            device_of,
            clients,
            test_split,
            scheduler: EventScheduler::new(perf, fluct),
            policy,
            detection: None,
            params,
            full_mask,
            last_latencies: vec![0.0; cfg.clients],
            last_full_latencies: vec![0.0; cfg.clients],
            vtime: 0.0,
            calib_total: 0.0,
            train_wall: 0.0,
            stale: Vec::new(),
            free_at: vec![0.0; cfg.clients],
        })
    }

    /// Run every round to completion.
    pub fn run(mut self) -> crate::Result<ExperimentResult> {
        let cfg = self.cfg;
        let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
        for round in 0..cfg.rounds {
            let plan = self.plan_round(round);
            let o = self.run_round(&plan)?;
            self.calib_total += o.calibration_secs;
            records.push(RoundRecord {
                round,
                round_time: o.round_time,
                vtime: self.vtime,
                straggler_ids: plan.straggler_ids.clone(),
                straggler_rates: plan.straggler_ids.iter().map(|&c| plan.rates[c]).collect(),
                t_target: o.t_target,
                straggler_time: o.straggler_time,
                train_loss: o.train_loss,
                train_acc: o.train_acc,
                test_loss: o.test_loss,
                test_acc: o.test_acc,
                invariant_fraction: o.invariant_fraction,
                calibration_secs: o.calibration_secs,
                aggregated: o.aggregated,
                dropped_updates: o.dropped_updates,
                stale_folded: o.stale_folded,
            });
        }

        let last_eval = records
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| (r.test_loss, r.test_acc))
            .unwrap_or((f64::NAN, f64::NAN));

        Ok(ExperimentResult {
            model: cfg.model.clone(),
            policy: cfg.policy,
            records,
            final_test_acc: last_eval.1,
            final_test_loss: last_eval.0,
            total_vtime: self.vtime,
            calibration_total: self.calib_total,
            seed: cfg.seed,
            train_wall_total: self.train_wall,
        })
    }

    /// Server-side planning: sampling, straggler recalibration, and
    /// sub-model assignment (Algorithm 1 lines 18-22).
    fn plan_round(&mut self, round: usize) -> RoundPlan {
        let cfg = self.cfg;
        let t_frac = round as f64 / cfg.rounds.max(1) as f64;
        let round_seed = cfg.seed ^ ((round as u64) << 32);
        let mut rng = Pcg32::new(cfg.seed ^ 0xA0_0000, round as u64);

        // --- client sampling (A.6) ------------------------------------------
        let selected: Vec<usize> = if cfg.sample_fraction >= 1.0 {
            (0..cfg.clients).collect()
        } else {
            let k = ((cfg.clients as f64 * cfg.sample_fraction).ceil() as usize)
                .clamp(1, cfg.clients);
            let mut s = rng.sample_indices(cfg.clients, k);
            s.sort_unstable();
            s
        };

        // --- straggler recalibration ----------------------------------------
        let recalibrate = round > 0
            && round % cfg.recalibrate_every == 0
            && !(cfg.static_stragglers && self.detection.is_some());
        if recalibrate {
            let lat: Vec<f64> = selected
                .iter()
                .map(|&c| self.last_full_latencies[c])
                .collect();
            let det = detect_stragglers(&lat, cfg.straggler_fraction, 0.02, &cfg.rates_menu);
            // map sample-local ids back to client ids
            self.detection = Some(Detection {
                stragglers: det.stragglers.iter().map(|&i| selected[i]).collect(),
                ..det
            });
        }

        // --- sub-model assignment -------------------------------------------
        let calib_start = Instant::now();
        let mut masks: Vec<MaskSet> = vec![self.full_mask.clone(); cfg.clients];
        let mut rates: Vec<f64> = vec![1.0; cfg.clients];
        let mut straggler_ids: Vec<usize> = Vec::new();
        if let Some(det) = &self.detection {
            for (k, &c) in det.stragglers.iter().enumerate() {
                let desired = cfg.fixed_rate.unwrap_or(det.rates[k]);
                let r = match &cfg.cluster_rates {
                    Some(menu) => snap_rate(desired, menu),
                    None => desired,
                };
                if cfg.policy != PolicyKind::None && cfg.policy != PolicyKind::Exclude {
                    let m = self.policy.make_mask(&self.runner.spec, r);
                    // the straggler only speeds up if it actually received
                    // a sub-model (invariant dropout returns the full mask
                    // until its first calibration observation)
                    if !m.is_full() {
                        rates[c] = r;
                        masks[c] = m;
                    }
                }
                straggler_ids.push(c);
            }
        }
        let calib_secs = calib_start.elapsed().as_secs_f64();

        // --- participation --------------------------------------------------
        // Semi-async: a client still finishing a previous round's work is
        // busy and sits this round out; its buffered update folds in when
        // it lands. Synchronous modes never mark anyone busy.
        let round_start = self.vtime;
        let active: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|&c| self.free_at[c] <= round_start)
            .collect();
        // Exclude policy: stragglers neither train nor aggregate.
        let participants: Vec<usize> = active
            .iter()
            .copied()
            .filter(|c| cfg.policy != PolicyKind::Exclude || !straggler_ids.contains(c))
            .collect();

        RoundPlan {
            round,
            t_frac,
            round_seed,
            selected,
            active,
            participants,
            straggler_ids,
            rates,
            masks,
            t_target: self.detection.as_ref().map(|d| d.t_target),
            is_calib_round: round % cfg.recalibrate_every == 0,
            calib_secs,
        }
    }

    /// Execute one planned round: train, schedule arrivals, resolve the
    /// barrier, aggregate (folding matured stale updates), observe
    /// deltas, evaluate.
    fn run_round(&mut self, plan: &RoundPlan) -> crate::Result<RoundOutcome> {
        let cfg = self.cfg;
        let mut calib_secs = plan.calib_secs;

        // --- local training (through the executor seam) ---------------------
        let jobs: Vec<TrainJob> = plan
            .participants
            .iter()
            .map(|&c| TrainJob {
                client: c,
                steps: cfg.local_steps,
                lr: cfg.lr,
                seed: plan.round_seed,
                use_fused: cfg.use_fused_steps,
            })
            .collect();
        let t0 = Instant::now();
        let results = self.executor.run_clients(
            self.runner,
            &self.clients,
            &plan.masks,
            &self.params,
            &jobs,
        );
        self.train_wall += t0.elapsed().as_secs_f64();
        let mut updates: Vec<(usize, fl::LocalResult)> = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            updates.push((plan.participants[i], r?));
        }

        // --- virtual-time arrival events ------------------------------------
        let comm_fractions: Vec<f64> = plan.masks.iter().map(|m| m.comm_fraction()).collect();
        let arrivals = self.scheduler.arrivals(
            &self.fleet,
            &self.device_of,
            &plan.active,
            &plan.rates,
            &comm_fractions,
            plan.t_frac,
            plan.round_seed,
        );
        for a in &arrivals {
            self.last_latencies[a.client] = a.at;
            self.last_full_latencies[a.client] = a.full_latency;
        }

        // membership bitmaps: the scale path runs thousands of clients,
        // so per-arrival Vec::contains scans would be quadratic
        let mut is_participant = vec![false; cfg.clients];
        for &c in &plan.participants {
            is_participant[c] = true;
        }

        // the barrier only waits on clients that actually train; with the
        // Exclude policy the round advances as soon as participants finish
        let participant_arrivals: Vec<ClientArrival> = arrivals
            .iter()
            .filter(|a| is_participant[a.client])
            .copied()
            .collect();
        let res = EventScheduler::resolve(cfg.sync_mode, &participant_arrivals, plan.t_target);
        let mut is_on_time = vec![false; cfg.clients];
        for &c in &res.on_time {
            is_on_time[c] = true;
        }
        let mut late_at: Vec<Option<f64>> = vec![None; cfg.clients];
        for a in &res.late {
            late_at[a.client] = Some(a.at);
        }

        let round_start = self.vtime;
        let mut round_time = res.round_time;
        if plan.participants.is_empty() {
            // degenerate semi-async corner: everyone is busy. Advance the
            // clock to the earliest buffered arrival so time still moves
            // and the buffer drains.
            if let Some(earliest) = self
                .stale
                .iter()
                .map(|s| s.arrives_at)
                .min_by(|a, b| a.partial_cmp(b).unwrap())
            {
                round_time = (earliest - round_start).max(0.0);
            }
        }
        let round_end = round_start + round_time;
        self.vtime = round_end;

        // last-known straggler latency, whether or not the straggler was
        // sampled this round (the pre-engine convention)
        let straggler_time = plan
            .straggler_ids
            .iter()
            .map(|&c| self.last_latencies[c])
            .fold(0.0f64, f64::max);
        let t_target = plan.t_target.unwrap_or(round_time);

        // --- aggregation set: fresh on-time updates, then matured stale ------
        let mut agg: Vec<ClientUpdate> = Vec::with_capacity(updates.len());
        let mut losses: Vec<f64> = Vec::new();
        let mut accs: Vec<f64> = Vec::new();
        let mut weights: Vec<f64> = Vec::new();
        let mut dropped_updates = 0usize;
        for (c, u) in &updates {
            if is_on_time[*c] {
                agg.push(ClientUpdate {
                    params: u.params.clone(),
                    weight: u.weight,
                    mask: plan.masks[*c].clone(),
                    staleness: 0,
                });
                losses.push(u.mean_loss);
                accs.push(u.mean_acc);
                weights.push(u.weight);
            } else {
                match cfg.sync_mode {
                    // late under a deadline: the update is discarded and
                    // the client abandons the round (free immediately)
                    SyncMode::Deadline { .. } => dropped_updates += 1,
                    // late under buffering: the update keeps computing
                    // and the client stays busy until it lands
                    SyncMode::Buffered { .. } => {
                        let at = late_at[*c].expect("late participant has an arrival");
                        self.stale.push(StaleUpdate {
                            result: u.clone(),
                            mask: plan.masks[*c].clone(),
                            arrives_at: round_start + at,
                            born_round: plan.round,
                        });
                        self.free_at[*c] = round_start + at;
                    }
                    // a full barrier never produces late arrivals
                    SyncMode::FullBarrier => unreachable!(),
                }
            }
        }

        // fold in previously-buffered updates that landed by round_end;
        // this round's lates were pushed above but cannot mature yet
        // (their arrival is past this round's own barrier)
        let mut stale_folded = 0usize;
        let mut still: Vec<StaleUpdate> = Vec::with_capacity(self.stale.len());
        for s in std::mem::take(&mut self.stale) {
            if s.born_round < plan.round && s.arrives_at <= round_end {
                let staleness = plan.round - s.born_round;
                // metrics carry the same staleness-discounted weight
                // the aggregation applies
                losses.push(s.result.mean_loss);
                accs.push(s.result.mean_acc);
                weights.push(s.result.weight * staleness_discount(staleness));
                agg.push(ClientUpdate {
                    params: s.result.params,
                    weight: s.result.weight,
                    mask: s.mask,
                    staleness,
                });
                stale_folded += 1;
            } else {
                still.push(s);
            }
        }
        self.stale = still;

        // --- metrics + masked FedAvg ----------------------------------------
        // example-weighted train metrics, matching FedAvg's weighting
        // (uniform shards reduce to the historical unweighted mean)
        let (train_loss, train_acc) = if agg.is_empty() {
            (f64::NAN, f64::NAN)
        } else {
            (
                stats::weighted_mean(&losses, &weights),
                stats::weighted_mean(&accs, &weights),
            )
        };
        let new_params = if agg.is_empty() {
            self.params.clone()
        } else {
            fedavg(&self.runner.spec, &self.params, &agg, cfg.aggregate)
        };

        // --- invariant observation (non-straggler deltas, L1 kernel) --------
        if plan.is_calib_round && matches!(self.policy, Policy::Invariant(_)) {
            let t0 = Instant::now();
            let voters: Vec<&[Tensor]> = updates
                .iter()
                .filter(|(c, _)| is_on_time[*c] && !plan.straggler_ids.contains(c))
                .take(MAX_DELTA_VOTERS)
                .map(|(_, u)| u.params.as_slice())
                .collect();
            let per_client = self
                .executor
                .run_deltas(self.runner, &self.params, &voters);
            let per_client = per_client
                .into_iter()
                .collect::<crate::Result<Vec<_>>>()?;
            self.policy.observe_deltas(&per_client);
            calib_secs += t0.elapsed().as_secs_f64();
        }
        self.params = new_params;

        // --- evaluation -----------------------------------------------------
        let (test_loss, test_acc) =
            if plan.round % cfg.eval_every == 0 || plan.round + 1 == cfg.rounds {
                fl::evaluate_split(
                    self.runner,
                    &self.params,
                    self.full_mask.tensors(),
                    &self.test_split,
                )?
            } else {
                (f64::NAN, f64::NAN)
            };

        let invariant_fraction = match &self.policy {
            Policy::Invariant(p) => p.invariant_fraction(),
            _ => 0.0,
        };

        Ok(RoundOutcome {
            round_time,
            t_target,
            straggler_time,
            train_loss,
            train_acc,
            test_loss,
            test_acc,
            invariant_fraction,
            aggregated: agg.len(),
            dropped_updates,
            stale_folded,
            calibration_secs: calib_secs,
        })
    }
}
