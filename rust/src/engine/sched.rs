//! Virtual-time event scheduling.
//!
//! The historical loop collapsed per-client latencies into a single
//! `fold(max)` — correct for a full barrier, useless for anything else.
//! [`EventScheduler`] instead turns the [`PerfModel`]'s per-client
//! latencies into explicit *arrival events* (round-relative virtual
//! seconds), and [`EventScheduler::resolve`] decides, per
//! [`SyncMode`], when the round ends and which arrivals make it into the
//! aggregation. The resolution is pure over the arrival list, so every
//! barrier policy is unit- and property-testable without a runtime.

use super::SyncMode;
use crate::fl::Fleet;
use crate::straggler::{FluctuationSchedule, PerfModel};

/// One client's arrival event for a round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientArrival {
    pub client: usize,
    /// arrival time in round-relative virtual seconds
    pub at: f64,
    /// the same draw normalized to the full model (straggler profiling)
    pub full_latency: f64,
}

/// How one round's barrier resolved.
#[derive(Clone, Debug, PartialEq)]
pub struct Resolution {
    /// virtual seconds this round occupies the server
    pub round_time: f64,
    /// clients whose updates aggregate this round
    pub on_time: Vec<usize>,
    /// arrivals that missed the barrier (discarded under `Deadline`,
    /// buffered as stale updates under `Buffered`)
    pub late: Vec<ClientArrival>,
}

/// Turns per-client latencies into arrival events and resolves barriers.
#[derive(Clone, Debug)]
pub struct EventScheduler {
    pub perf: PerfModel,
    pub fluct: FluctuationSchedule,
}

impl EventScheduler {
    pub fn new(perf: PerfModel, fluct: FluctuationSchedule) -> Self {
        Self { perf, fluct }
    }

    /// Arrival events for every active client this round, in `active`
    /// order. `rates[i]` and `comm_fractions[i]` belong to `active[i]` —
    /// cohort-aligned slices, so the call costs O(cohort) with no
    /// per-fleet table anywhere (the client's device resolves through
    /// [`Fleet::profile`]).
    pub fn arrivals(
        &self,
        fleet: &Fleet,
        active: &[usize],
        rates: &[f64],
        comm_fractions: &[f64],
        t_frac: f64,
        round_seed: u64,
    ) -> Vec<ClientArrival> {
        debug_assert_eq!(active.len(), rates.len());
        debug_assert_eq!(active.len(), comm_fractions.len());
        active
            .iter()
            .zip(rates.iter().zip(comm_fractions))
            .map(|(&c, (&rate, &comm))| {
                let t = self.perf.client_timing(
                    fleet.profile(c),
                    c,
                    rate,
                    comm,
                    t_frac,
                    &self.fluct,
                    round_seed,
                );
                ClientArrival {
                    client: c,
                    at: t.latency,
                    full_latency: t.full_latency,
                }
            })
            .collect()
    }

    /// Decide when the round ends and which arrivals aggregate.
    ///
    /// * [`SyncMode::FullBarrier`] — wait for everyone: `round_time` is
    ///   the max arrival, nothing is late.
    /// * [`SyncMode::Deadline`] — SALF-style cutoff at
    ///   `multiple_of_t_target · T_target`. Arrivals past the cutoff are
    ///   late; the round ends at the cutoff when anyone is late, else at
    ///   the last arrival. Before the first straggler detection there is
    ///   no `T_target`, so the round degrades to a full barrier. If *no*
    ///   arrival meets the cutoff the server must still make progress: it
    ///   waits for the earliest arrival alone.
    /// * [`SyncMode::Buffered`] — semi-async: the round ends as soon as
    ///   `k` updates arrived (k clamped to the arrival count); the rest
    ///   are late.
    pub fn resolve(
        mode: SyncMode,
        arrivals: &[ClientArrival],
        t_target: Option<f64>,
    ) -> Resolution {
        if arrivals.is_empty() {
            return Resolution {
                round_time: 0.0,
                on_time: Vec::new(),
                late: Vec::new(),
            };
        }
        let full_barrier = |arrivals: &[ClientArrival]| Resolution {
            round_time: arrivals.iter().map(|a| a.at).fold(0.0f64, f64::max),
            on_time: arrivals.iter().map(|a| a.client).collect(),
            late: Vec::new(),
        };
        match mode {
            SyncMode::FullBarrier => full_barrier(arrivals),
            SyncMode::Deadline { multiple_of_t_target } => {
                let Some(tt) = t_target else {
                    return full_barrier(arrivals);
                };
                let cutoff = multiple_of_t_target * tt;
                let (on, late): (Vec<&ClientArrival>, Vec<&ClientArrival>) =
                    arrivals.iter().partition(|a| a.at <= cutoff);
                if on.is_empty() {
                    // nobody met the cutoff: wait for the single earliest
                    // arrival so the round aggregates at least one update
                    // total_cmp: a NaN arrival (broken measurement)
                    // sorts last and can never panic the resolve
                    let first = arrivals
                        .iter()
                        .min_by(|a, b| {
                            a.at.total_cmp(&b.at).then(a.client.cmp(&b.client))
                        })
                        .unwrap();
                    // a non-finite "earliest" means every arrival is
                    // broken: end the round immediately rather than
                    // poisoning the virtual clock with NaN forever
                    let round_time = if first.at.is_finite() { first.at } else { 0.0 };
                    return Resolution {
                        round_time,
                        on_time: vec![first.client],
                        late: arrivals
                            .iter()
                            .filter(|a| a.client != first.client)
                            .copied()
                            .collect(),
                    };
                }
                let round_time = if late.is_empty() {
                    on.iter().map(|a| a.at).fold(0.0f64, f64::max)
                } else {
                    cutoff
                };
                Resolution {
                    round_time,
                    on_time: on.iter().map(|a| a.client).collect(),
                    late: late.into_iter().copied().collect(),
                }
            }
            SyncMode::Buffered { k } => {
                let mut sorted: Vec<ClientArrival> = arrivals.to_vec();
                // total_cmp: NaN arrivals sort last, so they land in the
                // late set instead of panicking the k-th-arrival scan
                sorted.sort_by(|a, b| a.at.total_cmp(&b.at).then(a.client.cmp(&b.client)));
                let k_eff = k.clamp(1, sorted.len());
                // only finite arrivals can end a round: the cut clamps
                // to the finite prefix so a NaN/inf latency (broken
                // measurement) always lands in the late set and never
                // becomes round_time — a NaN there would poison vtime
                // for every subsequent round
                let finite = sorted.iter().take_while(|a| a.at.is_finite()).count();
                let cut = k_eff.min(finite);
                let round_time = if cut == 0 { 0.0 } else { sorted[cut - 1].at };
                Resolution {
                    round_time,
                    on_time: sorted[..cut].iter().map(|a| a.client).collect(),
                    late: sorted[cut..].to_vec(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(pairs: &[(usize, f64)]) -> Vec<ClientArrival> {
        pairs
            .iter()
            .map(|&(client, at)| ClientArrival {
                client,
                at,
                full_latency: at,
            })
            .collect()
    }

    #[test]
    fn full_barrier_waits_for_everyone() {
        let a = arr(&[(0, 3.0), (1, 9.0), (2, 5.0)]);
        let r = EventScheduler::resolve(SyncMode::FullBarrier, &a, Some(5.0));
        assert_eq!(r.round_time, 9.0);
        assert_eq!(r.on_time, vec![0, 1, 2]);
        assert!(r.late.is_empty());
    }

    #[test]
    fn deadline_drops_late_arrivals_and_ends_at_cutoff() {
        let a = arr(&[(0, 3.0), (1, 9.0), (2, 5.0)]);
        let r = EventScheduler::resolve(
            SyncMode::Deadline { multiple_of_t_target: 1.2 },
            &a,
            Some(5.0), // cutoff = 6.0
        );
        assert_eq!(r.round_time, 6.0);
        assert_eq!(r.on_time, vec![0, 2]);
        assert_eq!(r.late.len(), 1);
        assert_eq!(r.late[0].client, 1);
    }

    #[test]
    fn deadline_with_everyone_on_time_ends_at_last_arrival() {
        let a = arr(&[(0, 3.0), (1, 4.0)]);
        let r = EventScheduler::resolve(
            SyncMode::Deadline { multiple_of_t_target: 2.0 },
            &a,
            Some(5.0), // cutoff = 10.0 — nobody late
        );
        assert_eq!(r.round_time, 4.0);
        assert_eq!(r.on_time, vec![0, 1]);
        assert!(r.late.is_empty());
    }

    #[test]
    fn deadline_without_detection_is_a_full_barrier() {
        let a = arr(&[(0, 3.0), (1, 9.0)]);
        let r = EventScheduler::resolve(
            SyncMode::Deadline { multiple_of_t_target: 1.0 },
            &a,
            None,
        );
        assert_eq!(r.round_time, 9.0);
        assert_eq!(r.on_time.len(), 2);
    }

    #[test]
    fn deadline_nobody_on_time_waits_for_first() {
        let a = arr(&[(0, 8.0), (1, 7.0)]);
        let r = EventScheduler::resolve(
            SyncMode::Deadline { multiple_of_t_target: 1.0 },
            &a,
            Some(2.0), // cutoff = 2.0 — everyone late
        );
        assert_eq!(r.round_time, 7.0);
        assert_eq!(r.on_time, vec![1]);
        assert_eq!(r.late.len(), 1);
        assert_eq!(r.late[0].client, 0);
    }

    #[test]
    fn buffered_ends_at_kth_arrival() {
        let a = arr(&[(0, 3.0), (1, 9.0), (2, 5.0), (3, 1.0)]);
        let r = EventScheduler::resolve(SyncMode::Buffered { k: 2 }, &a, None);
        assert_eq!(r.round_time, 3.0);
        assert_eq!(r.on_time, vec![3, 0]); // arrival order
        assert_eq!(r.late.len(), 2);
        let late_ids: Vec<usize> = r.late.iter().map(|a| a.client).collect();
        assert_eq!(late_ids, vec![2, 1]);
    }

    #[test]
    fn buffered_k_clamps_to_arrival_count() {
        let a = arr(&[(0, 3.0), (1, 9.0)]);
        let r = EventScheduler::resolve(SyncMode::Buffered { k: 10 }, &a, None);
        assert_eq!(r.round_time, 9.0);
        assert_eq!(r.on_time.len(), 2);
        assert!(r.late.is_empty());
    }

    #[test]
    fn nan_and_inf_arrivals_never_panic_resolution() {
        // regression: a NaN latency used to panic the Deadline/Buffered
        // partial_cmp sorts mid-round
        let a = arr(&[(0, 3.0), (1, f64::NAN), (2, 5.0), (3, f64::INFINITY)]);
        for mode in [
            SyncMode::FullBarrier,
            SyncMode::Deadline { multiple_of_t_target: 1.2 },
            SyncMode::Buffered { k: 2 },
        ] {
            let r = EventScheduler::resolve(mode, &a, Some(5.0));
            assert_eq!(
                r.on_time.len() + r.late.len(),
                a.len(),
                "{mode:?} lost an arrival"
            );
        }
        // buffered: the finite arrivals are on time, NaN/inf are late
        let r = EventScheduler::resolve(SyncMode::Buffered { k: 2 }, &a, None);
        assert_eq!(r.on_time, vec![0, 2]);
        assert_eq!(r.round_time, 5.0);
        // even with k beyond the finite prefix, a broken arrival never
        // ends the round: round_time must stay finite (a NaN here would
        // poison vtime for every later round)
        let r = EventScheduler::resolve(SyncMode::Buffered { k: 3 }, &a, None);
        assert_eq!(r.on_time, vec![0, 2]);
        assert_eq!(r.round_time, 5.0);
        assert_eq!(r.late.len(), 2);
        // deadline with every arrival broken still makes progress, with
        // a sane (zero) round time
        let broken = arr(&[(0, f64::NAN), (1, f64::NAN)]);
        let r = EventScheduler::resolve(
            SyncMode::Deadline { multiple_of_t_target: 1.0 },
            &broken,
            Some(2.0),
        );
        assert_eq!(r.on_time.len(), 1);
        assert_eq!(r.round_time, 0.0);
        let r = EventScheduler::resolve(SyncMode::Buffered { k: 1 }, &broken, None);
        assert!(r.on_time.is_empty());
        assert_eq!(r.round_time, 0.0);
        assert_eq!(r.late.len(), 2);
    }

    #[test]
    fn empty_arrivals_resolve_to_nothing() {
        for mode in [
            SyncMode::FullBarrier,
            SyncMode::Deadline { multiple_of_t_target: 1.0 },
            SyncMode::Buffered { k: 3 },
        ] {
            let r = EventScheduler::resolve(mode, &[], Some(1.0));
            assert_eq!(r.round_time, 0.0);
            assert!(r.on_time.is_empty() && r.late.is_empty());
        }
    }
}
