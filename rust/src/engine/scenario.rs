//! Seeded, declarative fleet-dynamics scripting.
//!
//! Every ROADMAP scenario — clients joining and leaving mid-run, the
//! straggler population drifting, device speeds fluctuating — used to be
//! bespoke bench code. A [`ScenarioConfig`] is the declarative,
//! replayable alternative: named presets (or `name:rate` overrides on the
//! CLI) compile to
//!
//! * per-round **churn** applied to [`Fleet`] availability
//!   ([`ScenarioSim::apply_churn`], seeded per round so a replay of the
//!   same experiment seed reproduces the same population trajectory), and
//! * a procedural [`FluctuationSchedule`]
//!   (`straggler::fluctuate::ProceduralLoad`) for straggler-population
//!   drift and device-speed jitter — O(phases) per latency lookup, no
//!   per-client event storage, viable at 100k clients.

use crate::fl::Fleet;
use crate::straggler::{
    FluctuationSchedule, ProceduralChurn, ProceduralLoad, ProceduralPhase,
};

/// Declarative description of one scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioConfig {
    /// preset name (diagnostics / reports)
    pub name: String,
    /// per-round probability that an available client churns out
    pub churn_out: f64,
    /// per-round probability that a churned-out client rejoins
    pub rejoin: f64,
    /// straggler-drift / speed-fluctuation phases
    pub phases: Vec<ProceduralPhase>,
}

impl ScenarioConfig {
    fn preset(name: &str) -> Option<ScenarioConfig> {
        let quiet = ScenarioConfig {
            name: name.to_string(),
            churn_out: 0.0,
            rejoin: 0.0,
            phases: vec![],
        };
        Some(match name {
            // clients leave and rejoin; timing stays calm
            "churn" => ScenarioConfig {
                churn_out: 0.05,
                rejoin: 0.30,
                ..quiet
            },
            // the straggler *population* shifts each quarter of training
            "drift" => ScenarioConfig {
                phases: drift_phases(0.15, 0.0),
                ..quiet
            },
            // every device's speed wobbles round to round
            "flux" => ScenarioConfig {
                phases: vec![ProceduralPhase {
                    start_frac: 0.0,
                    end_frac: 1.0,
                    slow_fraction: 0.0,
                    multiplier_lo: 1.0,
                    multiplier_hi: 1.0,
                    jitter: 0.25,
                }],
                ..quiet
            },
            // everything at once: churn + drift + jitter
            "storm" => ScenarioConfig {
                churn_out: 0.10,
                rejoin: 0.25,
                phases: drift_phases(0.15, 0.10),
                ..quiet
            },
            _ => return None,
        })
    }

    /// Parse a CLI scenario spec: `none`, a preset name, or
    /// `preset:rate` where `rate` overrides the preset's headline knob
    /// (churn-out rate for `churn`/`storm`, slow fraction for `drift`,
    /// jitter sigma for `flux`).
    pub fn parse(spec: &str) -> Result<Option<ScenarioConfig>, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(None);
        }
        let (name, rate) = match spec.split_once(':') {
            Some((n, r)) => {
                let rate: f64 = r
                    .parse()
                    .map_err(|_| format!("scenario rate {r:?} is not a number"))?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(format!("scenario rate {rate} outside [0, 1]"));
                }
                (n, Some(rate))
            }
            None => (spec, None),
        };
        let mut cfg = ScenarioConfig::preset(name).ok_or_else(|| {
            format!("unknown scenario {name:?} (none|churn|drift|flux|storm[:rate])")
        })?;
        if let Some(rate) = rate {
            match name {
                "churn" | "storm" => cfg.churn_out = rate,
                "drift" => {
                    for p in &mut cfg.phases {
                        p.slow_fraction = rate;
                    }
                }
                "flux" => {
                    for p in &mut cfg.phases {
                        p.jitter = rate;
                    }
                }
                _ => {}
            }
        }
        Ok(Some(cfg))
    }
}

/// Four quarter-phases, each with its own (seed-selected) slow subset —
/// the straggler population drifts at every quarter mark.
fn drift_phases(slow_fraction: f64, jitter: f64) -> Vec<ProceduralPhase> {
    (0..4)
        .map(|q| ProceduralPhase {
            start_frac: q as f64 * 0.25,
            end_frac: if q == 3 { 1.0 } else { (q + 1) as f64 * 0.25 },
            slow_fraction,
            multiplier_lo: 1.5,
            multiplier_hi: 2.5,
            jitter,
        })
        .collect()
}

/// A scenario bound to an experiment seed — the replayable executor of a
/// [`ScenarioConfig`].
#[derive(Clone, Debug)]
pub struct ScenarioSim {
    pub cfg: ScenarioConfig,
    seed: u64,
}

impl ScenarioSim {
    pub fn new(cfg: ScenarioConfig, seed: u64) -> Self {
        Self { cfg, seed }
    }

    /// The timing side of the scenario, as the perf model consumes it.
    pub fn fluctuation(&self) -> FluctuationSchedule {
        FluctuationSchedule::procedural(ProceduralLoad {
            seed: self.seed ^ 0xD21F_7A11,
            phases: self.cfg.phases.clone(),
        })
    }

    /// The churn side of the scenario, as the fleet consumes it.
    pub fn churn(&self) -> ProceduralChurn {
        ProceduralChurn {
            seed: self.seed ^ 0xC4_0212,
            churn_out: self.cfg.churn_out,
            rejoin: self.cfg.rejoin,
        }
    }

    /// Apply one round of join/leave churn as sparse deltas — O(expected
    /// flips), not O(fleet). Deterministic in `(scenario seed, round)`:
    /// replaying a seed replays the exact population trajectory. Returns
    /// `(churned out, rejoined)`.
    pub fn apply_churn(&self, round: usize, fleet: &mut Fleet) -> (usize, usize) {
        let churn = self.churn();
        if !churn.is_active() {
            return (0, 0);
        }
        let mut rng = churn.round_rng(round);
        fleet.apply_churn(churn.churn_out, churn.rejoin, &mut rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_none_is_none() {
        assert_eq!(ScenarioConfig::parse("none").unwrap(), None);
        assert_eq!(ScenarioConfig::parse("").unwrap(), None);
        for name in ["churn", "drift", "flux", "storm"] {
            let sc = ScenarioConfig::parse(name).unwrap().unwrap();
            assert_eq!(sc.name, name);
        }
        assert!(ScenarioConfig::parse("bogus").is_err());
        assert!(ScenarioConfig::parse("churn:2.0").is_err());
        assert!(ScenarioConfig::parse("churn:x").is_err());
    }

    #[test]
    fn rate_override_hits_the_headline_knob() {
        let c = ScenarioConfig::parse("churn:0.2").unwrap().unwrap();
        assert_eq!(c.churn_out, 0.2);
        let d = ScenarioConfig::parse("drift:0.4").unwrap().unwrap();
        assert!(d.phases.iter().all(|p| p.slow_fraction == 0.4));
        let f = ScenarioConfig::parse("flux:0.5").unwrap().unwrap();
        assert!(f.phases.iter().all(|p| p.jitter == 0.5));
    }

    #[test]
    fn drift_phases_cover_the_run() {
        let ph = drift_phases(0.1, 0.0);
        assert_eq!(ph.len(), 4);
        assert_eq!(ph[0].start_frac, 0.0);
        assert_eq!(ph[3].end_frac, 1.0);
        for w in ph.windows(2) {
            assert_eq!(w[0].end_frac, w[1].start_frac);
        }
    }

    #[test]
    fn churn_is_replayable_and_moves_the_population() {
        let sim = ScenarioSim::new(
            ScenarioConfig::parse("churn").unwrap().unwrap(),
            42,
        );
        let mut a = Fleet::synthetic_pool(2000, 1);
        let mut b = Fleet::synthetic_pool(2000, 1);
        for round in 0..10 {
            let (out_a, in_a) = sim.apply_churn(round, &mut a);
            let (out_b, in_b) = sim.apply_churn(round, &mut b);
            assert_eq!((out_a, in_a), (out_b, in_b), "round {round}");
            assert_eq!(a.num_available(), b.num_available(), "round {round}");
        }
        // 5% churn-out over 10 rounds must have churned someone out
        assert!(a.num_available() < 2000);
        assert!(a.num_available() > 1000, "churn collapsed the fleet");
        assert_eq!(a.availability(), b.availability());
    }

    #[test]
    fn quiet_scenario_never_touches_the_fleet() {
        let sim = ScenarioSim::new(
            ScenarioConfig::parse("flux").unwrap().unwrap(),
            7,
        );
        let mut f = Fleet::synthetic_pool(100, 1);
        sim.apply_churn(3, &mut f);
        assert_eq!(f.num_available(), 100);
        // but its fluctuation schedule is live
        assert!(sim.fluctuation().is_dynamic());
    }
}
