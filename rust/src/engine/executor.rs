//! The client-execution seam.
//!
//! [`ClientExecutor`] is where the engine hands a batch of per-client
//! work to a backend. [`LocalExecutor`] runs it on the in-process
//! fork-join pool (`util::pool::scope_map`), exactly as the historical
//! round loop did; the trait boundary is where sharded / multi-process /
//! remote backends plug in without the round logic changing.

use crate::dropout::MaskSet;
use crate::fl::{Client, LocalResult};
use crate::runtime::StepRunner;
use crate::tensor::Tensor;
use crate::util::pool::scope_map;

/// One client's local-training work item for a round.
#[derive(Clone, Copy, Debug)]
pub struct TrainJob {
    /// client id (index into the engine's client/mask tables)
    pub client: usize,
    /// local SGD steps
    pub steps: usize,
    pub lr: f32,
    /// round seed — clients salt it with their id internally
    pub seed: u64,
    /// route through the fused k-step artifact when available
    pub use_fused: bool,
}

/// Executes per-client work for the round engine.
///
/// Results align index-for-index with the submitted jobs; per-client
/// failures stay per-client so a future backend can surface partial
/// progress instead of poisoning the round.
pub trait ClientExecutor: Sync {
    /// Run local training for every job. `masks` is the full per-client
    /// mask table (indexed by `TrainJob::client`), `params` the current
    /// global model.
    fn run_clients(
        &self,
        runner: &StepRunner,
        clients: &[Client],
        masks: &[MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>>;

    /// Execute the invariant delta kernel for each voter's parameters
    /// against the pre-aggregation globals.
    fn run_deltas(
        &self,
        runner: &StepRunner,
        old: &[Tensor],
        news: &[&[Tensor]],
    ) -> Vec<crate::Result<Vec<Tensor>>>;
}

/// In-process executor over the scoped thread pool — the historical
/// `scope_map` execution path behind the trait seam.
#[derive(Clone, Copy, Debug)]
pub struct LocalExecutor {
    pub threads: usize,
}

impl LocalExecutor {
    pub fn new(threads: usize) -> Self {
        Self { threads }
    }
}

impl ClientExecutor for LocalExecutor {
    fn run_clients(
        &self,
        runner: &StepRunner,
        clients: &[Client],
        masks: &[MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>> {
        scope_map(jobs, self.threads, |_, job| {
            clients[job.client].local_train(
                runner,
                params,
                masks[job.client].tensors(),
                job.steps,
                job.lr,
                job.seed,
                job.use_fused,
            )
        })
    }

    fn run_deltas(
        &self,
        runner: &StepRunner,
        old: &[Tensor],
        news: &[&[Tensor]],
    ) -> Vec<crate::Result<Vec<Tensor>>> {
        // §Perf L3: voters execute the delta kernel concurrently —
        // calibration cost drops from #voters x delta_latency to roughly
        // one delta_latency (paper claims < 5% overhead)
        scope_map(news, self.threads, |_, new| runner.delta_step(old, new))
    }
}
