//! The client-execution seam.
//!
//! [`ClientExecutor`] is where the engine hands a round's per-client work
//! to a backend — and, since the fleet refactor, the *only* layer that
//! touches a runtime at all: the engine itself never sees a `StepRunner`.
//! Two backends ship in-tree:
//!
//! * [`LocalExecutor`] — the PJRT-backed in-process fork-join pool
//!   (`util::pool::scope_map`), exactly as the historical round loop ran;
//!   sharded / multi-process / remote backends plug in at the same seam.
//! * [`SimExecutor`] — a runtime-free deterministic backend for
//!   population-scale simulation: pseudo-training perturbs parameters
//!   from a per-(client, round) PRNG stream, the delta kernel is an exact
//!   host reimplementation, and evaluation returns pseudo-metrics derived
//!   from the parameter state. It needs no artifacts and no `xla`
//!   feature, which is what lets the 50k-client determinism suite run on
//!   CI hardware.
//!
//! Cohort slices are *job-aligned*: `cohort[i]` / `masks[i]` belong to
//! `jobs[i]`. The engine hydrates only the sampled cohort, so executors
//! never index by global client id.

use crate::data::Split;
use crate::dropout::MaskSet;
use crate::fl::codec::{pack_result, Compression};
use crate::fl::{self, AggScratch, Client, LocalResult, PackedResult};
use crate::model::ModelSpec;
use crate::runtime::StepRunner;
use crate::tensor::Tensor;
use crate::util::pool::scope_map;
use crate::util::prng::Pcg32;

/// One client's local-training work item for a round.
#[derive(Clone, Copy, Debug)]
pub struct TrainJob {
    /// global client id (PRNG salt + bookkeeping)
    pub client: usize,
    /// round index (sim backends shape pseudo-metrics with it)
    pub round: usize,
    /// local SGD steps
    pub steps: usize,
    pub lr: f32,
    /// round seed — clients salt it with their id internally
    pub seed: u64,
    /// route through the fused k-step artifact when available
    pub use_fused: bool,
}

/// Executes per-client work for the round engine.
///
/// Results align index-for-index with the submitted jobs; per-client
/// failures stay per-client so a future backend can surface partial
/// progress instead of poisoning the round.
pub trait ClientExecutor: Sync {
    /// The model's ordering contract (params / masks / delta groups).
    fn spec(&self) -> &ModelSpec;

    /// Worker-thread budget this executor runs with. The engine reuses
    /// the same budget for its server-side hot path (parallel masked
    /// FedAvg and the fused invariant-observation sweep), so one knob
    /// governs all in-process parallelism. Purely a performance hint:
    /// every engine result is bit-identical at any value (pinned by the
    /// determinism suite).
    fn threads(&self) -> usize {
        1
    }

    /// Run local training for every job. `cohort[i]` and `masks[i]` are
    /// the client and sub-model of `jobs[i]`; `params` the current global
    /// model.
    fn run_clients(
        &self,
        cohort: &[&Client],
        masks: &[&MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>>;

    /// Run local training and pack each result into the wire
    /// representation `mode` selects, reusing `scratch` pools for the
    /// packing maps. Dense mode is a pure passthrough; sparse/q8 pack
    /// only the mask's kept columns (quantization itself happens in the
    /// root engine's [`crate::fl::Codec`], never on workers — see
    /// `engine::sharded`). Provided so every backend gets the packed
    /// path from its existing `run_clients`.
    fn run_client_payloads(
        &self,
        cohort: &[&Client],
        masks: &[&MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
        mode: Compression,
        scratch: &mut AggScratch,
    ) -> Vec<crate::Result<PackedResult>> {
        self.run_clients(cohort, masks, params, jobs)
            .into_iter()
            .zip(masks)
            .map(|(r, m)| r.map(|res| pack_result(res, m, self.spec(), mode, scratch)))
            .collect()
    }

    /// Execute the invariant delta kernel for each voter's parameters
    /// against the pre-aggregation globals.
    fn run_deltas(
        &self,
        old: &[Tensor],
        news: &[&[Tensor]],
    ) -> Vec<crate::Result<Vec<Tensor>>>;

    /// Evaluate `params` over a split: (mean loss, accuracy).
    fn evaluate(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        split: &Split,
    ) -> crate::Result<(f64, f64)>;

    /// Telemetry drain: `(retries, backoff_ms)` — shard-slice
    /// re-dispatches performed since the last call and their summed
    /// deterministic virtual backoff ([`crate::engine::chaos::retry_backoff_ms`])
    /// in integer milliseconds. Plain executors never retry; the sharded
    /// tree overrides this, and the engine drains it once per round into
    /// the `shard_retries` telemetry.
    fn drain_fault_retries(&self) -> (usize, u64) {
        (0, 0)
    }
}

/// In-process executor over the scoped thread pool — the historical
/// `scope_map` execution path behind the trait seam.
/// (No `Debug` derive: the PJRT-backed `StepRunner` holds executable
/// handles that don't implement it.)
#[derive(Clone, Copy)]
pub struct LocalExecutor<'r> {
    runner: &'r StepRunner,
    pub threads: usize,
}

impl<'r> LocalExecutor<'r> {
    pub fn new(runner: &'r StepRunner, threads: usize) -> Self {
        Self { runner, threads }
    }
}

impl ClientExecutor for LocalExecutor<'_> {
    fn spec(&self) -> &ModelSpec {
        &self.runner.spec
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn run_clients(
        &self,
        cohort: &[&Client],
        masks: &[&MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>> {
        scope_map(jobs, self.threads, |i, job| {
            cohort[i].local_train(
                self.runner,
                params,
                masks[i].tensors(),
                job.steps,
                job.lr,
                job.seed,
                job.use_fused,
            )
        })
    }

    fn run_deltas(
        &self,
        old: &[Tensor],
        news: &[&[Tensor]],
    ) -> Vec<crate::Result<Vec<Tensor>>> {
        // §Perf L3: voters execute the delta kernel concurrently —
        // calibration cost drops from #voters x delta_latency to roughly
        // one delta_latency (paper claims < 5% overhead)
        scope_map(news, self.threads, |_, new| self.runner.delta_step(old, new))
    }

    fn evaluate(
        &self,
        params: &[Tensor],
        masks: &[Tensor],
        split: &Split,
    ) -> crate::Result<(f64, f64)> {
        fl::evaluate_split(self.runner, params, masks, split)
    }
}

/// Runtime-free deterministic backend for population-scale simulation.
///
/// Learning here is *pseudo*: what the backend guarantees is exact
/// replayability — every output is a pure function of `(global params,
/// job)` with no cross-client or cross-thread coupling, so a run is
/// bit-identical across thread counts and across replays of the same
/// seed. Timing, sampling, churn and aggregation (the things the fleet
/// layer actually studies) flow through the identical engine paths a
/// PJRT-backed run uses.
#[derive(Clone, Debug)]
pub struct SimExecutor {
    spec: ModelSpec,
    pub threads: usize,
}

impl SimExecutor {
    pub fn new(spec: ModelSpec, threads: usize) -> Self {
        Self { spec, threads }
    }
}

/// FNV-1a over parameter bit patterns — the deterministic state digest
/// sim evaluation seeds from.
fn param_digest(params: &[Tensor]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for t in params {
        for &v in t.data() {
            h ^= v.to_bits() as u64;
            h = h.wrapping_mul(0x1_0000_0001_B3);
        }
    }
    h
}

/// Host reimplementation of the L1 `neuron_delta` kernel: per-neuron max
/// relative weight change of each delta-input param (the same math the
/// runtime integration test checks the artifact against).
fn host_delta(spec: &ModelSpec, old: &[Tensor], new: &[Tensor]) -> Vec<Tensor> {
    spec.masks
        .iter()
        .enumerate()
        .map(|(g, m)| {
            let pi = spec
                .param_index(&spec.delta_inputs[g])
                .expect("delta input resolves (spec validated)");
            let (fan_in, neurons) = old[pi].as_2d_neurons();
            debug_assert_eq!(neurons, m.size);
            let o = old[pi].data();
            let n = new[pi].data();
            let mut out = vec![0.0f32; neurons];
            for r in 0..fan_in {
                for c in 0..neurons {
                    let ov = o[r * neurons + c];
                    let rel = (n[r * neurons + c] - ov).abs() / (ov.abs() + 1e-8);
                    if rel > out[c] {
                        out[c] = rel;
                    }
                }
            }
            Tensor::from_vec(&[neurons], out)
        })
        .collect()
}

impl ClientExecutor for SimExecutor {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn threads(&self) -> usize {
        self.threads
    }

    fn run_clients(
        &self,
        cohort: &[&Client],
        _masks: &[&MaskSet],
        params: &[Tensor],
        jobs: &[TrainJob],
    ) -> Vec<crate::Result<LocalResult>> {
        scope_map(jobs, self.threads, |i, job| {
            // client id as the PCG *stream* (like the latency-jitter
            // stream) — XOR-salting it into the seed would collide with
            // the round bits for ids >= 4096 at fleet scale
            let mut rng = Pcg32::new(job.seed ^ 0x51AB_17, job.client as u64);
            let step_scale = job.lr * 0.05 * (job.steps.max(1) as f32).sqrt();
            let new_params: Vec<Tensor> = params
                .iter()
                .map(|p| {
                    let mut q = p.clone();
                    for v in q.data_mut() {
                        *v += step_scale * (rng.next_f32() - 0.5);
                    }
                    q
                })
                .collect();
            // pseudo learning curve: decays with rounds, jitters per client
            let base = 2.5 / (1.0 + 0.15 * job.round as f64);
            let mean_loss = base * (0.9 + 0.2 * rng.next_f64());
            let mean_acc =
                ((1.0 - base / 2.5) * 0.9 + 0.05 * rng.next_f64()).clamp(0.0, 1.0);
            Ok(LocalResult {
                params: new_params,
                mean_loss,
                mean_acc,
                steps: job.steps,
                weight: cohort[i].data.len() as f64,
            })
        })
    }

    fn run_deltas(
        &self,
        old: &[Tensor],
        news: &[&[Tensor]],
    ) -> Vec<crate::Result<Vec<Tensor>>> {
        scope_map(news, self.threads, |_, new| {
            Ok(host_delta(&self.spec, old, new))
        })
    }

    fn evaluate(
        &self,
        params: &[Tensor],
        _masks: &[Tensor],
        split: &Split,
    ) -> crate::Result<(f64, f64)> {
        // pseudo-metrics: a pure function of the parameter state, so a
        // replay evaluates bit-identically. Drift of the parameter vector
        // away from zero stands in for learning progress.
        let mut abs_sum = 0.0f64;
        let mut count = 0usize;
        for t in params {
            for &v in t.data() {
                abs_sum += v.abs() as f64;
                count += 1;
            }
        }
        let drift = if count == 0 { 0.0 } else { abs_sum / count as f64 };
        let mut rng = Pcg32::new(param_digest(params), 0xE7A1);
        let loss = (2.3 / (1.0 + 8.0 * drift)).max(0.05) + 0.01 * rng.next_f64();
        let acc = (1.0 - loss / 2.4).clamp(0.0, 1.0);
        let _ = split;
        Ok((loss, acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::XStore;
    use crate::model::sim_spec;

    fn sim_cohort(n: usize) -> Vec<Client> {
        (0..n)
            .map(|i| {
                Client::new(
                    i * 3, // non-contiguous global ids, as fleet cohorts have
                    0,
                    Split {
                        xs: XStore::F32(vec![0.0; 4 * (i + 2)]),
                        ys: vec![0; i + 2],
                        feature_len: 4,
                    },
                )
            })
            .collect()
    }

    #[test]
    fn sim_training_is_thread_count_invariant() {
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(7);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(6);
        let cohort: Vec<&Client> = clients.iter().collect();
        let masks: Vec<&MaskSet> = clients.iter().map(|_| &full).collect();
        let jobs: Vec<TrainJob> = clients
            .iter()
            .map(|c| TrainJob {
                client: c.id,
                round: 2,
                steps: 3,
                lr: 0.01,
                seed: 99,
                use_fused: false,
            })
            .collect();
        let a: Vec<LocalResult> = SimExecutor::new(spec.clone(), 1)
            .run_clients(&cohort, &masks, &params, &jobs)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let b: Vec<LocalResult> = SimExecutor::new(spec, 8)
            .run_clients(&cohort, &masks, &params, &jobs)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.params, y.params);
            assert_eq!(x.mean_loss.to_bits(), y.mean_loss.to_bits());
            assert_eq!(x.weight, y.weight);
        }
        // per-client streams differ
        assert_ne!(a[0].params, a[1].params);
        // weight is the shard size
        assert_eq!(a[0].weight, 2.0);
        assert_eq!(a[5].weight, 7.0);
    }

    #[test]
    fn packed_payload_path_round_trips_sim_results() {
        use crate::fl::codec::unpack_result;
        let spec = sim_spec("femnist_cnn");
        let params = spec.init_params(5);
        let full = MaskSet::full(&spec);
        let clients = sim_cohort(4);
        let cohort: Vec<&Client> = clients.iter().collect();
        let masks: Vec<&MaskSet> = clients.iter().map(|_| &full).collect();
        let jobs: Vec<TrainJob> = clients
            .iter()
            .map(|c| TrainJob {
                client: c.id,
                round: 1,
                steps: 2,
                lr: 0.02,
                seed: 41,
                use_fused: false,
            })
            .collect();
        let ex = SimExecutor::new(spec.clone(), 2);
        let plain = ex.run_clients(&cohort, &masks, &params, &jobs);
        let mut scratch = AggScratch::new();
        let packed =
            ex.run_client_payloads(&cohort, &masks, &params, &jobs, Compression::Sparse, &mut scratch);
        assert_eq!(plain.len(), packed.len());
        for (p, pk) in plain.into_iter().zip(packed) {
            let p = p.unwrap();
            let got = unpack_result(pk.unwrap(), &full, &params, &spec, &mut scratch).unwrap();
            // full masks: the sparse packing is lossless even for sim output
            assert_eq!(p.params, got.params);
            assert_eq!(p.mean_loss.to_bits(), got.mean_loss.to_bits());
            assert_eq!(p.weight.to_bits(), got.weight.to_bits());
            assert_eq!(p.steps, got.steps);
        }
    }

    #[test]
    fn sim_delta_matches_host_math() {
        let spec = sim_spec("femnist_cnn");
        let old = spec.init_params(1);
        let mut new = old.clone();
        // move fc1_w column 0 hard, leave column 1 untouched
        let pi = spec.param_index("fc1_w").unwrap();
        let (fan_in, neurons) = new[pi].as_2d_neurons();
        for r in 0..fan_in {
            new[pi].data_mut()[r * neurons] += 1.0;
        }
        let ex = SimExecutor::new(spec.clone(), 2);
        let news: Vec<&[Tensor]> = vec![new.as_slice()];
        let deltas = ex.run_deltas(&old, &news).pop().unwrap().unwrap();
        assert_eq!(deltas.len(), spec.masks.len());
        assert_eq!(deltas[0].len(), spec.masks[0].size);
        assert!(deltas[0].data()[0] > deltas[0].data()[1]);
        assert_eq!(deltas[0].data()[1], 0.0);
    }

    #[test]
    fn sim_eval_is_deterministic_in_param_state() {
        let spec = sim_spec("cifar_vgg9");
        let ex = SimExecutor::new(spec.clone(), 1);
        let params = spec.init_params(3);
        let split = Split {
            xs: XStore::F32(vec![0.0; 8]),
            ys: vec![0, 1],
            feature_len: 4,
        };
        let full: Vec<Tensor> = MaskSet::full(&spec).tensors().to_vec();
        let (l1, a1) = ex.evaluate(&params, &full, &split).unwrap();
        let (l2, a2) = ex.evaluate(&params, &full, &split).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits());
        assert_eq!(a1.to_bits(), a2.to_bits());
        let other = spec.init_params(4);
        let (l3, _) = ex.evaluate(&other, &full, &split).unwrap();
        assert_ne!(l1.to_bits(), l3.to_bits());
    }
}
