//! The calibration interface between policy and mechanics.
//!
//! [`RoundPlan`] is everything the server decides *before* any client
//! runs: sampling, straggler assignments, sub-model masks, the barrier
//! target. [`RoundOutcome`] is everything the round produced. Together
//! they are the narrow seam through which `dropout::Policy` and
//! `straggler::detect` drive the engine — round mechanics never reach
//! back into policy state.

use crate::dropout::MaskSet;

/// Server-side decisions for one round, fixed before execution.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub round: usize,
    /// training progress fraction (fluctuation schedule lookup)
    pub t_frac: f64,
    /// per-round seed for client PRNGs and latency jitter
    pub round_seed: u64,
    /// clients sampled this round (A.6)
    pub selected: Vec<usize>,
    /// selected clients that are free to run (semi-async modes may leave
    /// a straggler busy finishing a previous round)
    pub active: Vec<usize>,
    /// active clients that actually train (Exclude policy removes
    /// stragglers here)
    pub participants: Vec<usize>,
    /// current straggler set, slowest first
    pub straggler_ids: Vec<usize>,
    /// per-client keep-rate table (1.0 = full model)
    pub rates: Vec<f64>,
    /// per-client sub-model masks
    pub masks: Vec<MaskSet>,
    /// detection's target time, when a detection exists
    pub t_target: Option<f64>,
    /// does the invariant policy observe deltas this round?
    pub is_calib_round: bool,
    /// wall-clock seconds spent on server-side planning
    pub calib_secs: f64,
}

/// Everything one executed round produced, before it is folded into the
/// experiment history.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// virtual seconds the round occupied the server
    pub round_time: f64,
    /// effective target time (round max when no detection exists)
    pub t_target: f64,
    /// slowest straggler arrival this round
    pub straggler_time: f64,
    /// example-weighted mean train loss over aggregated updates
    pub train_loss: f64,
    /// example-weighted mean train accuracy over aggregated updates
    pub train_acc: f64,
    /// test metrics (NaN on non-eval rounds)
    pub test_loss: f64,
    pub test_acc: f64,
    pub invariant_fraction: f64,
    /// updates folded into this round's FedAvg (fresh + stale)
    pub aggregated: usize,
    /// late updates discarded by the Deadline barrier
    pub dropped_updates: usize,
    /// buffered stale updates folded in with a staleness discount
    pub stale_folded: usize,
    /// wall-clock seconds of planning + delta observation
    pub calibration_secs: f64,
}
