//! The calibration interface between policy and mechanics.
//!
//! [`RoundPlan`] is everything the server decides *before* any client
//! runs: sampling, straggler assignments, sub-model masks, the barrier
//! target. [`RoundOutcome`] is everything the round produced. Together
//! they are the narrow seam through which `dropout::Policy` and
//! `straggler::detect` drive the engine — round mechanics never reach
//! back into policy state.

use crate::dropout::MaskSet;

/// Per-client sub-model masks, stored sparsely: only stragglers carry a
/// non-full mask, so a 100k-client fleet costs a handful of override
/// entries instead of 100k `MaskSet` clones per round.
#[derive(Clone, Debug)]
pub struct MaskTable {
    full: MaskSet,
    /// (client, mask) overrides, sorted by client id
    overrides: Vec<(usize, MaskSet)>,
}

impl MaskTable {
    pub fn new(full: MaskSet) -> Self {
        Self {
            full,
            overrides: Vec::new(),
        }
    }

    /// Install a non-full mask for `client` (replaces a prior override).
    pub fn set(&mut self, client: usize, mask: MaskSet) {
        match self.overrides.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => self.overrides[i].1 = mask,
            Err(i) => self.overrides.insert(i, (client, mask)),
        }
    }

    /// The mask `client` trains under this round.
    pub fn get(&self, client: usize) -> &MaskSet {
        match self.overrides.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => &self.overrides[i].1,
            Err(_) => &self.full,
        }
    }

    /// All non-full assignments (stragglers with sub-models).
    pub fn overrides(&self) -> &[(usize, MaskSet)] {
        &self.overrides
    }

    /// The override for `client`, if one is installed — `None` means the
    /// client trains (and transmits) the full model.
    pub fn override_for(&self, client: usize) -> Option<&MaskSet> {
        match self.overrides.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => Some(&self.overrides[i].1),
            Err(_) => None,
        }
    }

    pub fn full_mask(&self) -> &MaskSet {
        &self.full
    }
}

/// Per-client keep-rates, stored sparsely: only stragglers with an
/// actual sub-model carry a rate below 1.0, so the table costs
/// O(stragglers) instead of the former `vec![1.0; fleet]` per round.
#[derive(Clone, Debug, Default)]
pub struct RateTable {
    /// (client, keep-rate) overrides, sorted by client id
    entries: Vec<(usize, f64)>,
}

impl RateTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install a keep-rate for `client` (replaces a prior entry).
    pub fn set(&mut self, client: usize, rate: f64) {
        match self.entries.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => self.entries[i].1 = rate,
            Err(i) => self.entries.insert(i, (client, rate)),
        }
    }

    /// The keep-rate `client` trains under (1.0 = full model).
    pub fn get(&self, client: usize) -> f64 {
        match self.entries.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => self.entries[i].1,
            Err(_) => 1.0,
        }
    }

    /// All sub-model assignments (clients with keep-rate below 1.0).
    pub fn entries(&self) -> &[(usize, f64)] {
        &self.entries
    }
}

/// Server-side decisions for one round, fixed before execution.
#[derive(Clone, Debug)]
pub struct RoundPlan {
    pub round: usize,
    /// training progress fraction (fluctuation schedule lookup)
    pub t_frac: f64,
    /// per-round seed for client PRNGs and latency jitter
    pub round_seed: u64,
    /// clients sampled this round (A.6 / fleet cohort)
    pub selected: Vec<usize>,
    /// selected clients that are free to run (semi-async modes may leave
    /// a straggler busy finishing a previous round; fleet churn removes
    /// unavailable clients here)
    pub active: Vec<usize>,
    /// active clients that actually train (Exclude policy removes
    /// stragglers here)
    pub participants: Vec<usize>,
    /// current straggler set, slowest first
    pub straggler_ids: Vec<usize>,
    /// the same set sorted by client id — the round hot path (participant
    /// + delta-voter filters) membership-tests against this instead of
    /// `contains`-scanning `straggler_ids` per client; O(stragglers)
    /// memory where the former bitmap was O(fleet) per round
    pub straggler_sorted: Vec<usize>,
    /// per-client keep-rate table, sparse over 1.0 (full model)
    pub rates: RateTable,
    /// per-client sub-model masks (sparse over the full mask)
    pub masks: MaskTable,
    /// detection's target time, when a detection exists
    pub t_target: Option<f64>,
    /// does the invariant policy observe deltas this round?
    pub is_calib_round: bool,
    /// wall-clock seconds spent on server-side planning
    pub calib_secs: f64,
    /// per-client soft-training fractions, sparse over 1.0 (full local
    /// epoch) — Helios-style policies trim local steps instead of the
    /// model; empty for the whole FLuID family
    pub train_frac: Vec<(usize, f64)>,
}

impl RoundPlan {
    /// Is `client` in this round's straggler set? O(log stragglers).
    pub fn is_straggler(&self, client: usize) -> bool {
        self.straggler_sorted.binary_search(&client).is_ok()
    }

    /// The keep-rate `client` trains under (1.0 = full model).
    pub fn rate(&self, client: usize) -> f64 {
        self.rates.get(client)
    }

    /// The soft-training fraction `client` runs under (1.0 = full epoch).
    pub fn train_fraction(&self, client: usize) -> f64 {
        match self.train_frac.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => self.train_frac[i].1,
            Err(_) => 1.0,
        }
    }

    /// Local steps for `client` given the configured budget. Returns
    /// `base` exactly (no float ops) when no fraction is assigned, so
    /// FLuID-family rounds are untouched by the soft-training seam.
    pub fn train_steps(&self, client: usize, base: usize) -> usize {
        if self.train_frac.is_empty() {
            return base;
        }
        match self.train_frac.binary_search_by_key(&client, |(c, _)| *c) {
            Ok(i) => ((base as f64 * self.train_frac[i].1).round() as usize).max(1),
            Err(_) => base,
        }
    }
}

/// Everything one executed round produced, before it is folded into the
/// experiment history.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// virtual seconds the round occupied the server
    pub round_time: f64,
    /// effective target time (round max when no detection exists)
    pub t_target: f64,
    /// slowest straggler arrival this round
    pub straggler_time: f64,
    /// example-weighted mean train loss over aggregated updates
    pub train_loss: f64,
    /// example-weighted mean train accuracy over aggregated updates
    pub train_acc: f64,
    /// test metrics (NaN on non-eval rounds)
    pub test_loss: f64,
    pub test_acc: f64,
    pub invariant_fraction: f64,
    /// updates folded into this round's FedAvg (fresh + stale)
    pub aggregated: usize,
    /// late updates discarded by the Deadline barrier
    pub dropped_updates: usize,
    /// buffered stale updates folded in with a staleness discount
    pub stale_folded: usize,
    /// summed wire cost of every payload entering this round's FedAvg
    /// ([`crate::fl::DeltaPayload::wire_bytes`]) — the bytes-moved
    /// report the compression modes are judged by
    pub update_bytes: usize,
    /// wall-clock seconds of planning + delta observation
    pub calibration_secs: f64,
    /// participants whose updates never arrived this round (chaos
    /// `Vanish`/`Hang` faults dropped at the deadline)
    pub vanished: usize,
    /// updates refused by the [`super::UpdateValidator`] (corrupt /
    /// non-finite / out-of-bound payloads sent to quarantine)
    pub quarantined: usize,
    /// shard-slice re-dispatches the executor performed this round
    pub shard_retries: usize,
    /// fresh on-time updates as a fraction of the planned participants
    /// (1.0 when the round planned no participants)
    pub quorum_fraction: f64,
    /// virtual seconds the round waited on its slowest straggler beyond
    /// the detection target
    pub straggler_wait: f64,
    /// stale updates the mitigation policy admitted this round
    pub admitted_stale: usize,
    /// mean soft-training fraction over participants (1.0 when no
    /// policy trims local epochs)
    pub soft_fraction: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    #[test]
    fn mask_table_is_sparse_over_full() {
        let spec = tiny_spec();
        let mut t = MaskTable::new(MaskSet::full(&spec));
        assert!(t.get(7).is_full());
        assert!(t.overrides().is_empty());

        let keep = vec![vec![true; 10], vec![true, true, true, false, false, false]];
        let m = MaskSet::from_keep(&spec, &keep);
        t.set(3, m.clone());
        t.set(1, m.clone());
        assert_eq!(t.overrides().len(), 2);
        assert_eq!(t.overrides()[0].0, 1, "overrides sorted by client");
        assert_eq!(t.get(3).kept(1), 3);
        assert!(t.get(2).is_full());
        assert!(t.full_mask().is_full());

        // replacing an override keeps the table deduplicated
        t.set(3, MaskSet::full(&spec));
        assert_eq!(t.overrides().len(), 2);
        assert!(t.get(3).is_full());
        assert!(t.override_for(1).is_some());
        assert!(t.override_for(2).is_none());
    }

    fn empty_plan() -> RoundPlan {
        let spec = tiny_spec();
        RoundPlan {
            round: 0,
            t_frac: 0.0,
            round_seed: 0,
            selected: vec![],
            active: vec![],
            participants: vec![],
            straggler_ids: vec![],
            straggler_sorted: vec![],
            rates: RateTable::new(),
            masks: MaskTable::new(MaskSet::full(&spec)),
            t_target: None,
            is_calib_round: false,
            calib_secs: 0.0,
            train_frac: vec![],
        }
    }

    #[test]
    fn train_steps_are_exact_without_fractions_and_scaled_with() {
        let mut p = empty_plan();
        // no table: the base budget passes through untouched
        assert_eq!(p.train_steps(3, 4), 4);
        assert_eq!(p.train_fraction(3), 1.0);
        p.train_frac = vec![(2, 0.5), (5, 0.1)];
        assert_eq!(p.train_steps(2, 4), 2);
        assert_eq!(p.train_steps(5, 4), 1, "floored at one step");
        assert_eq!(p.train_steps(3, 4), 4, "unlisted client keeps base");
        assert_eq!(p.train_fraction(5), 0.1);
    }

    #[test]
    fn rate_table_is_sparse_over_full_rate() {
        let mut r = RateTable::new();
        assert_eq!(r.get(9), 1.0);
        assert!(r.entries().is_empty());
        r.set(5, 0.75);
        r.set(2, 0.5);
        r.set(5, 0.6); // replace keeps the table deduplicated
        assert_eq!(r.entries(), &[(2, 0.5), (5, 0.6)]);
        assert_eq!(r.get(5), 0.6);
        assert_eq!(r.get(2), 0.5);
        assert_eq!(r.get(0), 1.0);
    }
}
