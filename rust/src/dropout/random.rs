//! Federated Dropout baseline [CKMT18]: drop a uniformly random subset of
//! neurons per group, re-sampled every time a sub-model is extracted.

use super::mask::{kept_count, MaskSet};
use crate::model::ModelSpec;
use crate::util::prng::Pcg32;

pub struct RandomDropout {
    rng: Pcg32,
}

impl RandomDropout {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed, 0xD20),
        }
    }

    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| {
                let k = kept_count(m.size, r);
                let chosen = self.rng.sample_indices(m.size, k);
                let mut v = vec![false; m.size];
                for i in chosen {
                    v[i] = true;
                }
                v
            })
            .collect();
        MaskSet::from_keep(spec, &keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    #[test]
    fn keeps_requested_fraction() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(7);
        let m = p.make_mask(&spec, 0.5);
        assert_eq!(m.kept(0), 5);
        assert_eq!(m.kept(1), 3);
    }

    #[test]
    fn resamples_each_call() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(7);
        let a = p.make_mask(&spec, 0.5);
        let b = p.make_mask(&spec, 0.5);
        // overwhelmingly likely to differ (10 choose 5 ways)
        assert_ne!(a, b);
    }

    #[test]
    fn full_rate_keeps_everything() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(1);
        assert!(p.make_mask(&spec, 1.0).is_full());
    }
}
