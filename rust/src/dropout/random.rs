//! Federated Dropout baseline [CKMT18]: drop a uniformly random subset of
//! neurons per group, re-sampled every time a sub-model is extracted.

use super::mask::{kept_count, MaskSet};
use crate::model::ModelSpec;
use crate::util::prng::Pcg32;

pub struct RandomDropout {
    rng: Pcg32,
}

impl RandomDropout {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Pcg32::new(seed, 0xD20),
        }
    }

    /// Raw PRNG stream position — what a resumed run must continue from,
    /// since every extraction advances the stream.
    pub fn rng_state(&self) -> (u64, u64) {
        self.rng.state_parts()
    }

    /// Restore the stream position captured by [`RandomDropout::rng_state`].
    pub fn set_rng_state(&mut self, state: u64, inc: u64) {
        self.rng = Pcg32::from_state_parts(state, inc);
    }

    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| {
                let k = kept_count(m.size, r);
                let chosen = self.rng.sample_indices(m.size, k);
                let mut v = vec![false; m.size];
                for i in chosen {
                    v[i] = true;
                }
                v
            })
            .collect();
        MaskSet::from_keep(spec, &keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    #[test]
    fn keeps_requested_fraction() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(7);
        let m = p.make_mask(&spec, 0.5);
        assert_eq!(m.kept(0), 5);
        assert_eq!(m.kept(1), 3);
    }

    #[test]
    fn resamples_each_call() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(7);
        let a = p.make_mask(&spec, 0.5);
        let b = p.make_mask(&spec, 0.5);
        // overwhelmingly likely to differ (10 choose 5 ways)
        assert_ne!(a, b);
    }

    #[test]
    fn rng_state_restore_replays_the_next_mask() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(7);
        let _ = p.make_mask(&spec, 0.5); // advance the stream
        let (state, inc) = p.rng_state();
        let next = p.make_mask(&spec, 0.5);
        let mut q = RandomDropout::new(12345); // different seed...
        q.set_rng_state(state, inc); // ...but restored position
        assert_eq!(q.make_mask(&spec, 0.5), next);
    }

    #[test]
    fn full_rate_keeps_everything() {
        let spec = tiny_spec();
        let mut p = RandomDropout::new(1);
        assert!(p.make_mask(&spec, 1.0).is_full());
    }
}
