//! Drop-threshold calibration (paper §5, Algorithm 1 lines 9 & 22).
//!
//! The threshold `th` classifies a neuron as *invariant* when its
//! relative weight update is below `th`. FLuID initializes `th` from the
//! observed update distribution and increments it until the invariant
//! set is at least as large as the number of neurons that must leave the
//! sub-model ("it is critical to select a threshold that yields a number
//! of invariant neurons as close as possible to the number of neurons to
//! be dropped" — Appendix A.2).

/// Initial threshold: the paper uses "the average of the minimum percent
/// update of all neurons in the initial few training epochs". Given one
/// delta vector per (non-straggler) client, that is the mean over clients
/// of each client's minimum per-neuron update.
pub fn initial_threshold(per_client_deltas: &[Vec<f32>]) -> f32 {
    let minima: Vec<f32> = per_client_deltas
        .iter()
        .map(|c| c.iter().copied().fold(f32::INFINITY, f32::min))
        .collect();
    initial_from_minima(&minima)
}

/// [`initial_threshold`] when the per-client minima are already known —
/// the fused observation sweep computes them in its chunked reduction
/// and hands them here, so the two paths can never drift. Non-finite
/// minima (a client with no neurons, or all-NaN deltas) are skipped.
pub fn initial_from_minima(minima: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for &min in minima {
        if min.is_finite() {
            acc += min as f64;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        (acc / n as f64) as f32
    }
}

/// Count of neurons strictly below the threshold.
pub fn count_below(scores: &[f32], th: f32) -> usize {
    scores.iter().filter(|&&s| s < th).count()
}

/// Incrementally raise `th` (multiplicative step) until at least `needed`
/// neurons fall below it, or `max_iters` is exhausted. Returns the
/// calibrated threshold. Mirrors `increment_threshold` in Algorithm 1.
pub fn calibrate(scores: &[f32], mut th: f32, needed: usize, step: f32, max_iters: usize) -> f32 {
    assert!(step > 1.0, "step must be multiplicative > 1");
    if needed == 0 || scores.is_empty() {
        return th;
    }
    if th <= 0.0 {
        // bootstrap from the smallest positive score
        th = scores
            .iter()
            .copied()
            .filter(|&s| s > 0.0)
            .fold(f32::INFINITY, f32::min);
        if !th.is_finite() {
            th = 1e-6;
        }
        th *= 1.01; // strictly above the minimum so count_below >= 1
    }
    for _ in 0..max_iters {
        if count_below(scores, th) >= needed.min(scores.len()) {
            return th;
        }
        th *= step;
    }
    th
}

/// Exact alternative used when the score vector is fully known: the
/// threshold that yields *exactly* `needed` invariant neurons (the
/// (needed)-th smallest score, nudged up). Used by the coordinator once
/// calibration has converged; the incremental path above is what runs
/// during the initial epochs when scores are still streaming in.
pub fn exact_threshold(scores: &[f32], needed: usize) -> f32 {
    if needed == 0 || scores.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = scores.to_vec();
    // NaN-safe: a poisoned score sorts last instead of panicking
    v.sort_by(f32::total_cmp);
    let k = needed.min(v.len()) - 1;
    // strictly above the k-th smallest
    v[k] * (1.0 + 1e-6) + 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_is_mean_of_client_minima() {
        let deltas = vec![vec![0.5, 0.1, 0.9], vec![0.3, 0.7, 0.2]];
        assert!((initial_threshold(&deltas) - 0.15).abs() < 1e-6);
    }

    #[test]
    fn initial_handles_empty() {
        assert_eq!(initial_threshold(&[]), 0.0);
        assert_eq!(initial_threshold(&[vec![]]), 0.0);
    }

    #[test]
    fn calibrate_reaches_target() {
        let scores: Vec<f32> = (1..=100).map(|i| i as f32 / 100.0).collect();
        let th = calibrate(&scores, 0.005, 30, 1.2, 200);
        assert!(count_below(&scores, th) >= 30);
        // and not grossly overshooting: one step below would be short
        assert!(count_below(&scores, th / 1.2) < 30);
    }

    #[test]
    fn calibrate_monotone_in_needed() {
        let scores: Vec<f32> = (1..=50).map(|i| (i * i) as f32 * 1e-4).collect();
        let th10 = calibrate(&scores, 1e-5, 10, 1.1, 500);
        let th30 = calibrate(&scores, 1e-5, 30, 1.1, 500);
        assert!(th30 >= th10);
    }

    #[test]
    fn calibrate_bootstraps_zero_threshold() {
        let scores = vec![0.2, 0.4, 0.6];
        let th = calibrate(&scores, 0.0, 2, 1.5, 100);
        assert!(count_below(&scores, th) >= 2);
    }

    #[test]
    fn exact_threshold_counts() {
        let scores = vec![0.5, 0.1, 0.9, 0.3, 0.7];
        let th = exact_threshold(&scores, 2);
        assert_eq!(count_below(&scores, th), 2);
        let th = exact_threshold(&scores, 5);
        assert_eq!(count_below(&scores, th), 5);
    }
}
