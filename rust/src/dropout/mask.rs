//! `MaskSet` — a sub-model as per-group neuron masks.

use crate::model::ModelSpec;
use crate::tensor::Tensor;

/// One 0/1 f32 vector per maskable group, aligned with `spec.masks`.
#[derive(Clone, Debug, PartialEq)]
pub struct MaskSet {
    masks: Vec<Tensor>,
}

impl MaskSet {
    /// Full model: all ones.
    pub fn full(spec: &ModelSpec) -> MaskSet {
        MaskSet {
            masks: spec.masks.iter().map(|m| Tensor::ones(&[m.size])).collect(),
        }
    }

    /// Build from explicit keep-decisions per group.
    pub fn from_keep(spec: &ModelSpec, keep: &[Vec<bool>]) -> MaskSet {
        assert_eq!(keep.len(), spec.masks.len());
        let masks = spec
            .masks
            .iter()
            .zip(keep)
            .map(|(m, k)| {
                assert_eq!(m.size, k.len(), "group {}", m.name);
                Tensor::from_vec(
                    &[m.size],
                    k.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect(),
                )
            })
            .collect();
        MaskSet { masks }
    }

    /// Rebuild from raw per-group 0/1 tensors in group order — the
    /// snapshot-decode path. The inverse of [`MaskSet::tensors`].
    pub fn from_tensors(masks: Vec<Tensor>) -> MaskSet {
        MaskSet { masks }
    }

    /// Per-group tensors in manifest order (what the runtime takes).
    pub fn tensors(&self) -> &[Tensor] {
        &self.masks
    }

    /// Number of kept neurons in group `g`.
    pub fn kept(&self, g: usize) -> usize {
        self.masks[g].data().iter().filter(|&&x| x == 1.0).count()
    }

    /// Total kept / total neurons.
    pub fn keep_fraction(&self) -> f64 {
        let total: usize = self.masks.iter().map(|m| m.len()).sum();
        let kept: usize = (0..self.masks.len()).map(|g| self.kept(g)).sum();
        if total == 0 {
            1.0
        } else {
            kept as f64 / total as f64
        }
    }

    pub fn num_groups(&self) -> usize {
        self.masks.len()
    }

    /// Is neuron `i` of group `g` kept?
    pub fn is_kept(&self, g: usize, i: usize) -> bool {
        self.masks[g].data()[i] == 1.0
    }

    pub fn is_full(&self) -> bool {
        self.masks
            .iter()
            .all(|m| m.data().iter().all(|&x| x == 1.0))
    }

    /// Effective parameter fraction transmitted to a straggler — used by
    /// the communication model. Computed per maskable group as the kept
    /// fraction (output layers, biases and unmasked layers count as 1.0,
    /// conservatively matching the paper's "sub-model as a fraction of
    /// the global model" definition of r).
    pub fn comm_fraction(&self) -> f64 {
        self.keep_fraction()
    }
}

/// How many neurons must be *kept* in a group of size `n` at keep-rate
/// `r` (per-layer rate, paper §4.1). Never drops below 1 neuron.
pub fn kept_count(n: usize, r: f64) -> usize {
    ((n as f64 * r).round() as usize).clamp(1, n)
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::model::ModelSpec;
    use std::path::Path;

    pub(crate) const MANIFEST: &str = r#"{
 "model": "tiny", "batch_size": 4,
 "x_shape": [4, 8], "x_dtype": "f32", "num_classes": 3,
 "params": [
   {"name": "fc1_w", "shape": [8, 10]}, {"name": "fc1_b", "shape": [10]},
   {"name": "fc2_w", "shape": [10, 6]}, {"name": "fc2_b", "shape": [6]},
   {"name": "out_w", "shape": [6, 3]}, {"name": "out_b", "shape": [3]}
 ],
 "masks": [{"name": "fc1", "size": 10}, {"name": "fc2", "size": 6}],
 "delta_groups": ["fc1", "fc2"],
 "delta_inputs": ["fc1_w", "fc2_w"],
 "artifacts": {"train": "t", "eval": "e", "delta": "d"},
 "train_outputs": []
}"#;

    pub(crate) fn tiny_spec() -> ModelSpec {
        ModelSpec::from_json_str(MANIFEST, Path::new("/tmp")).unwrap()
    }

    #[test]
    fn full_mask_is_all_ones() {
        let m = MaskSet::full(&tiny_spec());
        assert!(m.is_full());
        assert_eq!(m.keep_fraction(), 1.0);
        assert_eq!(m.kept(0), 10);
        assert_eq!(m.kept(1), 6);
    }

    #[test]
    fn from_keep_counts() {
        let spec = tiny_spec();
        let keep = vec![
            vec![true, true, true, true, true, false, false, false, false, false],
            vec![true, true, true, false, false, false],
        ];
        let m = MaskSet::from_keep(&spec, &keep);
        assert_eq!(m.kept(0), 5);
        assert_eq!(m.kept(1), 3);
        assert!((m.keep_fraction() - 0.5).abs() < 1e-9);
        assert!(m.is_kept(0, 0) && !m.is_kept(0, 9));
        assert!(!m.is_full());
    }

    #[test]
    fn kept_count_bounds() {
        assert_eq!(kept_count(10, 1.0), 10);
        assert_eq!(kept_count(10, 0.75), 8);
        assert_eq!(kept_count(10, 0.5), 5);
        assert_eq!(kept_count(10, 0.0), 1); // never empty
        assert_eq!(kept_count(1, 0.1), 1);
    }
}
