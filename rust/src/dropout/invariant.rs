//! Invariant Dropout — the paper's contribution (§4, §5, Algorithm 1).
//!
//! The server watches the per-neuron relative weight updates of the
//! **non-straggler** clients (stragglers only train sub-models, so their
//! updates cannot vote). A neuron is *invariant* when its update falls
//! below the drop-threshold `th` for the majority of non-stragglers, for
//! `persistence` consecutive calibration steps ("targets neurons for
//! dropping whose gradients consistently fall below the threshold over
//! multiple epochs"). Sub-model extraction drops the lowest-update
//! invariant neurons first, calibrating `th` upward until the invariant
//! set covers the number of neurons that must leave the sub-model.

use super::mask::{kept_count, MaskSet};
use super::threshold;
use crate::model::ModelSpec;
use crate::tensor::Tensor;

/// Tunables for the invariant policy.
#[derive(Clone, Copy, Debug)]
pub struct InvariantConfig {
    /// multiplicative threshold increment per calibration step
    pub step: f32,
    /// consecutive below-threshold calibrations before a neuron is a
    /// first-class drop candidate
    pub persistence: u32,
    /// fraction of non-stragglers that must agree a neuron is invariant
    pub majority: f64,
    /// max calibration iterations per extraction
    pub max_iters: usize,
    /// freeze all group thresholds at this value (Table 3's controlled
    /// sweep); None = calibrate automatically (Algorithm 1)
    pub th_override: Option<f32>,
}

impl Default for InvariantConfig {
    fn default() -> Self {
        Self {
            step: 1.25,
            persistence: 2,
            majority: 0.5,
            max_iters: 200,
            th_override: None,
        }
    }
}

/// Invariant Dropout state held by the FLuID server.
pub struct InvariantDropout {
    pub cfg: InvariantConfig,
    /// per-group drop threshold (per-layer thresholds, paper §5)
    th: Vec<f32>,
    /// per-group per-neuron consecutive below-threshold count
    streak: Vec<Vec<u32>>,
    /// per-group per-neuron mean relative update over the last observation
    score: Vec<Vec<f32>>,
    observations: usize,
}

impl InvariantDropout {
    pub fn new(spec: &ModelSpec, cfg: InvariantConfig) -> Self {
        Self {
            cfg,
            th: vec![0.0; spec.masks.len()],
            streak: spec.masks.iter().map(|m| vec![0; m.size]).collect(),
            score: spec.masks.iter().map(|m| vec![0.0; m.size]).collect(),
            observations: 0,
        }
    }

    /// Has the policy seen any non-straggler updates yet? Until then,
    /// stragglers receive the full model (Algorithm 1's initialization
    /// epochs).
    pub fn ready(&self) -> bool {
        self.observations > 0
    }

    pub fn thresholds(&self) -> &[f32] {
        &self.th
    }

    /// Mean per-neuron update score for group `g` (Fig 6 / Table 3).
    pub fn scores(&self, g: usize) -> &[f32] {
        &self.score[g]
    }

    /// Fraction of all neurons currently below the (per-group) threshold —
    /// the "percentage of invariant neurons" metric of Fig 6 and Table 3.
    pub fn invariant_fraction(&self) -> f64 {
        let mut below = 0usize;
        let mut total = 0usize;
        for (g, sc) in self.score.iter().enumerate() {
            below += threshold::count_below(sc, self.th[g]);
            total += sc.len();
        }
        if total == 0 {
            0.0
        } else {
            below as f64 / total as f64
        }
    }

    /// Same metric at an explicit global threshold (Table 3 sweeps).
    pub fn invariant_fraction_at(&self, th: f32) -> f64 {
        let mut below = 0usize;
        let mut total = 0usize;
        for sc in &self.score {
            below += threshold::count_below(sc, th);
            total += sc.len();
        }
        if total == 0 {
            0.0
        } else {
            below as f64 / total as f64
        }
    }

    /// Override per-group thresholds (Table 3's controlled sweep).
    pub fn set_thresholds(&mut self, th: f32) {
        for t in &mut self.th {
            *t = th;
        }
    }

    /// Raw resumable state `(th, streak, score, observations)` — the
    /// evolving part of the policy that a checkpoint must capture (the
    /// config is reconstructed from the experiment seed).
    #[allow(clippy::type_complexity)]
    pub fn export_state(&self) -> (Vec<f32>, Vec<Vec<u32>>, Vec<Vec<f32>>, usize) {
        (
            self.th.clone(),
            self.streak.clone(),
            self.score.clone(),
            self.observations,
        )
    }

    /// Restore state captured by [`InvariantDropout::export_state`].
    /// Group shapes must match the spec this policy was built against.
    pub fn import_state(
        &mut self,
        th: Vec<f32>,
        streak: Vec<Vec<u32>>,
        score: Vec<Vec<f32>>,
        observations: usize,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            th.len() == self.th.len()
                && streak.len() == self.streak.len()
                && score.len() == self.score.len(),
            "snapshot has {}/{}/{} policy groups, model has {}",
            th.len(),
            streak.len(),
            score.len(),
            self.th.len()
        );
        for g in 0..streak.len() {
            anyhow::ensure!(
                streak[g].len() == self.streak[g].len()
                    && score[g].len() == self.score[g].len(),
                "policy group {g}: snapshot sizes {}/{} vs model {}",
                streak[g].len(),
                score[g].len(),
                self.streak[g].len()
            );
        }
        self.th = th;
        self.streak = streak;
        self.score = score;
        self.observations = observations;
        Ok(())
    }

    /// Ingest one round of non-straggler deltas: `per_client[c][g]` is the
    /// per-neuron relative-update vector of group `g` from client `c`
    /// (produced by the L1 `neuron_delta` kernel via `delta_step`).
    pub fn observe(&mut self, per_client: &[Vec<Tensor>]) {
        if per_client.is_empty() {
            return;
        }
        let clients = per_client.len();
        let groups = self.score.len();
        // mean score per neuron
        for g in 0..groups {
            let n = self.score[g].len();
            for i in 0..n {
                let mut acc = 0.0f64;
                for c in per_client {
                    acc += c[g].data()[i] as f64;
                }
                self.score[g][i] = (acc / clients as f64) as f32;
            }
        }
        // first observation initializes th per group: mean over clients of
        // each client's minimum per-neuron update (paper §5)
        if let Some(th) = self.cfg.th_override {
            for t in &mut self.th {
                *t = th;
            }
        } else if self.observations == 0 {
            for g in 0..groups {
                let per_client_vecs: Vec<Vec<f32>> = per_client
                    .iter()
                    .map(|c| c[g].data().to_vec())
                    .collect();
                let init = threshold::initial_threshold(&per_client_vecs);
                // strictly positive so the very first vote can pass
                self.th[g] = if init > 0.0 { init * 1.5 } else { 1e-6 };
            }
        }
        // majority vote + streak update
        let quorum = ((clients as f64) * self.cfg.majority).ceil().max(1.0) as usize;
        for g in 0..groups {
            let n = self.score[g].len();
            for i in 0..n {
                let votes = per_client
                    .iter()
                    .filter(|c| c[g].data()[i] < self.th[g])
                    .count();
                if votes >= quorum {
                    self.streak[g][i] = self.streak[g][i].saturating_add(1);
                } else {
                    self.streak[g][i] = 0;
                }
            }
        }
        self.observations += 1;
    }

    /// Extract a sub-model keeping fraction `r` per group. Neurons are
    /// dropped in priority order:
    ///   1. persistent invariant neurons (streak >= persistence), lowest
    ///      mean update first;
    ///   2. currently-below-threshold neurons (after calibrating `th`
    ///      upward until enough candidates exist — Algorithm 1 line 22);
    ///   3. lowest mean-update neurons regardless (threshold calibration
    ///      degenerate case: everything still moving).
    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        if !self.ready() {
            return MaskSet::full(spec);
        }
        let mut keep = Vec::with_capacity(spec.masks.len());
        for (g, m) in spec.masks.iter().enumerate() {
            let n = m.size;
            let n_keep = kept_count(n, r);
            let n_drop = n - n_keep;
            if n_drop == 0 {
                keep.push(vec![true; n]);
                continue;
            }
            // calibrate th until the invariant set is large enough
            // (skipped when the threshold is frozen for a controlled sweep)
            if self.cfg.th_override.is_none() {
                self.th[g] = threshold::calibrate(
                    &self.score[g],
                    self.th[g],
                    n_drop,
                    self.cfg.step,
                    self.cfg.max_iters,
                );
            }

            // order all neurons by (priority class, score)
            let mut order: Vec<usize> = (0..n).collect();
            let class = |i: usize| -> u8 {
                if self.streak[g][i] >= self.cfg.persistence
                    && self.score[g][i] < self.th[g]
                {
                    0
                } else if self.score[g][i] < self.th[g] {
                    1
                } else {
                    2
                }
            };
            if self.cfg.th_override.is_some() {
                // frozen-threshold mode (Table 3 protocol): the server
                // only has the binary invariant vote. Below-threshold
                // neurons drop first; if the threshold is too low to
                // cover the drop budget, the deficit comes from
                // *arbitrary* still-moving neurons — exactly why the
                // paper's accuracy peaks when #invariant ≈ #dropped.
                order.sort_by_key(|&i| (class(i).min(1), i));
            } else {
                order.sort_by(|&a, &b| {
                    class(a)
                        .cmp(&class(b))
                        .then(self.score[g][a].partial_cmp(&self.score[g][b]).unwrap())
                });
            }
            let mut k = vec![true; n];
            for &i in order.iter().take(n_drop) {
                k[i] = false;
            }
            keep.push(k);
        }
        MaskSet::from_keep(spec, &keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    /// deltas where group-0 neurons 0..5 barely move and 5..10 move a lot,
    /// group-1 neuron 0 barely moves.
    fn fake_deltas(clients: usize) -> Vec<Vec<Tensor>> {
        (0..clients)
            .map(|c| {
                let jitter = c as f32 * 1e-4;
                let g0: Vec<f32> = (0..10)
                    .map(|i| if i < 5 { 0.001 + jitter } else { 0.5 + jitter })
                    .collect();
                let g1: Vec<f32> = (0..6)
                    .map(|i| if i == 0 { 0.002 } else { 0.4 })
                    .collect();
                vec![Tensor::from_vec(&[10], g0), Tensor::from_vec(&[6], g1)]
            })
            .collect()
    }

    #[test]
    fn not_ready_returns_full() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(!p.ready());
        assert!(p.make_mask(&spec, 0.5).is_full());
    }

    #[test]
    fn drops_low_update_neurons_first() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        for _ in 0..3 {
            p.observe(&fake_deltas(4));
        }
        let m = p.make_mask(&spec, 0.5);
        // group 0: drop 5 -> exactly the invariant neurons 0..5
        for i in 0..5 {
            assert!(!m.is_kept(0, i), "neuron {i} should be dropped");
        }
        for i in 5..10 {
            assert!(m.is_kept(0, i), "neuron {i} should be kept");
        }
        // group 1: drop 3, neuron 0 must be among them
        assert!(!m.is_kept(1, 0));
        assert_eq!(m.kept(1), 3);
    }

    #[test]
    fn exact_drop_counts_per_group() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        for &r in &[0.95, 0.85, 0.75, 0.65, 0.5] {
            let m = p.make_mask(&spec, r);
            assert_eq!(m.kept(0), kept_count(10, r), "r={r}");
            assert_eq!(m.kept(1), kept_count(6, r), "r={r}");
        }
    }

    #[test]
    fn threshold_initialized_from_client_minima() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        // min update in group 0 is ~0.001; init = 1.5x mean-of-minima
        assert!(p.thresholds()[0] > 0.001 && p.thresholds()[0] < 0.01);
    }

    #[test]
    fn streaks_reset_when_neurons_start_moving() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        p.observe(&fake_deltas(4));
        assert!(p.streak[0][0] >= 2);
        // now neuron 0 starts moving hard
        let mut moved = fake_deltas(4);
        for c in &mut moved {
            c[0].data_mut()[0] = 0.9;
        }
        p.observe(&moved);
        assert_eq!(p.streak[0][0], 0);
    }

    #[test]
    fn export_import_state_round_trips_and_validates() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        for _ in 0..3 {
            p.observe(&fake_deltas(4));
        }
        let (th, streak, score, obs) = p.export_state();
        let mut q = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(!q.ready());
        q.import_state(th.clone(), streak.clone(), score.clone(), obs).unwrap();
        assert!(q.ready());
        assert_eq!(q.thresholds(), p.thresholds());
        // restored policy extracts the identical mask
        assert_eq!(q.make_mask(&spec, 0.5), p.make_mask(&spec, 0.5));
        // mismatched shapes are rejected, not silently adopted
        let mut r = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(r.import_state(vec![0.0], streak.clone(), score.clone(), obs).is_err());
        let mut bad_streak = streak.clone();
        bad_streak[0].pop();
        assert!(r.import_state(th, bad_streak, score, obs).is_err());
    }

    #[test]
    fn invariant_fraction_grows_with_threshold() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        let lo = p.invariant_fraction_at(0.002);
        let hi = p.invariant_fraction_at(1.0);
        assert!(lo < hi);
        assert!((hi - 1.0).abs() < 1e-9);
    }

    #[test]
    fn calibration_raises_threshold_for_aggressive_r() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        let th_before = p.thresholds()[0];
        // r=0.3 needs 7 drops in group 0 but only 5 neurons are invariant:
        // calibration must raise th
        let m = p.make_mask(&spec, 0.3);
        assert_eq!(m.kept(0), 3);
        assert!(p.thresholds()[0] > th_before);
    }
}
