//! Invariant Dropout — the paper's contribution (§4, §5, Algorithm 1).
//!
//! The server watches the per-neuron relative weight updates of the
//! **non-straggler** clients (stragglers only train sub-models, so their
//! updates cannot vote). A neuron is *invariant* when its update falls
//! below the drop-threshold `th` for the majority of non-stragglers, for
//! `persistence` consecutive calibration steps ("targets neurons for
//! dropping whose gradients consistently fall below the threshold over
//! multiple epochs"). Sub-model extraction drops the lowest-update
//! invariant neurons first, calibrating `th` upward until the invariant
//! set covers the number of neurons that must leave the sub-model.

use super::mask::{kept_count, MaskSet};
use super::threshold;
use crate::fl::parallel::{for_each_chunk2_mut, tree_reduce, AggScratch, CHUNK};
use crate::model::ModelSpec;
use crate::tensor::Tensor;

/// Tunables for the invariant policy.
#[derive(Clone, Copy, Debug)]
pub struct InvariantConfig {
    /// multiplicative threshold increment per calibration step
    pub step: f32,
    /// consecutive below-threshold calibrations before a neuron is a
    /// first-class drop candidate
    pub persistence: u32,
    /// fraction of non-stragglers that must agree a neuron is invariant
    pub majority: f64,
    /// max calibration iterations per extraction
    pub max_iters: usize,
    /// freeze all group thresholds at this value (Table 3's controlled
    /// sweep); None = calibrate automatically (Algorithm 1)
    pub th_override: Option<f32>,
}

impl Default for InvariantConfig {
    fn default() -> Self {
        Self {
            step: 1.25,
            persistence: 2,
            majority: 0.5,
            max_iters: 200,
            th_override: None,
        }
    }
}

/// Invariant Dropout state held by the FLuID server.
pub struct InvariantDropout {
    pub cfg: InvariantConfig,
    /// per-group drop threshold (per-layer thresholds, paper §5)
    th: Vec<f32>,
    /// per-group per-neuron consecutive below-threshold count
    streak: Vec<Vec<u32>>,
    /// per-group per-neuron mean relative update over the last observation
    score: Vec<Vec<f32>>,
    observations: usize,
}

impl InvariantDropout {
    pub fn new(spec: &ModelSpec, cfg: InvariantConfig) -> Self {
        Self {
            cfg,
            th: vec![0.0; spec.masks.len()],
            streak: spec.masks.iter().map(|m| vec![0; m.size]).collect(),
            score: spec.masks.iter().map(|m| vec![0.0; m.size]).collect(),
            observations: 0,
        }
    }

    /// Has the policy seen any non-straggler updates yet? Until then,
    /// stragglers receive the full model (Algorithm 1's initialization
    /// epochs).
    pub fn ready(&self) -> bool {
        self.observations > 0
    }

    pub fn thresholds(&self) -> &[f32] {
        &self.th
    }

    /// Mean per-neuron update score for group `g` (Fig 6 / Table 3).
    pub fn scores(&self, g: usize) -> &[f32] {
        &self.score[g]
    }

    /// Fraction of all neurons currently below the (per-group) threshold —
    /// the "percentage of invariant neurons" metric of Fig 6 and Table 3.
    pub fn invariant_fraction(&self) -> f64 {
        let mut below = 0usize;
        let mut total = 0usize;
        for (g, sc) in self.score.iter().enumerate() {
            below += threshold::count_below(sc, self.th[g]);
            total += sc.len();
        }
        if total == 0 {
            0.0
        } else {
            below as f64 / total as f64
        }
    }

    /// Same metric at an explicit global threshold (Table 3 sweeps).
    pub fn invariant_fraction_at(&self, th: f32) -> f64 {
        let mut below = 0usize;
        let mut total = 0usize;
        for sc in &self.score {
            below += threshold::count_below(sc, th);
            total += sc.len();
        }
        if total == 0 {
            0.0
        } else {
            below as f64 / total as f64
        }
    }

    /// Override per-group thresholds (Table 3's controlled sweep).
    pub fn set_thresholds(&mut self, th: f32) {
        for t in &mut self.th {
            *t = th;
        }
    }

    /// Raw resumable state `(th, streak, score, observations)` — the
    /// evolving part of the policy that a checkpoint must capture (the
    /// config is reconstructed from the experiment seed).
    #[allow(clippy::type_complexity)]
    pub fn export_state(&self) -> (Vec<f32>, Vec<Vec<u32>>, Vec<Vec<f32>>, usize) {
        (
            self.th.clone(),
            self.streak.clone(),
            self.score.clone(),
            self.observations,
        )
    }

    /// Restore state captured by [`InvariantDropout::export_state`].
    /// Group shapes must match the spec this policy was built against.
    pub fn import_state(
        &mut self,
        th: Vec<f32>,
        streak: Vec<Vec<u32>>,
        score: Vec<Vec<f32>>,
        observations: usize,
    ) -> crate::Result<()> {
        anyhow::ensure!(
            th.len() == self.th.len()
                && streak.len() == self.streak.len()
                && score.len() == self.score.len(),
            "snapshot has {}/{}/{} policy groups, model has {}",
            th.len(),
            streak.len(),
            score.len(),
            self.th.len()
        );
        for g in 0..streak.len() {
            anyhow::ensure!(
                streak[g].len() == self.streak[g].len()
                    && score[g].len() == self.score[g].len(),
                "policy group {g}: snapshot sizes {}/{} vs model {}",
                streak[g].len(),
                score[g].len(),
                self.streak[g].len()
            );
        }
        self.th = th;
        self.streak = streak;
        self.score = score;
        self.observations = observations;
        Ok(())
    }

    /// Ingest one round of non-straggler deltas: `per_client[c][g]` is the
    /// per-neuron relative-update vector of group `g` from client `c`
    /// (produced by the L1 `neuron_delta` kernel via `delta_step`).
    ///
    /// Serial convenience entry: a one-line delegation to
    /// [`InvariantDropout::observe_with`] with a throwaway scratch arena
    /// and one thread — bit-identical, just slower; the engine calls the
    /// pooled variant.
    pub fn observe(&mut self, per_client: &[Vec<Tensor>]) {
        self.observe_with(per_client, 1, &mut AggScratch::new());
    }

    /// The observation hot path (DESIGN.md §7): the historical three
    /// sweeps over the delta buffers — mean score, threshold
    /// initialization, majority vote + streak — are fused into a single
    /// cache-friendly pass with the per-client slices hoisted out of the
    /// element loop, accumulating per-neuron sums and below-threshold
    /// votes together in one arena-backed sweep. Only the very first
    /// uncalibrated observation takes a second pass (its votes need the
    /// threshold that pass initializes). Chunked over neurons at fixed
    /// boundaries, so results are bit-identical for every thread count;
    /// per-neuron sums add clients in the same order as the historical
    /// scan.
    pub fn observe_with(
        &mut self,
        per_client: &[Vec<Tensor>],
        threads: usize,
        scratch: &mut AggScratch,
    ) {
        if per_client.is_empty() {
            return;
        }
        let clients = per_client.len();
        let groups = self.score.len();
        let quorum = ((clients as f64) * self.cfg.majority).ceil().max(1.0) as usize;
        let first_uncalibrated = self.observations == 0 && self.cfg.th_override.is_none();
        if let Some(th) = self.cfg.th_override {
            for t in &mut self.th {
                *t = th;
            }
        }
        for g in 0..groups {
            let n = self.score[g].len();
            if n == 0 {
                if first_uncalibrated {
                    self.th[g] = 1e-6;
                }
                continue;
            }
            // first observation initializes th per group: mean over
            // clients of each client's minimum per-neuron update (paper
            // §5), reduced over fixed chunks in tree order
            if first_uncalibrated {
                let minima = tree_reduce(
                    n,
                    CHUNK,
                    threads,
                    |s, e| {
                        let mut m = vec![f32::INFINITY; clients];
                        for (mc, c) in m.iter_mut().zip(per_client) {
                            for &x in &c[g].data()[s..e] {
                                if x < *mc {
                                    *mc = x;
                                }
                            }
                        }
                        m
                    },
                    |mut a, b| {
                        for (x, &y) in a.iter_mut().zip(&b) {
                            if y < *x {
                                *x = y;
                            }
                        }
                        a
                    },
                )
                .unwrap_or_default();
                let init = threshold::initial_from_minima(&minima);
                // strictly positive so the very first vote can pass
                self.th[g] = if init > 0.0 { init * 1.5 } else { 1e-6 };
            }
            let th_g = self.th[g];
            // fused sweep: per-neuron score sums and below-threshold vote
            // counts from one pass over each client's delta buffer
            let AggScratch { acc, votes, .. } = &mut *scratch;
            acc.clear();
            acc.resize(n, 0.0);
            votes.clear();
            votes.resize(n, 0);
            for_each_chunk2_mut(
                acc.as_mut_slice(),
                votes.as_mut_slice(),
                CHUNK,
                threads,
                |start, a, v| {
                    for c in per_client {
                        let d = &c[g].data()[start..start + a.len()];
                        for ((aj, vj), &x) in a.iter_mut().zip(v.iter_mut()).zip(d) {
                            *aj += x as f64;
                            if x < th_g {
                                *vj += 1;
                            }
                        }
                    }
                },
            );
            // finalize score + streak in one aligned sweep
            let acc_s: &[f64] = &acc[..];
            let votes_s: &[u32] = &votes[..];
            let denom = clients as f64;
            let (score_g, streak_g) = (&mut self.score[g], &mut self.streak[g]);
            for_each_chunk2_mut(
                score_g.as_mut_slice(),
                streak_g.as_mut_slice(),
                CHUNK,
                threads,
                |start, sc, st| {
                    for (k, (s, t)) in sc.iter_mut().zip(st.iter_mut()).enumerate() {
                        let i = start + k;
                        *s = (acc_s[i] / denom) as f32;
                        *t = if (votes_s[i] as usize) >= quorum {
                            (*t).saturating_add(1)
                        } else {
                            0
                        };
                    }
                },
            );
        }
        self.observations += 1;
    }

    /// Extract a sub-model keeping fraction `r` per group. Neurons are
    /// dropped in priority order:
    ///   1. persistent invariant neurons (streak >= persistence), lowest
    ///      mean update first;
    ///   2. currently-below-threshold neurons (after calibrating `th`
    ///      upward until enough candidates exist — Algorithm 1 line 22);
    ///   3. lowest mean-update neurons regardless (threshold calibration
    ///      degenerate case: everything still moving).
    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        if !self.ready() {
            return MaskSet::full(spec);
        }
        let mut keep = Vec::with_capacity(spec.masks.len());
        for (g, m) in spec.masks.iter().enumerate() {
            let n = m.size;
            let n_keep = kept_count(n, r);
            let n_drop = n - n_keep;
            if n_drop == 0 {
                keep.push(vec![true; n]);
                continue;
            }
            // calibrate th until the invariant set is large enough
            // (skipped when the threshold is frozen for a controlled sweep)
            if self.cfg.th_override.is_none() {
                self.th[g] = threshold::calibrate(
                    &self.score[g],
                    self.th[g],
                    n_drop,
                    self.cfg.step,
                    self.cfg.max_iters,
                );
            }

            // order all neurons by (priority class, score)
            let mut order: Vec<usize> = (0..n).collect();
            let class = |i: usize| -> u8 {
                if self.streak[g][i] >= self.cfg.persistence
                    && self.score[g][i] < self.th[g]
                {
                    0
                } else if self.score[g][i] < self.th[g] {
                    1
                } else {
                    2
                }
            };
            if self.cfg.th_override.is_some() {
                // frozen-threshold mode (Table 3 protocol): the server
                // only has the binary invariant vote. Below-threshold
                // neurons drop first; if the threshold is too low to
                // cover the drop budget, the deficit comes from
                // *arbitrary* still-moving neurons — exactly why the
                // paper's accuracy peaks when #invariant ≈ #dropped.
                order.sort_by_key(|&i| (class(i).min(1), i));
            } else {
                // total_cmp, not partial_cmp().unwrap(): a NaN score (a
                // poisoned delta kernel output) must never panic
                // mid-round — it sorts after every finite score, i.e. it
                // is dropped last, like any other "still moving" neuron.
                order.sort_by(|&a, &b| {
                    class(a)
                        .cmp(&class(b))
                        .then(self.score[g][a].total_cmp(&self.score[g][b]))
                });
            }
            let mut k = vec![true; n];
            for &i in order.iter().take(n_drop) {
                k[i] = false;
            }
            keep.push(k);
        }
        MaskSet::from_keep(spec, &keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    /// deltas where group-0 neurons 0..5 barely move and 5..10 move a lot,
    /// group-1 neuron 0 barely moves.
    fn fake_deltas(clients: usize) -> Vec<Vec<Tensor>> {
        (0..clients)
            .map(|c| {
                let jitter = c as f32 * 1e-4;
                let g0: Vec<f32> = (0..10)
                    .map(|i| if i < 5 { 0.001 + jitter } else { 0.5 + jitter })
                    .collect();
                let g1: Vec<f32> = (0..6)
                    .map(|i| if i == 0 { 0.002 } else { 0.4 })
                    .collect();
                vec![Tensor::from_vec(&[10], g0), Tensor::from_vec(&[6], g1)]
            })
            .collect()
    }

    #[test]
    fn not_ready_returns_full() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(!p.ready());
        assert!(p.make_mask(&spec, 0.5).is_full());
    }

    #[test]
    fn drops_low_update_neurons_first() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        for _ in 0..3 {
            p.observe(&fake_deltas(4));
        }
        let m = p.make_mask(&spec, 0.5);
        // group 0: drop 5 -> exactly the invariant neurons 0..5
        for i in 0..5 {
            assert!(!m.is_kept(0, i), "neuron {i} should be dropped");
        }
        for i in 5..10 {
            assert!(m.is_kept(0, i), "neuron {i} should be kept");
        }
        // group 1: drop 3, neuron 0 must be among them
        assert!(!m.is_kept(1, 0));
        assert_eq!(m.kept(1), 3);
    }

    #[test]
    fn exact_drop_counts_per_group() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        for &r in &[0.95, 0.85, 0.75, 0.65, 0.5] {
            let m = p.make_mask(&spec, r);
            assert_eq!(m.kept(0), kept_count(10, r), "r={r}");
            assert_eq!(m.kept(1), kept_count(6, r), "r={r}");
        }
    }

    #[test]
    fn threshold_initialized_from_client_minima() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        // min update in group 0 is ~0.001; init = 1.5x mean-of-minima
        assert!(p.thresholds()[0] > 0.001 && p.thresholds()[0] < 0.01);
    }

    #[test]
    fn streaks_reset_when_neurons_start_moving() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        p.observe(&fake_deltas(4));
        assert!(p.streak[0][0] >= 2);
        // now neuron 0 starts moving hard
        let mut moved = fake_deltas(4);
        for c in &mut moved {
            c[0].data_mut()[0] = 0.9;
        }
        p.observe(&moved);
        assert_eq!(p.streak[0][0], 0);
    }

    #[test]
    fn export_import_state_round_trips_and_validates() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        for _ in 0..3 {
            p.observe(&fake_deltas(4));
        }
        let (th, streak, score, obs) = p.export_state();
        let mut q = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(!q.ready());
        q.import_state(th.clone(), streak.clone(), score.clone(), obs).unwrap();
        assert!(q.ready());
        assert_eq!(q.thresholds(), p.thresholds());
        // restored policy extracts the identical mask
        assert_eq!(q.make_mask(&spec, 0.5), p.make_mask(&spec, 0.5));
        // mismatched shapes are rejected, not silently adopted
        let mut r = InvariantDropout::new(&spec, InvariantConfig::default());
        assert!(r.import_state(vec![0.0], streak.clone(), score.clone(), obs).is_err());
        let mut bad_streak = streak.clone();
        bad_streak[0].pop();
        assert!(r.import_state(th, bad_streak, score, obs).is_err());
    }

    #[test]
    fn invariant_fraction_grows_with_threshold() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        let lo = p.invariant_fraction_at(0.002);
        let hi = p.invariant_fraction_at(1.0);
        assert!(lo < hi);
        assert!((hi - 1.0).abs() < 1e-9);
    }

    /// The historical three-pass observe (mean score, threshold init,
    /// majority vote + streak), kept verbatim as the reference the fused
    /// single-pass sweep is pinned against.
    struct RefObserver {
        th: Vec<f32>,
        streak: Vec<Vec<u32>>,
        score: Vec<Vec<f32>>,
        observations: usize,
        cfg: InvariantConfig,
    }

    impl RefObserver {
        fn new(spec: &ModelSpec, cfg: InvariantConfig) -> Self {
            Self {
                th: vec![0.0; spec.masks.len()],
                streak: spec.masks.iter().map(|m| vec![0; m.size]).collect(),
                score: spec.masks.iter().map(|m| vec![0.0; m.size]).collect(),
                observations: 0,
                cfg,
            }
        }

        fn observe(&mut self, per_client: &[Vec<Tensor>]) {
            if per_client.is_empty() {
                return;
            }
            let clients = per_client.len();
            let groups = self.score.len();
            for g in 0..groups {
                for i in 0..self.score[g].len() {
                    let mut acc = 0.0f64;
                    for c in per_client {
                        acc += c[g].data()[i] as f64;
                    }
                    self.score[g][i] = (acc / clients as f64) as f32;
                }
            }
            if let Some(th) = self.cfg.th_override {
                for t in &mut self.th {
                    *t = th;
                }
            } else if self.observations == 0 {
                for g in 0..groups {
                    let per_client_vecs: Vec<Vec<f32>> =
                        per_client.iter().map(|c| c[g].data().to_vec()).collect();
                    let init = threshold::initial_threshold(&per_client_vecs);
                    self.th[g] = if init > 0.0 { init * 1.5 } else { 1e-6 };
                }
            }
            let quorum = ((clients as f64) * self.cfg.majority).ceil().max(1.0) as usize;
            for g in 0..groups {
                for i in 0..self.score[g].len() {
                    let votes = per_client
                        .iter()
                        .filter(|c| c[g].data()[i] < self.th[g])
                        .count();
                    if votes >= quorum {
                        self.streak[g][i] = self.streak[g][i].saturating_add(1);
                    } else {
                        self.streak[g][i] = 0;
                    }
                }
            }
            self.observations += 1;
        }
    }

    fn bits32(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn fused_observe_is_bit_identical_to_reference_at_every_thread_count() {
        use crate::fl::parallel::AggScratch;
        use crate::util::prng::Pcg32;
        let spec = tiny_spec();
        for th_override in [None, Some(0.05f32)] {
            let cfg = InvariantConfig { th_override, ..Default::default() };
            let mut reference = RefObserver::new(&spec, cfg);
            let mut fused: Vec<InvariantDropout> = [1usize, 2, 4, 8]
                .iter()
                .map(|_| InvariantDropout::new(&spec, cfg))
                .collect();
            let mut scratch = AggScratch::new();
            let mut rng = Pcg32::new(99, 1);
            for _round in 0..4 {
                let deltas: Vec<Vec<Tensor>> = (0..5)
                    .map(|_| {
                        spec.masks
                            .iter()
                            .map(|m| {
                                Tensor::from_vec(
                                    &[m.size],
                                    (0..m.size).map(|_| rng.next_f32() * 0.3).collect(),
                                )
                            })
                            .collect()
                    })
                    .collect();
                reference.observe(&deltas);
                for (k, threads) in [1usize, 2, 4, 8].iter().enumerate() {
                    fused[k].observe_with(&deltas, *threads, &mut scratch);
                    let (th, streak, score, obs) = fused[k].export_state();
                    assert_eq!(bits32(&th), bits32(&reference.th), "th, threads={threads}");
                    assert_eq!(streak, reference.streak, "streak, threads={threads}");
                    for g in 0..score.len() {
                        assert_eq!(
                            bits32(&score[g]),
                            bits32(&reference.score[g]),
                            "score group {g}, threads={threads}"
                        );
                    }
                    assert_eq!(obs, reference.observations);
                }
            }
        }
    }

    /// Same pin, but with a group large enough to split across several
    /// parallel chunks (6000 neurons > CHUNK), so the multi-chunk sweep
    /// and the chunked minima tree-reduction are exercised for real.
    #[test]
    fn fused_observe_parallel_chunks_match_reference() {
        use crate::fl::parallel::AggScratch;
        use crate::util::prng::Pcg32;
        let manifest = r#"{
 "model": "wide", "batch_size": 4,
 "x_shape": [4, 8], "x_dtype": "f32", "num_classes": 3,
 "params": [
   {"name": "fc1_w", "shape": [2, 6000]}, {"name": "fc1_b", "shape": [6000]},
   {"name": "out_w", "shape": [4, 3]}, {"name": "out_b", "shape": [3]}
 ],
 "masks": [{"name": "fc1", "size": 6000}],
 "delta_groups": ["fc1"],
 "delta_inputs": ["fc1_w"],
 "artifacts": {"train": "t", "eval": "e", "delta": "d"},
 "train_outputs": []
}"#;
        let spec = ModelSpec::from_json_str(manifest, std::path::Path::new("/tmp")).unwrap();
        let cfg = InvariantConfig::default();
        let mut reference = RefObserver::new(&spec, cfg);
        let mut fused = InvariantDropout::new(&spec, cfg);
        let mut scratch = AggScratch::new();
        let mut rng = Pcg32::new(31, 7);
        for _round in 0..2 {
            let deltas: Vec<Vec<Tensor>> = (0..4)
                .map(|_| {
                    vec![Tensor::from_vec(
                        &[6000],
                        (0..6000).map(|_| rng.next_f32() * 0.25).collect(),
                    )]
                })
                .collect();
            reference.observe(&deltas);
            fused.observe_with(&deltas, 8, &mut scratch);
            let (th, streak, score, _) = fused.export_state();
            assert_eq!(bits32(&th), bits32(&reference.th));
            assert_eq!(streak, reference.streak);
            assert_eq!(bits32(&score[0]), bits32(&reference.score[0]));
        }
    }

    #[test]
    fn nan_scores_never_panic_make_mask() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        let mut deltas = fake_deltas(4);
        // one neuron's delta comes back NaN from every client
        for c in &mut deltas {
            c[0].data_mut()[3] = f32::NAN;
        }
        p.observe(&deltas);
        p.observe(&deltas);
        for &r in &[0.75, 0.5, 0.3] {
            let m = p.make_mask(&spec, r); // must not panic on the NaN sort key
            assert_eq!(m.kept(0), kept_count(10, r), "r={r}");
            assert_eq!(m.kept(1), kept_count(6, r), "r={r}");
        }
        // NaN sorts after every finite score, so it is dropped last: at
        // r=0.5 the five finite low-update neurons go first
        let m = p.make_mask(&spec, 0.5);
        assert!(m.is_kept(0, 3), "NaN-scored neuron dropped before finite ones");
    }

    #[test]
    fn calibration_raises_threshold_for_aggressive_r() {
        let spec = tiny_spec();
        let mut p = InvariantDropout::new(&spec, InvariantConfig::default());
        p.observe(&fake_deltas(4));
        let th_before = p.thresholds()[0];
        // r=0.3 needs 7 drops in group 0 but only 5 neurons are invariant:
        // calibration must raise th
        let m = p.make_mask(&spec, 0.3);
        assert_eq!(m.kept(0), 3);
        assert!(p.thresholds()[0] > th_before);
    }
}
