//! Dropout policies — the paper's contribution and its baselines.
//!
//! A *sub-model* is a per-group neuron mask (`MaskSet`): 1.0 keeps a
//! neuron, 0.0 drops it. Masks feed the AOT train step, where masking is
//! numerically identical to physical sub-model extraction (DESIGN.md §1).
//!
//! * [`invariant::InvariantDropout`] — the paper: drop neurons whose
//!   weights changed less than a calibrated threshold for the majority of
//!   non-straggler clients (§4, §5, Algorithm 1).
//! * [`ordered::OrderedDropout`] — FjORD baseline: keep a fixed prefix.
//! * [`random::RandomDropout`] — Federated Dropout baseline: random set
//!   each round.
//! * `NoDropout` — vanilla FL (stragglers train the full model).

pub mod invariant;
pub mod mask;
pub mod ordered;
pub mod random;
pub mod threshold;

pub use invariant::{InvariantConfig, InvariantDropout};
pub use mask::MaskSet;
pub use ordered::OrderedDropout;
pub use random::RandomDropout;

use crate::model::ModelSpec;

/// Which dropout technique an experiment runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// vanilla synchronous FL — no sub-models
    None,
    /// Federated Dropout [CKMT18]
    Random,
    /// Ordered Dropout / FjORD [HLA+21]
    Ordered,
    /// Invariant Dropout (this paper)
    Invariant,
    /// drop straggler *updates* entirely [KMA+19] — masks stay full, the
    /// coordinator skips aggregation of straggler deltas
    Exclude,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "none" | "baseline" => PolicyKind::None,
            "random" => PolicyKind::Random,
            "ordered" => PolicyKind::Ordered,
            "invariant" | "fluid" => PolicyKind::Invariant,
            "exclude" => PolicyKind::Exclude,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::None => "none",
            PolicyKind::Random => "random",
            PolicyKind::Ordered => "ordered",
            PolicyKind::Invariant => "invariant",
            PolicyKind::Exclude => "exclude",
        }
    }
}

/// Unified policy object used by the coordinator.
pub enum Policy {
    None,
    Random(RandomDropout),
    Ordered(OrderedDropout),
    Invariant(InvariantDropout),
    Exclude,
}

impl Policy {
    pub fn new(kind: PolicyKind, spec: &ModelSpec, seed: u64) -> Policy {
        Self::new_with(kind, spec, seed, InvariantConfig::default())
    }

    /// Like [`Policy::new`] but with explicit invariant tunables (used by
    /// the Table-3 threshold sweep and ablation benches).
    pub fn new_with(
        kind: PolicyKind,
        spec: &ModelSpec,
        seed: u64,
        inv: InvariantConfig,
    ) -> Policy {
        match kind {
            PolicyKind::None => Policy::None,
            PolicyKind::Exclude => Policy::Exclude,
            PolicyKind::Random => Policy::Random(RandomDropout::new(seed)),
            PolicyKind::Ordered => Policy::Ordered(OrderedDropout::new()),
            PolicyKind::Invariant => Policy::Invariant(InvariantDropout::new(spec, inv)),
        }
    }

    pub fn kind(&self) -> PolicyKind {
        match self {
            Policy::None => PolicyKind::None,
            Policy::Random(_) => PolicyKind::Random,
            Policy::Ordered(_) => PolicyKind::Ordered,
            Policy::Invariant(_) => PolicyKind::Invariant,
            Policy::Exclude => PolicyKind::Exclude,
        }
    }

    /// Produce the sub-model mask for one straggler at keep-rate `r`.
    /// `None`/`Exclude` always return the full mask.
    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        match self {
            Policy::None | Policy::Exclude => MaskSet::full(spec),
            Policy::Random(p) => p.make_mask(spec, r),
            Policy::Ordered(p) => p.make_mask(spec, r),
            Policy::Invariant(p) => p.make_mask(spec, r),
        }
    }

    /// Feed non-straggler per-neuron deltas (per client, per group) after
    /// a round — only Invariant uses these.
    pub fn observe_deltas(&mut self, per_client: &[Vec<crate::tensor::Tensor>]) {
        if let Policy::Invariant(p) = self {
            p.observe(per_client);
        }
    }

    /// Fraction of neurons currently held invariant — 0.0 for every
    /// policy except invariant dropout (reported per round).
    pub fn invariant_fraction(&self) -> f64 {
        match self {
            Policy::Invariant(p) => p.invariant_fraction(),
            _ => 0.0,
        }
    }

    /// [`Policy::observe_deltas`] through the pooled hot path: the round
    /// engine passes its scratch arena and thread budget so the fused
    /// observation sweep allocates nothing and parallelizes over neuron
    /// chunks. Bit-identical to the plain variant at any thread count.
    pub fn observe_deltas_with(
        &mut self,
        per_client: &[Vec<crate::tensor::Tensor>],
        threads: usize,
        scratch: &mut crate::fl::AggScratch,
    ) {
        if let Policy::Invariant(p) = self {
            p.observe_with(per_client, threads, scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(PolicyKind::parse("invariant"), Some(PolicyKind::Invariant));
        assert_eq!(PolicyKind::parse("FLUID"), Some(PolicyKind::Invariant));
        assert_eq!(PolicyKind::parse("ordered"), Some(PolicyKind::Ordered));
        assert_eq!(PolicyKind::parse("random"), Some(PolicyKind::Random));
        assert_eq!(PolicyKind::parse("none"), Some(PolicyKind::None));
        assert_eq!(PolicyKind::parse("exclude"), Some(PolicyKind::Exclude));
        assert_eq!(PolicyKind::parse("bogus"), None);
    }
}
