//! Ordered Dropout baseline (FjORD [HLA+21]): sub-models are nested
//! prefixes — at keep-rate `r` the first `ceil(r·n)` neurons of every
//! group are kept, so a smaller sub-model is always contained in a
//! larger one.

use super::mask::{kept_count, MaskSet};
use crate::model::ModelSpec;

#[derive(Default)]
pub struct OrderedDropout;

impl OrderedDropout {
    pub fn new() -> Self {
        Self
    }

    pub fn make_mask(&mut self, spec: &ModelSpec, r: f64) -> MaskSet {
        let keep: Vec<Vec<bool>> = spec
            .masks
            .iter()
            .map(|m| {
                let k = kept_count(m.size, r);
                (0..m.size).map(|i| i < k).collect()
            })
            .collect();
        MaskSet::from_keep(spec, &keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::mask::tests::tiny_spec;

    #[test]
    fn keeps_prefix() {
        let spec = tiny_spec();
        let mut p = OrderedDropout::new();
        let m = p.make_mask(&spec, 0.5);
        for i in 0..5 {
            assert!(m.is_kept(0, i));
        }
        for i in 5..10 {
            assert!(!m.is_kept(0, i));
        }
    }

    #[test]
    fn sub_models_are_nested() {
        let spec = tiny_spec();
        let mut p = OrderedDropout::new();
        let small = p.make_mask(&spec, 0.5);
        let large = p.make_mask(&spec, 0.75);
        for g in 0..small.num_groups() {
            for i in 0..spec.masks[g].size {
                if small.is_kept(g, i) {
                    assert!(large.is_kept(g, i), "nesting violated at {g}/{i}");
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let spec = tiny_spec();
        let mut p = OrderedDropout::new();
        assert_eq!(p.make_mask(&spec, 0.65), p.make_mask(&spec, 0.65));
    }
}
