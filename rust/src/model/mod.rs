//! Model specification — the rust mirror of the AOT manifest.
//!
//! `ModelSpec` is parsed from `artifacts/<model>_manifest.json` (written
//! by python/compile/aot.py) and is the *ordering contract* between the
//! coordinator and the compiled step functions: parameter order, mask
//! order, delta-group order and the train-output layout all come from
//! here and must never be reordered independently.

use crate::jsonlite;
use crate::tensor::{init, Tensor};
use crate::util::prng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// One named parameter tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One maskable neuron group ("neurons" in the paper's sense: CONV
/// filters, FC activations, LSTM hidden units).
#[derive(Clone, Debug, PartialEq)]
pub struct MaskSpec {
    pub name: String,
    pub size: usize,
}

/// Parsed manifest for one model.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub batch_size: usize,
    pub x_shape: Vec<usize>,
    pub x_is_int: bool,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub masks: Vec<MaskSpec>,
    /// delta group names, index-aligned with `masks`
    pub delta_groups: Vec<String>,
    /// weight param name feeding each delta group (index-aligned with
    /// `masks`); the delta artifact takes exactly (old..., new...) of these
    pub delta_inputs: Vec<String>,
    /// artifact file names relative to the artifacts dir
    pub train_hlo: String,
    pub eval_hlo: String,
    pub delta_hlo: String,
    /// optional fused k-step train artifact (§Perf L2 optimization)
    pub train_multi_hlo: Option<String>,
    /// the k baked into `train_multi_hlo` (0 when absent)
    pub train_multi_k: usize,
    /// directory the manifest was loaded from
    pub dir: PathBuf,
}

impl ModelSpec {
    /// Load `<dir>/<model>_manifest.json`.
    pub fn load(dir: &Path, model: &str) -> Result<Self> {
        let path = dir.join(format!("{model}_manifest.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::from_json_str(&text, dir)
    }

    pub fn from_json_str(text: &str, dir: &Path) -> Result<Self> {
        let j = jsonlite::parse(text).map_err(|e| anyhow!("{e}"))?;
        let params = j
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not array"))?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str().unwrap_or_default().to_string(),
                    shape: p.req("shape")?.as_shape()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let masks = j
            .req("masks")?
            .as_arr()
            .ok_or_else(|| anyhow!("masks not array"))?
            .iter()
            .map(|m| {
                Ok(MaskSpec {
                    name: m.req("name")?.as_str().unwrap_or_default().to_string(),
                    size: m.req("size")?.as_usize().unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let delta_groups = j
            .req("delta_groups")?
            .as_arr()
            .ok_or_else(|| anyhow!("delta_groups not array"))?
            .iter()
            .map(|g| g.as_str().unwrap_or_default().to_string())
            .collect::<Vec<_>>();
        let delta_inputs = j
            .req("delta_inputs")?
            .as_arr()
            .ok_or_else(|| anyhow!("delta_inputs not array"))?
            .iter()
            .map(|g| g.as_str().unwrap_or_default().to_string())
            .collect::<Vec<_>>();
        let arts = j.req("artifacts")?;
        let get_art = |k: &str| -> Result<String> {
            Ok(arts
                .req(k)?
                .as_str()
                .ok_or_else(|| anyhow!("artifact {k} not a string"))?
                .to_string())
        };

        let spec = Self {
            name: j.req("model")?.as_str().unwrap_or_default().to_string(),
            batch_size: j.req("batch_size")?.as_usize().unwrap_or(0),
            x_shape: j.req("x_shape")?.as_shape()?,
            x_is_int: j.req("x_dtype")?.as_str() == Some("i32"),
            num_classes: j.req("num_classes")?.as_usize().unwrap_or(0),
            params,
            masks,
            delta_groups,
            delta_inputs,
            train_hlo: get_art("train")?,
            eval_hlo: get_art("eval")?,
            delta_hlo: get_art("delta")?,
            train_multi_hlo: arts
                .get("train_multi")
                .and_then(|x| x.as_str())
                .map(str::to_string),
            train_multi_k: j
                .get("train_multi_k")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            dir: dir.to_path_buf(),
        };
        spec.validate()?;
        Ok(spec)
    }

    fn validate(&self) -> Result<()> {
        if self.params.is_empty() {
            return Err(anyhow!("model {} has no params", self.name));
        }
        if self.masks.len() != self.delta_groups.len() {
            return Err(anyhow!(
                "masks ({}) and delta_groups ({}) must align",
                self.masks.len(),
                self.delta_groups.len()
            ));
        }
        for (m, g) in self.masks.iter().zip(&self.delta_groups) {
            if &m.name != g {
                return Err(anyhow!("mask {} vs delta group {g} mismatch", m.name));
            }
        }
        if self.delta_inputs.len() != self.masks.len() {
            return Err(anyhow!("delta_inputs must align with masks"));
        }
        for p in &self.delta_inputs {
            if self.param_index(p).is_none() {
                return Err(anyhow!("delta input {p} not a model param"));
            }
        }
        Ok(())
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }

    /// Total maskable neuron count.
    pub fn num_neurons(&self) -> usize {
        self.masks.iter().map(|m| m.size).sum()
    }

    /// Model size in bytes (f32) — used by the communication model.
    pub fn size_bytes(&self) -> usize {
        self.num_params() * 4
    }

    /// Deterministically initialize all parameters (mirrors python init).
    pub fn init_params(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg32::new(seed, 0x1217);
        self.params
            .iter()
            .map(|p| init::init_param(&mut rng, &p.name, &p.shape))
            .collect()
    }

    pub fn mask_index(&self, name: &str) -> Option<usize> {
        self.masks.iter().position(|m| m.name == name)
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }
}

/// A small synthetic manifest for runtime-free simulation
/// (`coordinator::run_sim` / the fleet-scale determinism suite). The sim
/// executor never executes artifacts, so family-exact shapes are
/// irrelevant; what matters is a valid ordering contract with a couple of
/// maskable groups, small enough that aggregating a 256-client cohort is
/// cheap.
pub fn sim_spec(model: &str) -> ModelSpec {
    let (g1, g2) = match model {
        "shakespeare_lstm" => (48usize, 24usize),
        "cifar_vgg9" | "cifar_resnet18" => (64, 32),
        _ => (48, 16),
    };
    let manifest = format!(
        r#"{{
 "model": "{model}", "batch_size": 8,
 "x_shape": [8, 16], "x_dtype": "f32", "num_classes": 10,
 "params": [
   {{"name": "fc1_w", "shape": [16, {g1}]}}, {{"name": "fc1_b", "shape": [{g1}]}},
   {{"name": "fc2_w", "shape": [{g1}, {g2}]}}, {{"name": "fc2_b", "shape": [{g2}]}},
   {{"name": "out_w", "shape": [{g2}, 10]}}, {{"name": "out_b", "shape": [10]}}
 ],
 "masks": [{{"name": "fc1", "size": {g1}}}, {{"name": "fc2", "size": {g2}}}],
 "delta_groups": ["fc1", "fc2"],
 "delta_inputs": ["fc1_w", "fc2_w"],
 "artifacts": {{"train": "sim", "eval": "sim", "delta": "sim"}},
 "train_outputs": []
}}"#
    );
    ModelSpec::from_json_str(&manifest, Path::new("/"))
        .expect("sim manifest is statically valid")
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
 "model": "tiny", "batch_size": 4,
 "x_shape": [4, 8], "x_dtype": "f32", "num_classes": 3,
 "params": [
   {"name": "fc1_w", "shape": [8, 6]}, {"name": "fc1_b", "shape": [6]},
   {"name": "out_w", "shape": [6, 3]}, {"name": "out_b", "shape": [3]}
 ],
 "masks": [{"name": "fc1", "size": 6}],
 "delta_groups": ["fc1"],
 "delta_inputs": ["fc1_w"],
 "artifacts": {"train": "t.hlo.txt", "eval": "e.hlo.txt", "delta": "d.hlo.txt"},
 "train_outputs": ["fc1_w", "fc1_b", "out_w", "out_b", "loss", "acc"]
}"#;

    #[test]
    fn parses_manifest() {
        let s = ModelSpec::from_json_str(MANIFEST, Path::new("/tmp")).unwrap();
        assert_eq!(s.name, "tiny");
        assert_eq!(s.batch_size, 4);
        assert_eq!(s.num_params(), 8 * 6 + 6 + 6 * 3 + 3);
        assert_eq!(s.num_neurons(), 6);
        assert_eq!(s.size_bytes(), s.num_params() * 4);
        assert!(!s.x_is_int);
        assert_eq!(s.mask_index("fc1"), Some(0));
        assert_eq!(s.param_index("out_w"), Some(2));
    }

    #[test]
    fn init_matches_spec_shapes() {
        let s = ModelSpec::from_json_str(MANIFEST, Path::new("/tmp")).unwrap();
        let ps = s.init_params(42);
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].shape(), &[8, 6]);
        assert!(ps[1].data().iter().all(|&x| x == 0.0)); // bias zero
        // deterministic
        assert_eq!(ps, s.init_params(42));
        assert_ne!(ps[0], s.init_params(43)[0]);
    }

    #[test]
    fn sim_specs_are_valid_for_every_family() {
        for m in [
            "femnist_cnn",
            "cifar_vgg9",
            "cifar_resnet18",
            "shakespeare_lstm",
        ] {
            let s = sim_spec(m);
            assert_eq!(s.name, m);
            assert_eq!(s.masks.len(), 2);
            assert!(s.num_params() < 10_000, "sim spec too big: {}", s.num_params());
            // delta inputs resolve (validate() checked it, but pin the
            // group -> weight mapping the sim delta kernel relies on)
            for d in &s.delta_inputs {
                assert!(s.param_index(d).is_some());
            }
        }
    }

    #[test]
    fn misaligned_masks_rejected() {
        let bad = MANIFEST.replace(r#""delta_groups": ["fc1"]"#, r#""delta_groups": []"#);
        assert!(ModelSpec::from_json_str(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_key_rejected() {
        let bad = MANIFEST.replace(r#""batch_size": 4,"#, "");
        let err = ModelSpec::from_json_str(&bad, Path::new("/tmp"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("batch_size"));
    }
}
