//! The unified straggler-mitigation seam (DESIGN.md §14).
//!
//! FLuID's mitigation behavior used to be smeared across four layers:
//! dropout-policy `match` arms in `engine/mod.rs` (construction, snapshot
//! pairing, mask cutting, calibration gating), detection/adaptation in
//! `straggler/{detect,adapt}.rs`, staleness weighting in
//! `fl/aggregate.rs`, and round-cut rules in `engine/sched.rs`. Adding a
//! neighboring method meant touching all of them in lock-step.
//!
//! [`MitigationPolicy`] is the one seam the round engine talks to
//! instead. Its lifecycle hooks mirror the engine's round phases:
//!
//! * [`MitigationPolicy::plan`] — who is a straggler this round, and
//!   what rate / mask / soft-training fraction each one gets
//!   ([`Assignments`]);
//! * [`MitigationPolicy::observe`] — per-arrival latency evidence
//!   (closes the adaptive loop; a no-op for open-loop policies);
//! * [`MitigationPolicy::weigh`] — a per-update aggregation-weight
//!   multiplier consumed by the masked-FedAvg weight
//!   ([`crate::fl::policy_weight`]); `1.0` leaves the update untouched
//!   *without* a float multiply, so the FLuID paths stay bit-identical;
//! * [`MitigationPolicy::admit_stale`] — the semi-async admission gate
//!   for matured buffered updates (SAFA's lag tolerance);
//! * [`MitigationPolicy::elastic_lambda`] — the post-aggregation elastic
//!   mix `new = λ·agg + (1−λ)·old` (FedProx-style; `1.0` skips the
//!   blend entirely);
//! * [`MitigationPolicy::snapshot_state`] / `restore_state` — the single
//!   dispatch site for checkpoint/resume policy state (collapses the old
//!   engine-side `(Policy, PolicyState)` double-`match`).
//!
//! [`Mitigation`] selects the active implementation: `fluid` hosts every
//! pre-existing path (the five dropout policies × paper/ewma adaptation)
//! with every pinned trajectory bit-identical; `fedprox`, `safa`, and
//! `helios` are the policy zoo. `coordinator::matrix` races them under
//! identical seeds and emits the leaderboard JSON.

mod fluid;
mod zoo;

pub use fluid::FluidPolicy;
pub use zoo::{FedProxPolicy, HeliosPolicy, SafaPolicy};

use crate::coordinator::ExperimentConfig;
use crate::dropout::PolicyKind;
use crate::engine::plan::{MaskTable, RateTable};
use crate::fl::AggScratch;
use crate::model::ModelSpec;
use crate::snapshot::{PolicyState, ZooState};
use crate::straggler::{CtrlState, Detection};
use crate::tensor::Tensor;

/// Which mitigation family an experiment runs. `Fluid` hosts all five
/// dropout policies (the paper + its baselines) behind the historical
/// code paths; the others are the policy zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Mitigation {
    /// FLuID and its dropout baselines (`PolicyKind` selects which)
    #[default]
    Fluid,
    /// FedProx-style elastic aggregation: stragglers run the full model,
    /// and the global step is damped by `mitigation_trade_off` (λ):
    /// `new = λ·agg + (1−λ)·old`. λ = 1 is exactly the `none` baseline.
    FedProx,
    /// SAFA-style lag-tolerant semi-async: no sub-models; buffered late
    /// updates are admitted only while their version lag stays within
    /// `safa_lag` rounds, and admitted stale updates are damped by
    /// `1/(1+staleness)` on top of the engine's staleness discount.
    Safa,
    /// Helios-style soft-training: stragglers keep the full model but
    /// run a smoothed fraction of their local steps (partial epochs
    /// instead of sub-models); communication stays full-size.
    Helios,
}

impl Mitigation {
    pub fn name(&self) -> &'static str {
        match self {
            Mitigation::Fluid => "fluid",
            Mitigation::FedProx => "fedprox",
            Mitigation::Safa => "safa",
            Mitigation::Helios => "helios",
        }
    }
}

/// The id string a run reports per round: the dropout-policy name under
/// `fluid` (these are the paper's comparison axes), the zoo policy name
/// otherwise.
pub fn active_id(mitigation: Mitigation, policy: PolicyKind) -> &'static str {
    match mitigation {
        Mitigation::Fluid => policy.name(),
        other => other.name(),
    }
}

/// Parse a `--policy` argument into the `(PolicyKind, Mitigation)` pair.
/// The five historical names select a dropout policy under `fluid`; the
/// zoo names select a mitigation with no dropout masks at all.
pub fn parse_policy_arg(s: &str) -> Option<(PolicyKind, Mitigation)> {
    if let Some(kind) = PolicyKind::parse(s) {
        return Some((kind, Mitigation::Fluid));
    }
    let mit = match s.to_ascii_lowercase().as_str() {
        "fedprox" => Mitigation::FedProx,
        "safa" => Mitigation::Safa,
        "helios" => Mitigation::Helios,
        _ => return None,
    };
    Some((PolicyKind::None, mit))
}

/// Everything `plan` may read. Borrowed from the engine for the duration
/// of one planning call; policies must not retain any of it.
pub struct PlanCtx<'c> {
    pub round: usize,
    /// this round's sampled cohort, sorted by client id
    pub selected: &'c [usize],
    /// fleet mode filters unmeasured clients out of the detection pool
    pub fleet_mode: bool,
    /// full-model-normalized latency each client last reported
    pub last_full_latencies: &'c [f64],
    pub spec: &'c ModelSpec,
    /// the all-ones mask `MaskTable` defaults to
    pub full_mask: &'c crate::dropout::MaskSet,
}

/// One aggregation candidate, as `weigh` sees it.
pub struct UpdateCtx {
    pub client: usize,
    /// rounds between the update's birth and this aggregation (0 = fresh)
    pub staleness: usize,
    pub is_straggler: bool,
}

/// Per-round mitigation assignments: who is a straggler and what each
/// one gets. Tables are sparse (absent = full model, rate 1.0, full
/// local steps), so a quiet round costs O(stragglers), never O(fleet).
#[derive(Default)]
pub struct Assignments {
    /// detection order (the order rates were assigned in)
    pub straggler_ids: Vec<usize>,
    pub rates: RateTable,
    pub masks: Option<MaskTable>,
    /// per-client soft-training fractions (Helios): `local_steps` scales
    /// by the fraction, communication stays full
    pub train_frac: Vec<(usize, f64)>,
    /// the barrier target (slowest non-straggler latency), when known
    pub t_target: Option<f64>,
    /// Exclude policy: stragglers neither train nor aggregate
    pub exclude_stragglers: bool,
}

/// The full per-policy state a snapshot carries, and the one dispatch
/// site for restoring it. The engine maps these fields 1:1 onto the
/// snapshot container's POLICY / SCHED / CTRL / ZOO sections, so every
/// pre-seam snapshot stays byte-compatible.
pub struct MitigationState {
    pub policy: PolicyState,
    pub detection: Option<Detection>,
    pub ctrl: Option<CtrlState>,
    pub zoo: Option<ZooState>,
}

/// The unified mitigation seam. One implementation is active per run;
/// the engine calls the hooks in round order (`plan` → `observe` →
/// `weigh`/`admit_stale` → `elastic_lambda`) and snapshot boundaries use
/// `snapshot_state`/`restore_state`.
pub trait MitigationPolicy {
    /// Stable id for reports and the leaderboard.
    fn id(&self) -> &'static str;

    /// Straggler detection + per-client assignments for one round.
    fn plan(&mut self, ctx: PlanCtx<'_>) -> Assignments;

    /// Per-arrival latency evidence (no-op for open-loop policies).
    fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64);

    /// Aggregation-weight multiplier for one update. `1.0` means
    /// "untouched" and skips the multiply (bit-identity contract).
    fn weigh(&self, ctx: &UpdateCtx) -> f64 {
        let _ = ctx;
        1.0
    }

    /// Admission gate for a matured buffered update. Rejected updates
    /// are dropped (counted in `dropped_updates`), never aggregated.
    fn admit_stale(&self, client: usize, staleness: usize) -> bool {
        let _ = (client, staleness);
        true
    }

    /// A fresh or stale update from `client` entered this round's
    /// aggregation (version bookkeeping for lag-tolerant policies).
    fn record_contribution(&mut self, client: usize, round: usize) {
        let _ = (client, round);
    }

    /// Post-aggregation elastic mix λ: `new = λ·agg + (1−λ)·old`.
    /// `1.0` skips the blend entirely (bit-identity contract).
    fn elastic_lambda(&self) -> f64 {
        1.0
    }

    /// Does this policy consume non-straggler delta observations on
    /// calibration rounds (the invariant-dropout voter sweep)?
    fn wants_delta_observations(&self) -> bool {
        false
    }

    /// Feed the calibration voters' per-neuron deltas (invariant only).
    fn observe_deltas(
        &mut self,
        per_client: &[Vec<Tensor>],
        threads: usize,
        scratch: &mut AggScratch,
    ) {
        let _ = (per_client, threads, scratch);
    }

    /// Fraction of neurons currently invariant (0.0 outside FLuID).
    fn invariant_fraction(&self) -> f64 {
        0.0
    }

    /// Export every piece of evolving policy state for a snapshot.
    fn snapshot_state(&self) -> MitigationState;

    /// Reinstall snapshot state. A state captured under a *different*
    /// policy must fail with a clean fingerprint-style error, never
    /// half-apply.
    fn restore_state(&mut self, state: MitigationState) -> crate::Result<()>;
}

/// Construct the configured mitigation policy. The returned trait object
/// borrows `cfg` (policies read their knobs live, like the engine does).
pub fn build<'c>(
    cfg: &'c ExperimentConfig,
    spec: &ModelSpec,
    n: usize,
) -> Box<dyn MitigationPolicy + 'c> {
    match cfg.mitigation {
        Mitigation::Fluid => Box::new(FluidPolicy::new(cfg, spec, n)),
        Mitigation::FedProx => Box::new(FedProxPolicy::new(cfg, n)),
        Mitigation::Safa => Box::new(SafaPolicy::new(cfg, n)),
        Mitigation::Helios => Box::new(HeliosPolicy::new(cfg, n)),
    }
}

/// The paper's straggler-recalibration gate + pool filter, shared by
/// every policy (the zoo reuses FLuID's detection machinery verbatim:
/// they differ in what they *assign*, not in who they detect).
///
/// Fleet mode: a fresh cohort is mostly *unmeasured* (latency still
/// 0.0) — zeros would both collapse t_target to 0 and flag every
/// measured client as a straggler, so detection only reads clients with
/// a real measurement. The classic path keeps the historic behavior
/// bit-for-bit (zeros included), as pinned by tests/engine_regression.rs.
pub(crate) fn recalibrate_detection(
    controller: &mut crate::straggler::RateController,
    detection: &mut Option<Detection>,
    cfg: &ExperimentConfig,
    ctx: &PlanCtx<'_>,
) {
    let recalibrate = ctx.round > 0
        && ctx.round % cfg.recalibrate_every == 0
        && !(cfg.static_stragglers && detection.is_some());
    if !recalibrate {
        return;
    }
    let pool: Vec<usize> = if ctx.fleet_mode {
        ctx.selected
            .iter()
            .copied()
            .filter(|&c| ctx.last_full_latencies[c] > 0.0)
            .collect()
    } else {
        ctx.selected.to_vec()
    };
    if let Some(det) = controller.recalibrate(
        &pool,
        ctx.last_full_latencies,
        cfg.straggler_fraction,
        crate::straggler::detect::DETECT_MARGIN,
        &cfg.rates_menu,
    ) {
        *detection = Some(det);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mitigation_names_and_policy_arg_parse() {
        assert_eq!(Mitigation::Fluid.name(), "fluid");
        assert_eq!(
            parse_policy_arg("invariant"),
            Some((PolicyKind::Invariant, Mitigation::Fluid))
        );
        assert_eq!(
            parse_policy_arg("fluid"),
            Some((PolicyKind::Invariant, Mitigation::Fluid))
        );
        assert_eq!(
            parse_policy_arg("exclude"),
            Some((PolicyKind::Exclude, Mitigation::Fluid))
        );
        assert_eq!(
            parse_policy_arg("fedprox"),
            Some((PolicyKind::None, Mitigation::FedProx))
        );
        assert_eq!(
            parse_policy_arg("SAFA"),
            Some((PolicyKind::None, Mitigation::Safa))
        );
        assert_eq!(
            parse_policy_arg("helios"),
            Some((PolicyKind::None, Mitigation::Helios))
        );
        assert_eq!(parse_policy_arg("bogus"), None);
    }

    #[test]
    fn active_id_reports_the_dropout_policy_under_fluid() {
        assert_eq!(active_id(Mitigation::Fluid, PolicyKind::Invariant), "invariant");
        assert_eq!(active_id(Mitigation::Fluid, PolicyKind::Exclude), "exclude");
        assert_eq!(active_id(Mitigation::FedProx, PolicyKind::None), "fedprox");
        assert_eq!(active_id(Mitigation::Safa, PolicyKind::None), "safa");
        assert_eq!(active_id(Mitigation::Helios, PolicyKind::None), "helios");
    }
}
