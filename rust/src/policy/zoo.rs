//! The policy zoo: straggler-mitigation alternatives from related work,
//! hosted behind the same [`MitigationPolicy`] seam as FLuID.
//!
//! All three reuse FLuID's paper-mode detection (one-shot latency menu
//! snap through [`recalibrate_detection`]) to decide *who* the
//! stragglers are, but answer *what to do about them* differently:
//!
//! * [`FedProxPolicy`] — elastic aggregation: every client trains the
//!   full model, and the aggregated proposal is blended toward the old
//!   global parameters (`new = α·proposal + (1-α)·old`) to damp the
//!   noise stragglers inject. α is `mitigation_trade_off`; α = 1.0 is
//!   bit-identical to plain FedAvg.
//! * [`SafaPolicy`] — lag-tolerant semi-async: stragglers miss the
//!   round cut, but their stale updates are admitted as long as the
//!   model-version lag is within `safa_lag` rounds, down-weighted by
//!   `1/(1+staleness)` on top of the scheduler's maturity discount.
//! * [`HeliosPolicy`] — soft-training: stragglers keep the full model
//!   but run a reduced fraction of local steps, smoothed per client
//!   (`frac ← (frac + desired)/2`) so the training budget converges to
//!   the detected speedup rather than jumping.

use super::{
    recalibrate_detection, Assignments, MitigationPolicy, MitigationState, PlanCtx, UpdateCtx,
};
use crate::coordinator::ExperimentConfig;
use crate::engine::plan::RateTable;
use crate::snapshot::{PolicyState, ZooState};
use crate::straggler::{Detection, RateController};

/// FedProx-style elastic aggregation. No per-client state beyond the
/// shared detection; the whole method lives in [`elastic_lambda`].
///
/// [`elastic_lambda`]: MitigationPolicy::elastic_lambda
pub struct FedProxPolicy<'c> {
    cfg: &'c ExperimentConfig,
    controller: RateController,
    detection: Option<Detection>,
}

impl<'c> FedProxPolicy<'c> {
    pub fn new(cfg: &'c ExperimentConfig, n: usize) -> Self {
        Self {
            cfg,
            controller: RateController::new(n, cfg.adapt_config()),
            detection: None,
        }
    }
}

impl MitigationPolicy for FedProxPolicy<'_> {
    fn id(&self) -> &'static str {
        super::Mitigation::FedProx.name()
    }

    fn plan(&mut self, ctx: PlanCtx<'_>) -> Assignments {
        recalibrate_detection(&mut self.controller, &mut self.detection, self.cfg, &ctx);
        Assignments {
            straggler_ids: self
                .detection
                .as_ref()
                .map(|d| d.stragglers.clone())
                .unwrap_or_default(),
            t_target: self.detection.as_ref().map(|d| d.t_target),
            ..Assignments::default()
        }
    }

    fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64) {
        self.controller.observe(client, latency, full_latency, applied_rate);
    }

    fn elastic_lambda(&self) -> f64 {
        self.cfg.mitigation_trade_off
    }

    fn snapshot_state(&self) -> MitigationState {
        MitigationState {
            policy: PolicyState::Stateless,
            detection: self.detection.clone(),
            ctrl: self.controller.export_state(),
            zoo: None,
        }
    }

    fn restore_state(&mut self, state: MitigationState) -> crate::Result<()> {
        anyhow::ensure!(
            matches!(state.policy, PolicyState::Stateless),
            "snapshot policy state does not match the configured mitigation fedprox"
        );
        anyhow::ensure!(
            state.zoo.is_none(),
            "snapshot zoo state does not match the configured mitigation fedprox"
        );
        self.detection = state.detection;
        if let Some(ctrl) = state.ctrl {
            self.controller.import_state(ctrl);
        }
        Ok(())
    }
}

/// SAFA-style lag-tolerant semi-async admission over `Buffered` sync.
/// Tracks the last global round each client contributed to; a stale
/// update is admitted only while its version lag is within
/// `cfg.safa_lag`.
pub struct SafaPolicy<'c> {
    cfg: &'c ExperimentConfig,
    controller: RateController,
    detection: Option<Detection>,
    /// last round whose aggregate included this client's update
    version: Vec<usize>,
}

impl<'c> SafaPolicy<'c> {
    pub fn new(cfg: &'c ExperimentConfig, n: usize) -> Self {
        Self {
            cfg,
            controller: RateController::new(n, cfg.adapt_config()),
            detection: None,
            version: vec![0; n],
        }
    }
}

impl MitigationPolicy for SafaPolicy<'_> {
    fn id(&self) -> &'static str {
        super::Mitigation::Safa.name()
    }

    fn plan(&mut self, ctx: PlanCtx<'_>) -> Assignments {
        recalibrate_detection(&mut self.controller, &mut self.detection, self.cfg, &ctx);
        Assignments {
            straggler_ids: self
                .detection
                .as_ref()
                .map(|d| d.stragglers.clone())
                .unwrap_or_default(),
            t_target: self.detection.as_ref().map(|d| d.t_target),
            ..Assignments::default()
        }
    }

    fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64) {
        self.controller.observe(client, latency, full_latency, applied_rate);
    }

    fn weigh(&self, ctx: &UpdateCtx) -> f64 {
        if ctx.staleness == 0 {
            1.0
        } else {
            1.0 / (1.0 + ctx.staleness as f64)
        }
    }

    fn admit_stale(&self, _client: usize, staleness: usize) -> bool {
        staleness <= self.cfg.safa_lag
    }

    fn record_contribution(&mut self, client: usize, round: usize) {
        self.version[client] = round;
    }

    fn snapshot_state(&self) -> MitigationState {
        MitigationState {
            policy: PolicyState::Stateless,
            detection: self.detection.clone(),
            ctrl: self.controller.export_state(),
            zoo: Some(ZooState::Safa { version: self.version.clone() }),
        }
    }

    fn restore_state(&mut self, state: MitigationState) -> crate::Result<()> {
        anyhow::ensure!(
            matches!(state.policy, PolicyState::Stateless),
            "snapshot policy state does not match the configured mitigation safa"
        );
        match state.zoo {
            Some(ZooState::Safa { version }) => {
                anyhow::ensure!(
                    version.len() == self.version.len(),
                    "snapshot safa version table has {} clients, engine has {}",
                    version.len(),
                    self.version.len()
                );
                self.version = version;
            }
            // old-writer snapshot without a zoo section: start the
            // version ledger fresh (admission only loosens for one lap)
            None => {}
            Some(other) => anyhow::bail!(
                "snapshot zoo state {:?} does not match the configured mitigation safa",
                other.tag_name()
            ),
        }
        self.detection = state.detection;
        if let Some(ctrl) = state.ctrl {
            self.controller.import_state(ctrl);
        }
        Ok(())
    }
}

/// Helios-style soft-training: stragglers run `frac · local_steps`
/// local steps on the full model instead of a sub-model. The per-client
/// fraction is smoothed toward the detected speedup requirement.
pub struct HeliosPolicy<'c> {
    cfg: &'c ExperimentConfig,
    controller: RateController,
    detection: Option<Detection>,
    /// per-client soft-training fraction, 1.0 = full local epoch
    frac: Vec<f64>,
}

impl<'c> HeliosPolicy<'c> {
    pub fn new(cfg: &'c ExperimentConfig, n: usize) -> Self {
        Self {
            cfg,
            controller: RateController::new(n, cfg.adapt_config()),
            detection: None,
            frac: vec![1.0; n],
        }
    }
}

impl MitigationPolicy for HeliosPolicy<'_> {
    fn id(&self) -> &'static str {
        super::Mitigation::Helios.name()
    }

    fn plan(&mut self, ctx: PlanCtx<'_>) -> Assignments {
        recalibrate_detection(&mut self.controller, &mut self.detection, self.cfg, &ctx);
        let mut rates = RateTable::new();
        let mut train_frac: Vec<(usize, f64)> = Vec::new();
        let mut straggler_ids: Vec<usize> = Vec::new();
        if let Some(det) = &self.detection {
            for (k, &c) in det.stragglers.iter().enumerate() {
                let desired = self.cfg.fixed_rate.unwrap_or(det.rates[k]);
                // smooth toward the requirement so one noisy calibration
                // round can't halve a client's training budget outright
                let smoothed = 0.5 * (self.frac[c] + desired);
                self.frac[c] = smoothed;
                // compute time scales with the step budget; comm stays at
                // the full model (no mask override, comm_fraction 1.0)
                rates.set(c, smoothed);
                train_frac.push((c, smoothed));
                straggler_ids.push(c);
            }
        }
        Assignments {
            straggler_ids,
            rates,
            masks: None,
            train_frac,
            t_target: self.detection.as_ref().map(|d| d.t_target),
            exclude_stragglers: false,
        }
    }

    fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64) {
        self.controller.observe(client, latency, full_latency, applied_rate);
    }

    fn snapshot_state(&self) -> MitigationState {
        MitigationState {
            policy: PolicyState::Stateless,
            detection: self.detection.clone(),
            ctrl: self.controller.export_state(),
            zoo: Some(ZooState::Helios { frac: self.frac.clone() }),
        }
    }

    fn restore_state(&mut self, state: MitigationState) -> crate::Result<()> {
        anyhow::ensure!(
            matches!(state.policy, PolicyState::Stateless),
            "snapshot policy state does not match the configured mitigation helios"
        );
        match state.zoo {
            Some(ZooState::Helios { frac }) => {
                anyhow::ensure!(
                    frac.len() == self.frac.len(),
                    "snapshot helios fraction table has {} clients, engine has {}",
                    frac.len(),
                    self.frac.len()
                );
                self.frac = frac;
            }
            None => {}
            Some(other) => anyhow::bail!(
                "snapshot zoo state {:?} does not match the configured mitigation helios",
                other.tag_name()
            ),
        }
        self.detection = state.detection;
        if let Some(ctrl) = state.ctrl {
            self.controller.import_state(ctrl);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dropout::PolicyKind;
    use crate::policy::Mitigation;

    fn zoo_cfg(mit: Mitigation) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::None);
        cfg.mitigation = mit;
        cfg
    }

    #[test]
    fn safa_admits_within_lag_and_rejects_beyond() {
        let mut cfg = zoo_cfg(Mitigation::Safa);
        cfg.safa_lag = 2;
        let p = SafaPolicy::new(&cfg, 8);
        assert!(p.admit_stale(3, 1));
        assert!(p.admit_stale(3, 2));
        assert!(!p.admit_stale(3, 3));
    }

    #[test]
    fn safa_weighs_stale_updates_down() {
        let cfg = zoo_cfg(Mitigation::Safa);
        let p = SafaPolicy::new(&cfg, 8);
        let fresh = UpdateCtx { client: 0, staleness: 0, is_straggler: false };
        let stale = UpdateCtx { client: 0, staleness: 3, is_straggler: true };
        assert_eq!(p.weigh(&fresh), 1.0);
        assert!((p.weigh(&stale) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn fedprox_lambda_tracks_trade_off_knob() {
        let mut cfg = zoo_cfg(Mitigation::FedProx);
        cfg.mitigation_trade_off = 0.25;
        let p = FedProxPolicy::new(&cfg, 8);
        assert!((p.elastic_lambda() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn helios_smooths_fractions_and_round_trips_state() {
        let cfg = zoo_cfg(Mitigation::Helios);
        let mut p = HeliosPolicy::new(&cfg, 4);
        p.frac = vec![1.0, 0.5, 1.0, 0.25];
        let snap = p.snapshot_state();
        let mut q = HeliosPolicy::new(&cfg, 4);
        q.restore_state(snap).unwrap();
        assert_eq!(q.frac, vec![1.0, 0.5, 1.0, 0.25]);
    }

    #[test]
    fn zoo_restore_rejects_mismatched_variant() {
        let cfg = zoo_cfg(Mitigation::Safa);
        let mut p = SafaPolicy::new(&cfg, 4);
        let err = p
            .restore_state(MitigationState {
                policy: PolicyState::Stateless,
                detection: None,
                ctrl: None,
                zoo: Some(ZooState::Helios { frac: vec![1.0; 4] }),
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("safa"), "{err:#}");
    }
}
