//! The FLuID family behind the [`MitigationPolicy`] seam.
//!
//! `FluidPolicy` hosts all five dropout policies (invariant / random /
//! ordered / none / exclude) and both adaptation modes (paper menu snap,
//! ewma closed loop). The planning, observation, and snapshot logic is
//! the engine's historical code moved here verbatim — every pinned
//! trajectory replays bit-identically through the trait (the regression
//! and determinism suites compare against the pre-seam reference loop).

use super::{recalibrate_detection, Assignments, MitigationPolicy, MitigationState, PlanCtx};
use crate::coordinator::ExperimentConfig;
use crate::dropout::{InvariantConfig, Policy, PolicyKind};
use crate::engine::plan::{MaskTable, RateTable};
use crate::fl::AggScratch;
use crate::model::ModelSpec;
use crate::snapshot::PolicyState;
use crate::straggler::{snap_rate, AdaptMode, Detection, RateController};
use crate::tensor::Tensor;

/// FLuID + its dropout baselines: detection through the calibration
/// seam ([`RateController`]), sub-model masks through the configured
/// [`Policy`].
pub struct FluidPolicy<'c> {
    cfg: &'c ExperimentConfig,
    policy: Policy,
    controller: RateController,
    detection: Option<Detection>,
}

impl<'c> FluidPolicy<'c> {
    pub fn new(cfg: &'c ExperimentConfig, spec: &ModelSpec, n: usize) -> Self {
        let inv_cfg = InvariantConfig {
            th_override: cfg.invariant_th_override,
            ..Default::default()
        };
        Self {
            cfg,
            policy: Policy::new_with(cfg.policy, spec, cfg.seed ^ 0xD20, inv_cfg),
            controller: RateController::new(n, cfg.adapt_config()),
            detection: None,
        }
    }
}

impl MitigationPolicy for FluidPolicy<'_> {
    fn id(&self) -> &'static str {
        self.cfg.policy.name()
    }

    fn plan(&mut self, ctx: PlanCtx<'_>) -> Assignments {
        let cfg = self.cfg;
        recalibrate_detection(&mut self.controller, &mut self.detection, cfg, &ctx);

        // --- sub-model assignment ---------------------------------------
        let ewma = cfg.adapt == AdaptMode::Ewma;
        let mut masks = MaskTable::new(ctx.full_mask.clone());
        // rates and straggler membership are sparse: O(stragglers) per
        // round where the former dense tables were O(fleet)
        let mut rates = RateTable::new();
        let mut straggler_ids: Vec<usize> = Vec::new();
        if let Some(det) = &self.detection {
            for (k, &c) in det.stragglers.iter().enumerate() {
                let desired = cfg.fixed_rate.unwrap_or(det.rates[k]);
                let r = match &cfg.cluster_rates {
                    Some(menu) => snap_rate(desired, menu),
                    None => desired,
                };
                // The controller's straggler set persists across cohorts,
                // so in ewma mode only clients actually sampled this
                // round get a mask cut (mask extraction advances policy
                // state — random dropout's PRNG — so the classic paper
                // path keeps cutting one per straggler, bit-identically
                // to the pre-controller loop). `selected` is sorted.
                let sampled_now = !ewma || ctx.selected.binary_search(&c).is_ok();
                if sampled_now
                    && cfg.policy != PolicyKind::None
                    && cfg.policy != PolicyKind::Exclude
                {
                    let m = self.policy.make_mask(ctx.spec, r);
                    // the straggler only speeds up if it actually received
                    // a sub-model (invariant dropout returns the full mask
                    // until its first calibration observation)
                    if !m.is_full() {
                        rates.set(c, r);
                        masks.set(c, m);
                    }
                }
                straggler_ids.push(c);
            }
        }

        Assignments {
            straggler_ids,
            rates,
            masks: Some(masks),
            train_frac: Vec::new(),
            t_target: self.detection.as_ref().map(|d| d.t_target),
            exclude_stragglers: cfg.policy == PolicyKind::Exclude,
        }
    }

    fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64) {
        // close the loop: the controller smooths these into its
        // per-client profiles (no-op in paper mode). The applied rate
        // rides along so evidence from a full-model fallback round can
        // never drive a feedback step.
        self.controller.observe(client, latency, full_latency, applied_rate);
    }

    fn wants_delta_observations(&self) -> bool {
        matches!(self.policy, Policy::Invariant(_))
    }

    fn observe_deltas(
        &mut self,
        per_client: &[Vec<Tensor>],
        threads: usize,
        scratch: &mut AggScratch,
    ) {
        self.policy.observe_deltas_with(per_client, threads, scratch);
    }

    fn invariant_fraction(&self) -> f64 {
        self.policy.invariant_fraction()
    }

    fn snapshot_state(&self) -> MitigationState {
        let policy = match &self.policy {
            Policy::Random(p) => {
                let (state, inc) = p.rng_state();
                PolicyState::Random { state, inc }
            }
            Policy::Invariant(p) => {
                let (th, streak, score, observations) = p.export_state();
                PolicyState::Invariant { th, streak, score, observations }
            }
            Policy::None | Policy::Ordered(_) | Policy::Exclude => PolicyState::Stateless,
        };
        MitigationState {
            policy,
            detection: self.detection.clone(),
            ctrl: self.controller.export_state(),
            zoo: None,
        }
    }

    fn restore_state(&mut self, state: MitigationState) -> crate::Result<()> {
        // refuse before touching any state, so a bad snapshot can never
        // half-apply (the policy match below mutates on its happy arms)
        anyhow::ensure!(
            state.zoo.is_none(),
            "snapshot carries zoo policy state, but the configured mitigation is fluid"
        );
        match (&mut self.policy, &state.policy) {
            (Policy::Random(p), PolicyState::Random { state, inc }) => {
                p.set_rng_state(*state, *inc);
            }
            (Policy::Invariant(p), PolicyState::Invariant { th, streak, score, observations }) => {
                p.import_state(th.clone(), streak.clone(), score.clone(), *observations)?;
            }
            (
                Policy::None | Policy::Ordered(_) | Policy::Exclude,
                PolicyState::Stateless,
            ) => {}
            _ => anyhow::bail!(
                "snapshot policy state does not match the configured policy {:?}",
                self.cfg.policy
            ),
        }
        self.detection = state.detection;
        if let Some(ctrl) = state.ctrl {
            self.controller.import_state(ctrl);
        }
        Ok(())
    }
}

impl std::fmt::Debug for FluidPolicy<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FluidPolicy")
            .field("policy", &self.cfg.policy)
            .field("adapt", &self.cfg.adapt)
            .field("detected", &self.detection.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snapshot::ZooState;

    fn spec() -> ModelSpec {
        crate::model::sim_spec("femnist_cnn")
    }

    #[test]
    fn fluid_rejects_zoo_snapshot_state() {
        let cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::None);
        let mut p = FluidPolicy::new(&cfg, &spec(), cfg.clients);
        let err = p
            .restore_state(MitigationState {
                policy: PolicyState::Stateless,
                detection: None,
                ctrl: None,
                zoo: Some(ZooState::Safa { version: vec![0; 5] }),
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("zoo"), "{err:#}");
    }

    #[test]
    fn fluid_rejects_mismatched_policy_state() {
        let cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
        let mut p = FluidPolicy::new(&cfg, &spec(), cfg.clients);
        let err = p
            .restore_state(MitigationState {
                policy: PolicyState::Random { state: 1, inc: 3 },
                detection: None,
                ctrl: None,
                zoo: None,
            })
            .unwrap_err();
        assert!(
            format!("{err:#}").contains("does not match the configured policy"),
            "{err:#}"
        );
    }

    #[test]
    fn plan_without_detection_assigns_nothing() {
        let cfg = ExperimentConfig::mobile("femnist_cnn", PolicyKind::Invariant);
        let spec = spec();
        let full = crate::dropout::MaskSet::full(&spec);
        let mut p = FluidPolicy::new(&cfg, &spec, cfg.clients);
        let selected: Vec<usize> = (0..cfg.clients).collect();
        let lat = vec![0.0; cfg.clients];
        let a = p.plan(PlanCtx {
            round: 0,
            selected: &selected,
            fleet_mode: false,
            last_full_latencies: &lat,
            spec: &spec,
            full_mask: &full,
        });
        assert!(a.straggler_ids.is_empty());
        assert!(a.rates.entries().is_empty());
        assert!(a.t_target.is_none());
        assert!(!a.exclude_stragglers);
    }
}
