//! Recursive-descent JSON parser.

use super::Json;
use std::collections::BTreeMap;

/// Parse failure with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = (start + len).min(self.bytes.len());
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let j = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[0],
            Json::Num(1.0)
        );
    }

    #[test]
    fn whitespace_tolerant() {
        assert!(parse(" {\n\t\"a\" : 1 } \n").is_ok());
    }

    #[test]
    fn escapes_and_unicode() {
        let j = parse(r#""a\n\t\"\\ é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ é");
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn errors_have_offsets() {
        let e = parse("{\"a\": }").unwrap_err();
        assert_eq!(e.offset, 6);
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn real_manifest_parses() {
        // mirror of the aot.py manifest structure
        let text = r#"{
 "model": "femnist_cnn", "batch_size": 10,
 "x_shape": [10, 28, 28, 1], "x_dtype": "f32", "num_classes": 62,
 "params": [{"name": "conv1_w", "shape": [5, 5, 1, 16]}],
 "masks": [{"name": "conv1", "size": 16}],
 "delta_groups": ["conv1"],
 "artifacts": {"train": "femnist_cnn_train.hlo.txt"},
 "train_outputs": ["conv1_w", "loss", "acc"]
}"#;
        let j = parse(text).unwrap();
        assert_eq!(j.req("batch_size").unwrap().as_usize(), Some(10));
        assert_eq!(
            j.req("params").unwrap().as_arr().unwrap()[0]
                .req("shape")
                .unwrap()
                .as_shape()
                .unwrap(),
            vec![5, 5, 1, 16]
        );
    }
}
