//! JSON substrate (serde/serde_json unavailable offline — DESIGN.md §2).
//!
//! A complete-enough JSON implementation for this repo's needs: parsing
//! artifact manifests written by `python/compile/aot.py`, and emitting
//! experiment results / reports. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (manifests are pure ASCII).

mod parse;

pub use parse::{parse, ParseError};

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so emission is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut m) = self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    // ---- accessors ----------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that fails loudly with the key name — manifest fields are
    /// a hard contract with the python side.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-array helper: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_shape(&self) -> anyhow::Result<Vec<usize>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array, got {self:?}"))?;
        arr.iter()
            .map(|x| {
                x.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("expected number in shape"))
            })
            .collect()
    }

    // ---- emission -----------------------------------------------------------

    /// Compact single-line emission.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, None, 0);
        s
    }

    /// Pretty emission with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, Some(2), 0);
        s
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    x.emit(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    emit_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.emit(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_emit() {
        let j = Json::obj()
            .set("name", "fluid")
            .set("rounds", 100usize)
            .set("rs", vec![0.5, 0.75])
            .set("ok", true);
        assert_eq!(
            j.to_string_compact(),
            r#"{"name":"fluid","ok":true,"rounds":100,"rs":[0.5,0.75]}"#
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.to_string_compact(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn round_trip_compact_and_pretty() {
        let j = Json::obj()
            .set("arr", vec![1i64, 2, 3])
            .set("nested", Json::obj().set("x", 1.5));
        for text in [j.to_string_compact(), j.to_string_pretty()] {
            assert_eq!(parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn shape_helper() {
        let j = parse("[5, 5, 1, 16]").unwrap();
        assert_eq!(j.as_shape().unwrap(), vec![5, 5, 1, 16]);
    }

    #[test]
    fn req_reports_key() {
        let j = Json::obj().set("a", 1i64);
        let err = j.req("missing").unwrap_err().to_string();
        assert!(err.contains("missing"));
    }
}
