//! Federated datasets.
//!
//! The paper evaluates on FEMNIST, CIFAR10 and LEAF-Shakespeare. Those
//! corpora are not downloadable in this environment, so we build
//! *deterministic synthetic equivalents* with the same shapes, class
//! counts and partition structure (DESIGN.md §2): what matters for every
//! comparison in the paper is that all dropout policies train the same
//! model on the same heterogeneous client data — the learning-dynamics
//! ordering (Invariant vs Ordered vs Random) is preserved.
//!
//! * [`synthetic::femnist`] — 62-class 28x28x1 images, non-IID by
//!   "writer" (each client draws a subset of classes with its own style
//!   transform), mirroring LEAF's by-writer split.
//! * [`synthetic::cifar10`] — 10-class 32x32x3 images, IID partition
//!   (Flower's split used by the paper) or Dirichlet non-IID.
//! * [`shakespeare::load`] — char-level next-character prediction over an
//!   embedded public-domain Shakespeare excerpt, partitioned by "role"
//!   (contiguous speaker chunks), mirroring LEAF's by-role split.

pub mod partition;
pub mod shakespeare;
pub mod synthetic;

use crate::runtime::{Batch, XData};
use crate::tensor::Tensor;
use crate::util::prng::Pcg32;

/// Feature storage for one split (dense f32 or token i32).
#[derive(Clone, Debug)]
pub enum XStore {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A set of examples: `feature_len` values per example + one label.
#[derive(Clone, Debug)]
pub struct Split {
    pub xs: XStore,
    pub ys: Vec<i32>,
    pub feature_len: usize,
}

impl Split {
    pub fn len(&self) -> usize {
        self.ys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }

    /// Assemble a batch from example indices; `x_shape` is the manifest
    /// batch shape (x_shape[0] must equal idx.len()).
    pub fn batch(&self, idx: &[usize], x_shape: &[usize]) -> Batch {
        assert_eq!(x_shape[0], idx.len(), "batch size mismatch");
        assert_eq!(
            x_shape[1..].iter().product::<usize>(),
            self.feature_len,
            "feature len mismatch"
        );
        let y: Vec<i32> = idx.iter().map(|&i| self.ys[i]).collect();
        let x = match &self.xs {
            XStore::F32(data) => {
                let mut out = Vec::with_capacity(idx.len() * self.feature_len);
                for &i in idx {
                    out.extend_from_slice(
                        &data[i * self.feature_len..(i + 1) * self.feature_len],
                    );
                }
                XData::F32(Tensor::from_vec(x_shape, out))
            }
            XStore::I32(data) => {
                let mut out = Vec::with_capacity(idx.len() * self.feature_len);
                for &i in idx {
                    out.extend_from_slice(
                        &data[i * self.feature_len..(i + 1) * self.feature_len],
                    );
                }
                XData::I32(out)
            }
        };
        Batch { x, y }
    }

    /// Sample a random batch (without replacement within the batch).
    pub fn sample_batch(&self, rng: &mut Pcg32, x_shape: &[usize]) -> Batch {
        let bs = x_shape[0];
        let idx = if self.len() >= bs {
            rng.sample_indices(self.len(), bs)
        } else {
            // tiny client: sample with replacement
            (0..bs).map(|_| rng.below_usize(self.len())).collect()
        };
        self.batch(&idx, x_shape)
    }

    /// Class histogram (diagnostics / partition tests).
    pub fn class_histogram(&self, num_classes: usize) -> Vec<usize> {
        let mut h = vec![0; num_classes];
        for &y in &self.ys {
            h[y as usize] += 1;
        }
        h
    }
}

/// A federated dataset: one split per client + a held-out test split.
#[derive(Clone, Debug)]
pub struct FlData {
    pub clients: Vec<Split>,
    pub test: Split,
    pub num_classes: usize,
}

impl FlData {
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Total training examples across clients.
    pub fn total_examples(&self) -> usize {
        self.clients.iter().map(|c| c.len()).sum()
    }

    /// Build the dataset matching a model name (dispatch used by the CLI,
    /// examples and benches).
    pub fn for_model(
        model: &str,
        num_clients: usize,
        samples_per_client: usize,
        seed: u64,
    ) -> FlData {
        match model {
            "femnist_cnn" => synthetic::femnist(num_clients, samples_per_client, seed),
            "cifar_vgg9" | "cifar_resnet18" => {
                synthetic::cifar10(num_clients, samples_per_client, seed, true)
            }
            "shakespeare_lstm" => {
                shakespeare::load(num_clients, samples_per_client, 48, seed)
            }
            other => panic!("unknown model {other}"),
        }
    }
}

/// Per-shard example counts — materialized or streaming.
///
/// `Table` is the historical `Vec<usize>` (exact sizes, O(shards)
/// memory). `Lognormal` is the million-client variant: shard `i`'s size
/// is [`partition::lognormal_shard_size_at`]`(i, ...)`, computed on
/// demand in O(1), so a source's descriptor memory is a few words no
/// matter the fleet. Both are deterministic in their seeds.
#[derive(Clone, Debug)]
pub enum ShardSizes {
    Table(Vec<usize>),
    Lognormal {
        count: usize,
        base: usize,
        sigma: f32,
        seed: u64,
    },
}

impl From<Vec<usize>> for ShardSizes {
    fn from(sizes: Vec<usize>) -> Self {
        ShardSizes::Table(sizes)
    }
}

impl ShardSizes {
    /// Streaming lognormal sizes for `count` shards around `base`.
    pub fn lognormal(count: usize, base: usize, sigma: f32, seed: u64) -> Self {
        ShardSizes::Lognormal { count, base, sigma, seed }
    }

    pub fn len(&self) -> usize {
        match self {
            ShardSizes::Table(t) => t.len(),
            ShardSizes::Lognormal { count, .. } => *count,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Examples in `shard` — O(1) in both representations.
    pub fn get(&self, shard: usize) -> usize {
        match self {
            ShardSizes::Table(t) => t[shard],
            ShardSizes::Lognormal { count, base, sigma, seed } => {
                assert!(shard < *count, "shard {shard} out of range for {count}");
                partition::lognormal_shard_size_at(shard, *base, *sigma, *seed)
            }
        }
    }

    /// Sum of all shard sizes — O(shards) time, O(1) extra memory (the
    /// construction-time pass sources run once; never per round).
    pub fn total(&self) -> usize {
        match self {
            ShardSizes::Table(t) => t.iter().sum(),
            ShardSizes::Lognormal { .. } => (0..self.len()).map(|i| self.get(i)).sum(),
        }
    }
}

/// Lazy shard hydration — the fleet-scale data seam.
///
/// A source knows how many shards exist and how big each is *without*
/// materializing any of them; [`ShardSource::hydrate`] renders one
/// shard's [`Split`] on demand. The round engine hydrates only the
/// sampled cohort each round, so peak resident data is proportional to
/// the cohort, never the fleet.
pub trait ShardSource: Send + Sync {
    fn num_shards(&self) -> usize;

    /// Examples in `shard` (known without hydration — descriptor data).
    fn shard_len(&self, shard: usize) -> usize;

    /// Materialize one shard's data.
    fn hydrate(&self, shard: usize) -> Split;

    /// The shared held-out test split (materialized once).
    fn test(&self) -> &Split;

    fn num_classes(&self) -> usize;
}

/// Model names the built-in synthetic datasets can serve. The classic
/// artifact path accepts anything with a manifest on disk; the sim and
/// fleet paths are limited to these and validate up front.
pub fn is_known_model(model: &str) -> bool {
    matches!(
        model,
        "femnist_cnn" | "cifar_vgg9" | "cifar_resnet18" | "shakespeare_lstm"
    )
}

/// Lazy source matching a model name, with heterogeneous per-shard sizes
/// (the fleet counterpart of [`FlData::for_model`]). Accepts a
/// materialized `Vec<usize>` or a streaming [`ShardSizes`].
pub fn shard_source_for_model(
    model: &str,
    sizes: impl Into<ShardSizes>,
    seed: u64,
) -> Box<dyn ShardSource> {
    let sizes = sizes.into();
    match model {
        "femnist_cnn" => Box::new(synthetic::FemnistShards::new(sizes, seed)),
        "cifar_vgg9" | "cifar_resnet18" => {
            Box::new(synthetic::CifarShards::new(sizes, seed))
        }
        "shakespeare_lstm" => Box::new(shakespeare::ShakespeareShards::new(sizes, 48, seed)),
        other => panic!("unknown model {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_split() -> Split {
        Split {
            xs: XStore::F32((0..6 * 4).map(|i| i as f32).collect()),
            ys: vec![0, 1, 2, 0, 1, 2],
            feature_len: 4,
        }
    }

    #[test]
    fn batch_assembles_rows() {
        let s = tiny_split();
        let b = s.batch(&[2, 0], &[2, 4]);
        match &b.x {
            XData::F32(t) => {
                assert_eq!(t.shape(), &[2, 4]);
                assert_eq!(&t.data()[..4], &[8.0, 9.0, 10.0, 11.0]);
                assert_eq!(&t.data()[4..], &[0.0, 1.0, 2.0, 3.0]);
            }
            _ => panic!("expected f32"),
        }
        assert_eq!(b.y, vec![2, 0]);
    }

    #[test]
    fn sample_batch_handles_tiny_clients() {
        let s = tiny_split();
        let mut rng = Pcg32::new(1, 1);
        let b = s.sample_batch(&mut rng, &[10, 4]); // bigger than split
        assert_eq!(b.y.len(), 10);
    }

    #[test]
    fn histogram_counts() {
        let s = tiny_split();
        assert_eq!(s.class_histogram(3), vec![2, 2, 2]);
    }

    #[test]
    #[should_panic(expected = "batch size mismatch")]
    fn wrong_batch_size_panics() {
        tiny_split().batch(&[0], &[2, 4]);
    }
}
