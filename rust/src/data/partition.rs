//! Sample-to-client partitioners.
//!
//! * [`iid`] — shuffle and deal evenly (the Flower IID split the paper
//!   uses for CIFAR10 on mobile devices).
//! * [`dirichlet`] — per-class Dirichlet(α) proportions (the standard
//!   FjORD/FedML non-IID protocol): smaller α, more skew.
//! * [`by_chunks`] — contiguous chunks (LEAF by-writer / by-role shape).

use crate::util::prng::Pcg32;

/// Evenly deal `n` shuffled samples to `k` clients.
pub fn iid(n: usize, k: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::with_capacity(n / k + 1); k];
    for (i, s) in idx.into_iter().enumerate() {
        out[i % k].push(s);
    }
    out
}

/// Dirichlet(α) label-skew partition: for each class, split its samples
/// across clients with Dirichlet-sampled proportions.
pub fn dirichlet(labels: &[i32], k: usize, alpha: f64, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let num_classes = labels.iter().copied().max().unwrap_or(0) as usize + 1;
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &y) in labels.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    let mut out = vec![Vec::new(); k];
    for class_samples in by_class.iter_mut() {
        rng.shuffle(class_samples);
        let props = rng.dirichlet(alpha, k);
        // cumulative cut points
        let n = class_samples.len();
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (c, p) in props.iter().enumerate() {
            acc += p;
            let end = if c == k - 1 {
                n
            } else {
                ((acc * n as f64).round() as usize).min(n)
            };
            out[c].extend_from_slice(&class_samples[start..end]);
            start = end;
        }
    }
    out
}

/// Contiguous chunks of (roughly) equal size — the shape of LEAF's
/// by-writer / by-role splits over a sequential corpus.
pub fn by_chunks(n: usize, k: usize) -> Vec<Vec<usize>> {
    (0..k)
        .map(|c| {
            let (start, end) = chunk_bounds(n, k, c);
            (start..end).collect()
        })
        .collect()
}

/// Bounds `[start, end)` of chunk `shard` in a `by_chunks(n, k)`
/// partition, computed in O(1) without materializing any index vector —
/// the lazy-hydration primitive for fleet-scale chunk partitions (only
/// the sampled cohort's chunks ever become data).
pub fn chunk_bounds(n: usize, k: usize, shard: usize) -> (usize, usize) {
    assert!(shard < k, "shard {shard} out of range for {k} chunks");
    let base = n / k;
    let extra = n % k;
    // chunks [0, extra) have base+1 elements, the rest have base
    let start = shard * base + shard.min(extra);
    let len = base + usize::from(shard < extra);
    (start, start + len)
}

/// Heterogeneous per-shard example counts for fleet-scale partitions:
/// a lognormal spread around `base` (LEAF-style size skew), deterministic
/// in `seed`. Sizes never drop below 2 so every shard can fill a batch by
/// wrapping.
pub fn lognormal_shard_sizes(k: usize, base: usize, sigma: f32, seed: u64) -> Vec<usize> {
    let mut rng = Pcg32::new(seed ^ 0x51AD5, 0x512E5);
    let cap = base.saturating_mul(6).max(4);
    (0..k)
        .map(|_| {
            let s = (base as f64 * rng.lognormal(sigma) as f64).round() as usize;
            s.clamp(2, cap)
        })
        .collect()
}

/// The *streaming* counterpart of [`lognormal_shard_sizes`]: shard
/// `index`'s size in O(1) with no table — one PRNG stream per index, so
/// any shard is randomly addressable. Same distribution and clamps as
/// the materialized table, but a different draw sequence (the sequential
/// stream above is not per-index addressable); the engine only engages
/// this above its streaming fleet threshold, where no pinned trajectory
/// exists.
pub fn lognormal_shard_size_at(
    index: usize,
    base: usize,
    sigma: f32,
    seed: u64,
) -> usize {
    let mut rng = Pcg32::new(seed ^ 0x51AD5, 0x512E5 ^ ((index as u64) << 1 | 1));
    let cap = base.saturating_mul(6).max(4);
    let s = (base as f64 * rng.lognormal(sigma) as f64).round() as usize;
    s.clamp(2, cap)
}

/// Every sample assigned exactly once — shared invariant of all
/// partitioners (property-tested in rust/tests/properties.rs).
pub fn is_exact_cover(parts: &[Vec<usize>], n: usize) -> bool {
    let mut seen = vec![false; n];
    for p in parts {
        for &i in p {
            if i >= n || seen[i] {
                return false;
            }
            seen[i] = true;
        }
    }
    seen.into_iter().all(|b| b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_is_even_and_complete() {
        let mut rng = Pcg32::new(1, 1);
        let parts = iid(103, 5, &mut rng);
        assert!(is_exact_cover(&parts, 103));
        let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert!(lens.iter().all(|&l| l == 20 || l == 21), "{lens:?}");
    }

    #[test]
    fn dirichlet_complete_and_skewed() {
        let mut rng = Pcg32::new(2, 1);
        let labels: Vec<i32> = (0..500).map(|i| (i % 10) as i32).collect();
        let parts = dirichlet(&labels, 8, 0.3, &mut rng);
        assert!(is_exact_cover(&parts, 500));
        // low alpha should create visibly uneven class ownership
        let mut any_skew = false;
        for p in &parts {
            let mut h = [0usize; 10];
            for &i in p {
                h[labels[i] as usize] += 1;
            }
            let max = *h.iter().max().unwrap() as f64;
            let sum: usize = h.iter().sum();
            if sum > 0 && max / sum as f64 > 0.3 {
                any_skew = true;
            }
        }
        assert!(any_skew, "Dirichlet(0.3) produced near-uniform partitions");
    }

    #[test]
    fn dirichlet_high_alpha_is_nearly_uniform() {
        let mut rng = Pcg32::new(3, 1);
        let labels: Vec<i32> = (0..1000).map(|i| (i % 10) as i32).collect();
        let parts = dirichlet(&labels, 4, 1000.0, &mut rng);
        assert!(is_exact_cover(&parts, 1000));
        for p in &parts {
            assert!((200..=300).contains(&p.len()), "{}", p.len());
        }
    }

    #[test]
    fn chunks_cover_exactly() {
        let parts = by_chunks(10, 3);
        assert!(is_exact_cover(&parts, 10));
        assert_eq!(parts[0], vec![0, 1, 2, 3]);
        assert_eq!(parts[2], vec![7, 8, 9]);
    }

    #[test]
    fn chunk_bounds_match_materialized_chunks() {
        for (n, k) in [(10, 3), (103, 7), (5, 8), (0, 2), (64, 64)] {
            let parts = by_chunks(n, k);
            for (c, part) in parts.iter().enumerate() {
                let (start, end) = chunk_bounds(n, k, c);
                assert_eq!(end - start, part.len(), "n={n} k={k} c={c}");
                if !part.is_empty() {
                    assert_eq!(part[0], start);
                    assert_eq!(*part.last().unwrap(), end - 1);
                }
            }
        }
    }

    #[test]
    fn shard_sizes_are_deterministic_and_spread() {
        let a = lognormal_shard_sizes(1000, 20, 0.45, 7);
        let b = lognormal_shard_sizes(1000, 20, 0.45, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (2..=120).contains(&s)));
        let min = *a.iter().min().unwrap();
        let max = *a.iter().max().unwrap();
        assert!(max > min, "no size heterogeneity");
        let mean = a.iter().sum::<usize>() as f64 / a.len() as f64;
        assert!((10.0..=40.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn streaming_shard_sizes_are_deterministic_and_spread() {
        let a: Vec<usize> =
            (0..1000).map(|i| lognormal_shard_size_at(i, 20, 0.45, 7)).collect();
        let b: Vec<usize> =
            (0..1000).map(|i| lognormal_shard_size_at(i, 20, 0.45, 7)).collect();
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (2..=120).contains(&s)));
        assert!(a.iter().max() > a.iter().min(), "no size heterogeneity");
        let mean = a.iter().sum::<usize>() as f64 / a.len() as f64;
        assert!((10.0..=40.0).contains(&mean), "mean {mean}");
        // random access: any index is addressable without its prefix
        assert_eq!(a[777], lognormal_shard_size_at(777, 20, 0.45, 7));
    }

    #[test]
    fn cover_detector_catches_bad_partitions() {
        assert!(!is_exact_cover(&[vec![0, 0]], 2)); // duplicate
        assert!(!is_exact_cover(&[vec![0]], 2)); // missing
        assert!(!is_exact_cover(&[vec![5]], 2)); // out of range
    }
}
