//! Char-level Shakespeare next-character prediction (LEAF stand-in).
//!
//! A public-domain excerpt is embedded below; clients are "roles":
//! contiguous chunks of the corpus (LEAF partitions by speaking role,
//! which is likewise contiguous text per client). The task matches the
//! paper's: predict the character following an 80-char (here `seq_len`)
//! window.

use super::{partition, FlData, ShardSizes, ShardSource, Split, XStore};
use crate::util::prng::Pcg32;

/// Fixed 80-symbol vocabulary (matches model.py VOCAB). Unknown chars map
/// to the space at index 0.
pub const ALPHABET: &str =
    " abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789.,:;'!?-()[]\"&\n";

/// Embedded public-domain corpus (famous soliloquies + sonnets).
pub const CORPUS: &str = r#"To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;
Tomorrow, and tomorrow, and tomorrow,
Creeps in this petty pace from day to day
To the last syllable of recorded time,
And all our yesterdays have lighted fools
The way to dusty death. Out, out, brief candle!
Life's but a walking shadow, a poor player
That struts and frets his hour upon the stage
And then is heard no more: it is a tale
Told by an idiot, full of sound and fury,
Signifying nothing.
Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date:
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade
Nor lose possession of that fair thou owest;
Nor shall Death brag thou wander'st in his shade,
When in eternal lines to time thou growest:
So long as men can breathe or eyes can see,
So long lives this and this gives life to thee.
Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments;
Our stern alarums changed to merry meetings,
Our dreadful marches to delightful measures.
Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.
Here, under leave of Brutus and the rest--
For Brutus is an honourable man;
So are they all, all honourable men--
Come I to speak in Caesar's funeral.
He was my friend, faithful and just to me:
But Brutus says he was ambitious;
And Brutus is an honourable man.
But soft, what light through yonder window breaks?
It is the east, and Juliet is the sun.
Arise, fair sun, and kill the envious moon,
Who is already sick and pale with grief,
That thou her maid art far more fair than she.
The quality of mercy is not strain'd,
It droppeth as the gentle rain from heaven
Upon the place beneath: it is twice blest;
It blesseth him that gives and him that takes:
'Tis mightiest in the mightiest: it becomes
The throned monarch better than his crown;
His sceptre shows the force of temporal power,
The attribute to awe and majesty,
Wherein doth sit the dread and fear of kings;
But mercy is above this sceptred sway;
It is enthroned in the hearts of kings,
It is an attribute to God himself;
And earthly power doth then show likest God's
When mercy seasons justice. All the world's a stage,
And all the men and women merely players:
They have their exits and their entrances;
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms.
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school. And then the lover,
Sighing like furnace, with a woeful ballad
Made to his mistress' eyebrow. Then a soldier,
Full of strange oaths and bearded like the pard,
Jealous in honour, sudden and quick in quarrel,
Seeking the bubble reputation
Even in the cannon's mouth. And then the justice,
In fair round belly with good capon lined,
With eyes severe and beard of formal cut,
Full of wise saws and modern instances;
And so he plays his part.
"#;

/// Char -> token id over [`ALPHABET`] (unknown -> 0).
pub fn encode(c: char) -> i32 {
    ALPHABET.chars().position(|a| a == c).unwrap_or(0) as i32
}

/// Token id -> char.
pub fn decode(t: i32) -> char {
    ALPHABET.chars().nth(t as usize).unwrap_or(' ')
}

/// Vocabulary size (must stay <= model.py VOCAB = 80).
pub fn vocab_size() -> usize {
    ALPHABET.chars().count()
}

/// Build the federated dataset: contiguous "role" chunks per client;
/// windows of `seq_len` chars predicting the following char.
pub fn load(num_clients: usize, samples_per_client: usize, seq_len: usize, seed: u64) -> FlData {
    let tokens: Vec<i32> = CORPUS.chars().map(encode).collect();
    let n = tokens.len();
    assert!(n > seq_len + 2, "corpus too small");

    let chunks = partition::by_chunks(n, num_clients.max(1));
    let mut clients = Vec::with_capacity(num_clients);
    for (ci, chunk) in chunks.iter().enumerate().take(num_clients) {
        let mut rng = Pcg32::new(seed ^ 0x5AE5, ci as u64 + 1);
        let lo = chunk[0];
        let hi = chunk[chunk.len() - 1];
        let mut xs = Vec::with_capacity(samples_per_client * seq_len);
        let mut ys = Vec::with_capacity(samples_per_client);
        for _ in 0..samples_per_client {
            // windows may extend past the chunk edge into the corpus tail —
            // roles share scene context in LEAF too
            let max_start = (hi.min(n - seq_len - 2)).max(lo);
            let start = lo + rng.below_usize((max_start - lo).max(1));
            let start = start.min(n - seq_len - 1);
            xs.extend(tokens[start..start + seq_len].iter());
            ys.push(tokens[start + seq_len]);
        }
        clients.push(Split {
            xs: XStore::I32(xs),
            ys,
            feature_len: seq_len,
        });
    }

    // test: evenly spaced windows over the whole corpus
    let test_n = (num_clients * samples_per_client / 5).clamp(32, 1000);
    let mut xs = Vec::with_capacity(test_n * seq_len);
    let mut ys = Vec::with_capacity(test_n);
    let stride = ((n - seq_len - 1) / test_n).max(1);
    for i in 0..test_n {
        let start = (i * stride) % (n - seq_len - 1);
        xs.extend(tokens[start..start + seq_len].iter());
        ys.push(tokens[start + seq_len]);
    }

    FlData {
        clients,
        test: Split {
            xs: XStore::I32(xs),
            ys,
            feature_len: seq_len,
        },
        num_classes: 80,
    }
}

/// Lazy Shakespeare "role" shards for the fleet-scale path: the corpus
/// tokens are encoded once; each shard's windows render on demand from a
/// contiguous chunk ([`partition::chunk_bounds`], O(1) per shard). With
/// more shards than usable chunks (a 50k fleet over a small corpus),
/// shards cycle through the chunk ring — many "roles" can read the same
/// scene, as LEAF's by-role split also allows.
pub struct ShakespeareShards {
    tokens: Vec<i32>,
    sizes: ShardSizes,
    seq_len: usize,
    /// number of distinct chunks the corpus supports
    ring: usize,
    seed: u64,
    test: Split,
}

impl ShakespeareShards {
    pub fn new(sizes: impl Into<ShardSizes>, seq_len: usize, seed: u64) -> Self {
        let sizes = sizes.into();
        let tokens: Vec<i32> = CORPUS.chars().map(encode).collect();
        let n = tokens.len();
        assert!(n > seq_len + 2, "corpus too small");
        // each chunk should hold at least a couple of window starts
        let ring = (n / (seq_len / 2).max(8)).max(1).min(sizes.len().max(1));

        let total: usize = sizes.total();
        let test_n = (total / 5).clamp(32, 500);
        let mut xs = Vec::with_capacity(test_n * seq_len);
        let mut ys = Vec::with_capacity(test_n);
        let stride = ((n - seq_len - 1) / test_n).max(1);
        for i in 0..test_n {
            let start = (i * stride) % (n - seq_len - 1);
            xs.extend(tokens[start..start + seq_len].iter());
            ys.push(tokens[start + seq_len]);
        }
        let test = Split {
            xs: XStore::I32(xs),
            ys,
            feature_len: seq_len,
        };
        Self {
            tokens,
            sizes,
            seq_len,
            ring,
            seed,
            test,
        }
    }
}

impl ShardSource for ShakespeareShards {
    fn num_shards(&self) -> usize {
        self.sizes.len()
    }

    fn shard_len(&self, shard: usize) -> usize {
        self.sizes.get(shard)
    }

    fn hydrate(&self, shard: usize) -> Split {
        let n = self.tokens.len();
        let seq_len = self.seq_len;
        let (lo, hi_excl) = partition::chunk_bounds(n, self.ring, shard % self.ring);
        let lo = lo.min(n - seq_len - 2);
        let hi = hi_excl.saturating_sub(1).max(lo);
        let samples = self.sizes.get(shard);
        let mut rng = Pcg32::new(self.seed ^ 0x5AE5_F1, shard as u64 + 1);
        let mut xs = Vec::with_capacity(samples * seq_len);
        let mut ys = Vec::with_capacity(samples);
        for _ in 0..samples {
            let max_start = (hi.min(n - seq_len - 2)).max(lo);
            let start = lo + rng.below_usize((max_start - lo).max(1));
            let start = start.min(n - seq_len - 1);
            xs.extend(self.tokens[start..start + seq_len].iter());
            ys.push(self.tokens[start + seq_len]);
        }
        Split {
            xs: XStore::I32(xs),
            ys,
            feature_len: seq_len,
        }
    }

    fn test(&self) -> &Split {
        &self.test
    }

    fn num_classes(&self) -> usize {
        80
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_model() {
        assert!(vocab_size() <= 80, "vocab {} > 80", vocab_size());
    }

    #[test]
    fn encode_decode_round_trip() {
        for c in "Hello, World! 'tis".chars() {
            assert_eq!(decode(encode(c)), c);
        }
        // unknown maps to space
        assert_eq!(encode('@'), 0);
    }

    #[test]
    fn tokens_in_range() {
        for c in CORPUS.chars() {
            let t = encode(c);
            assert!((0..80).contains(&t), "char {c:?} -> {t}");
        }
    }

    #[test]
    fn load_shapes() {
        let d = load(5, 20, 48, 9);
        assert_eq!(d.num_clients(), 5);
        for c in &d.clients {
            assert_eq!(c.len(), 20);
            assert_eq!(c.feature_len, 48);
            if let XStore::I32(x) = &c.xs {
                assert_eq!(x.len(), 20 * 48);
            }
        }
    }

    #[test]
    fn clients_get_different_text() {
        let d = load(4, 10, 32, 1);
        let (a, b) = (&d.clients[0].xs, &d.clients[3].xs);
        match (a, b) {
            (XStore::I32(x), XStore::I32(y)) => assert_ne!(x, y),
            _ => panic!(),
        }
    }

    #[test]
    fn deterministic() {
        let a = load(3, 10, 24, 5);
        let b = load(3, 10, 24, 5);
        match (&a.clients[2].xs, &b.clients[2].xs) {
            (XStore::I32(x), XStore::I32(y)) => assert_eq!(x, y),
            _ => panic!(),
        }
    }

    #[test]
    fn lazy_shards_hydrate_valid_windows_even_past_the_corpus() {
        // more shards than the corpus has distinct chunks: the ring cycles
        let src = ShakespeareShards::new(vec![6; 10_000], 48, 3);
        assert_eq!(src.num_shards(), 10_000);
        for &shard in &[0usize, 1, 137, 9_999] {
            let s = src.hydrate(shard);
            assert_eq!(s.len(), 6);
            assert_eq!(s.feature_len, 48);
            if let XStore::I32(x) = &s.xs {
                assert!(x.iter().all(|&t| (0..80).contains(&t)));
            }
            assert!(s.ys.iter().all(|&t| (0..80).contains(&t)));
        }
        // replayable
        let a = src.hydrate(42);
        let b = src.hydrate(42);
        assert_eq!(a.ys, b.ys);
        assert!(!src.test().is_empty());
        assert_eq!(src.num_classes(), 80);
    }
}
