//! Deterministic synthetic image datasets (FEMNIST / CIFAR10 stand-ins).
//!
//! Each class is a reproducible template (a mixture of Gaussian blobs for
//! FEMNIST, a colored sinusoidal texture for CIFAR); every example is its
//! class template pushed through a per-writer style transform plus pixel
//! noise. The result is genuinely learnable by the paper's models while
//! exhibiting LEAF-style non-IID structure (writers own class subsets and
//! styles).

use super::{FlData, ShardSizes, ShardSource, Split, XStore};
use crate::util::prng::Pcg32;

pub const FEMNIST_CLASSES: usize = 62;
pub const FEMNIST_SIDE: usize = 28;
pub const CIFAR_CLASSES: usize = 10;
pub const CIFAR_SIDE: usize = 32;

/// Per-class template: K Gaussian blobs with deterministic positions.
fn femnist_template(class: usize) -> Vec<(f32, f32, f32, f32)> {
    // (cx, cy, sigma, amplitude) per blob — seeded only by the class id
    let mut rng = Pcg32::new(0xFE_0001 + class as u64, 17);
    let blobs = 3 + class % 4;
    (0..blobs)
        .map(|_| {
            (
                rng.uniform(5.0, 23.0),
                rng.uniform(5.0, 23.0),
                rng.uniform(1.5, 4.0),
                rng.uniform(0.6, 1.0),
            )
        })
        .collect()
}

fn render_femnist(
    template: &[(f32, f32, f32, f32)],
    dx: f32,
    dy: f32,
    scale: f32,
    noise: f32,
    rng: &mut Pcg32,
    out: &mut Vec<f32>,
) {
    for y in 0..FEMNIST_SIDE {
        for x in 0..FEMNIST_SIDE {
            let mut v = 0.0f32;
            for &(cx, cy, sigma, amp) in template {
                let ddx = x as f32 - (cx * scale + dx);
                let ddy = y as f32 - (cy * scale + dy);
                let s2 = 2.0 * sigma * sigma * scale;
                v += amp * (-(ddx * ddx + ddy * ddy) / s2).exp();
            }
            v += noise * rng.normal();
            out.push(v.clamp(0.0, 1.5));
        }
    }
}

/// One writer's shard, generated independently of every other shard
/// (each writer owns its own PRNG stream) — the unit of lazy hydration.
fn femnist_client_split(
    templates: &[Vec<(f32, f32, f32, f32)>],
    c: usize,
    samples: usize,
    seed: u64,
) -> Split {
    let feature_len = FEMNIST_SIDE * FEMNIST_SIDE;
    let mut rng = Pcg32::new(seed ^ 0xFE31, c as u64 + 1);
    // writer's class subset (non-IID): 16..24 classes
    let k = 16 + rng.below_usize(9);
    let classes = rng.sample_indices(FEMNIST_CLASSES, k);
    // writer style
    let (dx, dy) = (rng.uniform(-2.5, 2.5), rng.uniform(-2.5, 2.5));
    let scale = rng.uniform(0.85, 1.15);

    let mut xs = Vec::with_capacity(samples * feature_len);
    let mut ys = Vec::with_capacity(samples);
    for _ in 0..samples {
        let class = classes[rng.below_usize(classes.len())];
        render_femnist(&templates[class], dx, dy, scale, 0.15, &mut rng, &mut xs);
        ys.push(class as i32);
    }
    Split {
        xs: XStore::F32(xs),
        ys,
        feature_len,
    }
}

/// Style-neutral balanced test pool of `test_n` examples.
fn femnist_test(
    templates: &[Vec<(f32, f32, f32, f32)>],
    test_n: usize,
    seed: u64,
) -> Split {
    let feature_len = FEMNIST_SIDE * FEMNIST_SIDE;
    let mut rng = Pcg32::new(seed ^ 0xFE32, 0);
    let mut xs = Vec::with_capacity(test_n * feature_len);
    let mut ys = Vec::with_capacity(test_n);
    for i in 0..test_n {
        let class = i % FEMNIST_CLASSES;
        render_femnist(&templates[class], 0.0, 0.0, 1.0, 0.15, &mut rng, &mut xs);
        ys.push(class as i32);
    }
    Split {
        xs: XStore::F32(xs),
        ys,
        feature_len,
    }
}

/// LEAF-style by-writer FEMNIST: each client is a "writer" with a class
/// subset (~20 of 62) and a persistent style (shift/scale); the test set
/// is style-neutral.
pub fn femnist(num_clients: usize, samples_per_client: usize, seed: u64) -> FlData {
    let templates: Vec<_> = (0..FEMNIST_CLASSES).map(femnist_template).collect();
    let clients = (0..num_clients)
        .map(|c| femnist_client_split(&templates, c, samples_per_client, seed))
        .collect();
    let test_n = (num_clients * samples_per_client / 5).clamp(FEMNIST_CLASSES, 2000);
    FlData {
        clients,
        test: femnist_test(&templates, test_n, seed),
        num_classes: FEMNIST_CLASSES,
    }
}

/// Lazy FEMNIST shards for the fleet-scale path: per-writer generation is
/// seed-independent across writers, so a shard renders on demand and only
/// the sampled cohort's pixels are ever resident.
pub struct FemnistShards {
    templates: Vec<Vec<(f32, f32, f32, f32)>>,
    sizes: ShardSizes,
    seed: u64,
    test: Split,
}

impl FemnistShards {
    pub fn new(sizes: impl Into<ShardSizes>, seed: u64) -> Self {
        let sizes = sizes.into();
        let templates: Vec<_> = (0..FEMNIST_CLASSES).map(femnist_template).collect();
        let total: usize = sizes.total();
        // smaller cap than the eager path: the fleet test pool is a smoke
        // gauge, not an accuracy benchmark
        let test_n = (total / 5).clamp(FEMNIST_CLASSES, 800);
        let test = femnist_test(&templates, test_n, seed);
        Self {
            templates,
            sizes,
            seed,
            test,
        }
    }
}

impl ShardSource for FemnistShards {
    fn num_shards(&self) -> usize {
        self.sizes.len()
    }

    fn shard_len(&self, shard: usize) -> usize {
        self.sizes.get(shard)
    }

    fn hydrate(&self, shard: usize) -> Split {
        femnist_client_split(&self.templates, shard, self.sizes.get(shard), self.seed)
    }

    fn test(&self) -> &Split {
        &self.test
    }

    fn num_classes(&self) -> usize {
        FEMNIST_CLASSES
    }
}

/// Per-class CIFAR texture parameters.
fn cifar_template(class: usize) -> [(f32, f32, f32); 3] {
    let mut rng = Pcg32::new(0xC1FA_0001 + class as u64, 23);
    // per channel: (fx, fy, phase)
    [
        (rng.uniform(0.15, 0.8), rng.uniform(0.15, 0.8), rng.uniform(0.0, 6.28)),
        (rng.uniform(0.15, 0.8), rng.uniform(0.15, 0.8), rng.uniform(0.0, 6.28)),
        (rng.uniform(0.15, 0.8), rng.uniform(0.15, 0.8), rng.uniform(0.0, 6.28)),
    ]
}

fn render_cifar(class: usize, noise: f32, rng: &mut Pcg32, out: &mut Vec<f32>) {
    let t = cifar_template(class);
    let jx = rng.uniform(-1.0, 1.0);
    let jy = rng.uniform(-1.0, 1.0);
    for y in 0..CIFAR_SIDE {
        for x in 0..CIFAR_SIDE {
            for (fx, fy, ph) in t {
                let v = 0.5
                    + 0.4 * ((x as f32 + jx) * fx + (y as f32 + jy) * fy + ph).sin()
                    + noise * rng.normal();
                out.push(v.clamp(0.0, 1.0));
            }
        }
    }
}

/// CIFAR10 stand-in. `iid=true` mirrors the Flower IID partition used by
/// the paper's mobile experiments; `iid=false` uses Dirichlet(0.5) class
/// skew (FjORD-style) via [`super::partition::dirichlet`].
pub fn cifar10(num_clients: usize, samples_per_client: usize, seed: u64, iid: bool) -> FlData {
    let feature_len = CIFAR_SIDE * CIFAR_SIDE * 3;
    let total = num_clients * samples_per_client;

    // build a global pool, then partition
    let mut rng = Pcg32::new(seed ^ 0xC1FA, 1);
    let mut xs = Vec::with_capacity(total * feature_len);
    let mut ys = Vec::with_capacity(total);
    for i in 0..total {
        let class = i % CIFAR_CLASSES;
        render_cifar(class, 0.1, &mut rng, &mut xs);
        ys.push(class as i32);
    }

    let assignment = if iid {
        super::partition::iid(total, num_clients, &mut rng)
    } else {
        super::partition::dirichlet(&ys, num_clients, 0.5, &mut rng)
    };

    let mut clients = Vec::with_capacity(num_clients);
    for idxs in &assignment {
        let mut cx = Vec::with_capacity(idxs.len() * feature_len);
        let mut cy = Vec::with_capacity(idxs.len());
        for &i in idxs {
            cx.extend_from_slice(&xs[i * feature_len..(i + 1) * feature_len]);
            cy.push(ys[i]);
        }
        clients.push(Split {
            xs: XStore::F32(cx),
            ys: cy,
            feature_len,
        });
    }

    // fresh test pool
    let test_n = (total / 5).clamp(CIFAR_CLASSES, 1000);
    let mut tx = Vec::with_capacity(test_n * feature_len);
    let mut ty = Vec::with_capacity(test_n);
    for i in 0..test_n {
        let class = i % CIFAR_CLASSES;
        render_cifar(class, 0.1, &mut rng, &mut tx);
        ty.push(class as i32);
    }

    FlData {
        clients,
        test: Split {
            xs: XStore::F32(tx),
            ys: ty,
            feature_len,
        },
        num_classes: CIFAR_CLASSES,
    }
}

/// Lazy CIFAR shards for the fleet-scale path. The eager [`cifar10`]
/// builds a global pool and partitions it — inherently O(fleet) memory —
/// so the fleet regime switches to per-client generation: each client
/// renders from its own PRNG stream with a 6-of-10 class subset
/// (Dirichlet-like label skew without a shared pool).
pub struct CifarShards {
    sizes: ShardSizes,
    seed: u64,
    test: Split,
}

impl CifarShards {
    pub fn new(sizes: impl Into<ShardSizes>, seed: u64) -> Self {
        let sizes = sizes.into();
        let feature_len = CIFAR_SIDE * CIFAR_SIDE * 3;
        let total: usize = sizes.total();
        let test_n = (total / 5).clamp(CIFAR_CLASSES, 500);
        let mut rng = Pcg32::new(seed ^ 0xC1FA_7E57, 1);
        let mut xs = Vec::with_capacity(test_n * feature_len);
        let mut ys = Vec::with_capacity(test_n);
        for i in 0..test_n {
            let class = i % CIFAR_CLASSES;
            render_cifar(class, 0.1, &mut rng, &mut xs);
            ys.push(class as i32);
        }
        Self {
            sizes,
            seed,
            test: Split {
                xs: XStore::F32(xs),
                ys,
                feature_len,
            },
        }
    }
}

impl ShardSource for CifarShards {
    fn num_shards(&self) -> usize {
        self.sizes.len()
    }

    fn shard_len(&self, shard: usize) -> usize {
        self.sizes.get(shard)
    }

    fn hydrate(&self, shard: usize) -> Split {
        let feature_len = CIFAR_SIDE * CIFAR_SIDE * 3;
        let samples = self.sizes.get(shard);
        let mut rng = Pcg32::new(self.seed ^ 0xC1FA_0D, shard as u64 + 1);
        let classes = rng.sample_indices(CIFAR_CLASSES, 6);
        let mut xs = Vec::with_capacity(samples * feature_len);
        let mut ys = Vec::with_capacity(samples);
        for _ in 0..samples {
            let class = classes[rng.below_usize(classes.len())];
            render_cifar(class, 0.1, &mut rng, &mut xs);
            ys.push(class as i32);
        }
        Split {
            xs: XStore::F32(xs),
            ys,
            feature_len,
        }
    }

    fn test(&self) -> &Split {
        &self.test
    }

    fn num_classes(&self) -> usize {
        CIFAR_CLASSES
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn femnist_shapes_and_determinism() {
        let a = femnist(3, 10, 42);
        let b = femnist(3, 10, 42);
        assert_eq!(a.num_clients(), 3);
        assert_eq!(a.clients[0].len(), 10);
        assert_eq!(a.clients[0].feature_len, 784);
        match (&a.clients[1].xs, &b.clients[1].xs) {
            (XStore::F32(x), XStore::F32(y)) => assert_eq!(x, y),
            _ => panic!(),
        }
        assert_eq!(a.num_classes, 62);
    }

    #[test]
    fn femnist_clients_are_non_iid() {
        let d = femnist(4, 60, 7);
        // each writer covers a strict subset of classes
        for c in &d.clients {
            let h = c.class_histogram(62);
            let covered = h.iter().filter(|&&n| n > 0).count();
            assert!(covered < 40, "writer covers {covered} classes — too IID");
        }
        // but different writers cover different subsets
        let h0 = d.clients[0].class_histogram(62);
        let h1 = d.clients[1].class_histogram(62);
        assert_ne!(h0, h1);
    }

    #[test]
    fn femnist_pixels_in_range() {
        let d = femnist(1, 5, 3);
        if let XStore::F32(x) = &d.clients[0].xs {
            assert!(x.iter().all(|&v| (0.0..=1.5).contains(&v)));
        }
    }

    #[test]
    fn cifar_iid_partition_is_even() {
        let d = cifar10(5, 20, 11, true);
        assert_eq!(d.num_clients(), 5);
        for c in &d.clients {
            assert_eq!(c.len(), 20);
            assert_eq!(c.feature_len, 32 * 32 * 3);
        }
        assert_eq!(d.total_examples(), 100);
    }

    #[test]
    fn cifar_noniid_partition_covers_all() {
        let d = cifar10(6, 30, 13, false);
        assert_eq!(d.total_examples(), 180);
        // dirichlet split is uneven but complete
        let lens: Vec<usize> = d.clients.iter().map(|c| c.len()).collect();
        assert!(lens.iter().any(|&l| l != 30), "{lens:?}");
    }

    #[test]
    fn lazy_femnist_shards_match_the_eager_build() {
        // hydrate(c) must reproduce the exact split femnist() materializes
        let eager = femnist(4, 12, 77);
        let src = FemnistShards::new(vec![12; 4], 77);
        assert_eq!(src.num_shards(), 4);
        for c in 0..4 {
            let lazy = src.hydrate(c);
            assert_eq!(lazy.ys, eager.clients[c].ys, "client {c}");
            match (&lazy.xs, &eager.clients[c].xs) {
                (XStore::F32(a), XStore::F32(b)) => assert_eq!(a, b, "client {c}"),
                _ => panic!(),
            }
        }
        assert_eq!(src.num_classes(), 62);
    }

    #[test]
    fn lazy_shards_honor_heterogeneous_sizes() {
        let sizes = vec![3, 9, 5];
        let fem = FemnistShards::new(sizes.clone(), 5);
        let cif = CifarShards::new(sizes.clone(), 5);
        for (c, &s) in sizes.iter().enumerate() {
            assert_eq!(fem.shard_len(c), s);
            assert_eq!(fem.hydrate(c).len(), s);
            assert_eq!(cif.shard_len(c), s);
            assert_eq!(cif.hydrate(c).len(), s);
        }
        assert!(!fem.test().is_empty());
        assert!(!cif.test().is_empty());
    }

    #[test]
    fn lazy_cifar_shards_are_deterministic_and_skewed() {
        let a = CifarShards::new(vec![30; 2], 9).hydrate(1);
        let b = CifarShards::new(vec![30; 2], 9).hydrate(1);
        assert_eq!(a.ys, b.ys);
        // 6-of-10 class subset: some class must be absent
        let h = a.class_histogram(10);
        assert!(h.iter().any(|&c| c == 0), "no label skew: {h:?}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // template distance between two classes must exceed noise floor
        let mut rng = Pcg32::new(1, 1);
        let mut a = Vec::new();
        render_cifar(0, 0.0, &mut rng, &mut a);
        let mut b = Vec::new();
        render_cifar(1, 0.0, &mut rng, &mut b);
        let dist: f32 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt();
        assert!(dist > 5.0, "class templates too similar: {dist}");
    }
}
