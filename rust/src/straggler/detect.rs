//! Straggler detection and sub-model sizing (Algorithm 1 lines 18-22).
//!
//! From measured end-to-end latencies the server marks the slowest
//! fraction as stragglers, sets `T_target` to the next-slowest
//! (non-straggler) client's time — the paper's choice that minimizes
//! non-straggler idle time — and sizes each straggler's sub-model as the
//! available rate closest to `1/speedup` (Appendix A.3 linearity).

/// Result of one detection pass.
#[derive(Clone, Debug, PartialEq)]
pub struct Detection {
    /// client ids flagged as stragglers, slowest first
    pub stragglers: Vec<usize>,
    /// the target time: slowest non-straggler latency
    pub t_target: f64,
    /// per-straggler required speedup (aligned with `stragglers`)
    pub speedups: Vec<f64>,
    /// per-straggler keep-rate r (aligned with `stragglers`)
    pub rates: Vec<f64>,
}

/// The paper's pre-defined sub-model sizes (§7: "FLuID currently only
/// uses pre-defined sub-model sizes").
pub const DEFAULT_RATES: &[f64] = &[0.5, 0.65, 0.75, 0.85, 0.95, 1.0];

/// The engine's detection margin: a client is only flagged when it runs
/// at least this much slower than `T_target` (shared by every
/// mitigation policy so detection stays comparable across the zoo).
pub const DETECT_MARGIN: f64 = 0.02;

/// Snap a desired keep-rate to the closest available sub-model size.
pub fn snap_rate(desired: f64, available: &[f64]) -> f64 {
    let mut best = 1.0;
    let mut best_d = f64::INFINITY;
    for &r in available {
        let d = (r - desired).abs();
        if d < best_d {
            best_d = d;
            best = r;
        }
    }
    best
}

/// Detect stragglers from end-to-end latencies.
///
/// * `latencies[i]` — client i's last-round latency.
/// * `straggler_fraction` — how much of the fleet may be treated as
///   stragglers (paper: 1 of 5 on mobile, 20% in the scale study).
/// * `margin` — a client is only a straggler if it is at least this much
///   slower than `T_target` (avoids flapping when times are tied).
/// * `available` — the sub-model size menu.
pub fn detect_stragglers(
    latencies: &[f64],
    straggler_fraction: f64,
    margin: f64,
    available: &[f64],
) -> Detection {
    let n = latencies.len();
    if n == 0 {
        return Detection {
            stragglers: vec![],
            t_target: 0.0,
            speedups: vec![],
            rates: vec![],
        };
    }
    // Non-finite latencies (a NaN or ±inf propagated from a broken
    // measurement) are excluded up front: a NaN used to panic the
    // `partial_cmp().unwrap()` sort mid-round, and an all-inf profile
    // would make every speedup meaningless. For all-finite inputs this
    // path is unchanged bit-for-bit.
    let mut order: Vec<usize> = (0..n).filter(|&c| latencies[c].is_finite()).collect();
    if order.is_empty() {
        return Detection {
            stragglers: vec![],
            t_target: 0.0,
            speedups: vec![],
            rates: vec![],
        };
    }
    let max_stragglers =
        ((order.len() as f64 * straggler_fraction).floor() as usize).min(order.len() - 1);

    // order clients slowest-first (total_cmp: total order even if a
    // non-finite value ever slipped through)
    order.sort_by(|&a, &b| latencies[b].total_cmp(&latencies[a]));

    // T_target = slowest latency outside the straggler candidate set
    let t_target = latencies[order[max_stragglers.min(order.len() - 1)]];

    let mut stragglers = Vec::new();
    let mut speedups = Vec::new();
    let mut rates = Vec::new();
    for &c in order.iter().take(max_stragglers) {
        let speedup = latencies[c] / t_target;
        if !speedup.is_finite() || speedup <= 1.0 + margin {
            continue; // not meaningfully slower than the target
        }
        stragglers.push(c);
        speedups.push(speedup);
        rates.push(snap_rate(1.0 / speedup, available));
    }
    Detection {
        stragglers,
        t_target,
        speedups,
        rates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snap_picks_closest() {
        assert_eq!(snap_rate(0.78, DEFAULT_RATES), 0.75);
        assert_eq!(snap_rate(0.81, DEFAULT_RATES), 0.85);
        assert_eq!(snap_rate(0.97, DEFAULT_RATES), 0.95);
        assert_eq!(snap_rate(0.99, DEFAULT_RATES), 1.0);
        assert_eq!(snap_rate(0.2, DEFAULT_RATES), 0.5);
    }

    #[test]
    fn five_clients_one_straggler() {
        // the mobile-fleet shape: Pixel 3 ~25% slower than S9
        let lat = [62.0, 66.0, 72.0, 80.0, 100.0];
        let d = detect_stragglers(&lat, 0.2, 0.02, DEFAULT_RATES);
        assert_eq!(d.stragglers, vec![4]);
        assert_eq!(d.t_target, 80.0);
        assert!((d.speedups[0] - 1.25).abs() < 1e-9);
        // 1/1.25 = 0.8 -> snaps to 0.85 or 0.75; 0.8 is equidistant,
        // first-closest wins deterministically
        assert!(d.rates[0] == 0.75 || d.rates[0] == 0.85);
    }

    #[test]
    fn homogeneous_fleet_has_no_stragglers() {
        let lat = [50.0, 50.2, 49.9, 50.1, 50.0];
        let d = detect_stragglers(&lat, 0.2, 0.05, DEFAULT_RATES);
        assert!(d.stragglers.is_empty());
    }

    #[test]
    fn twenty_percent_of_large_fleet() {
        let mut lat: Vec<f64> = (0..100).map(|i| 50.0 + i as f64 * 0.01).collect();
        // make the top 20 clearly slower
        for l in lat.iter_mut().skip(80) {
            *l *= 1.5;
        }
        let d = detect_stragglers(&lat, 0.2, 0.02, DEFAULT_RATES);
        assert_eq!(d.stragglers.len(), 20);
        // slowest first
        assert!(lat[d.stragglers[0]] >= lat[d.stragglers[19]]);
        // all rates < 1
        assert!(d.rates.iter().all(|&r| r < 1.0));
    }

    #[test]
    fn target_is_next_slowest() {
        let lat = [10.0, 20.0, 30.0, 40.0, 100.0];
        let d = detect_stragglers(&lat, 0.2, 0.02, DEFAULT_RATES);
        assert_eq!(d.t_target, 40.0);
        assert_eq!(d.stragglers, vec![4]);
        assert_eq!(d.speedups[0], 2.5);
        assert_eq!(d.rates[0], 0.5); // 1/2.5 = 0.4 -> closest is 0.5
    }

    #[test]
    fn empty_input() {
        let d = detect_stragglers(&[], 0.2, 0.02, DEFAULT_RATES);
        assert!(d.stragglers.is_empty());
    }

    #[test]
    fn nan_and_inf_latencies_never_panic_detection() {
        // a broken measurement must not panic the server mid-round, and
        // must not steal the straggler slot from a real straggler
        let lat = [62.0, 66.0, 72.0, 80.0, 100.0, f64::NAN];
        let d = detect_stragglers(&lat, 0.2, 0.02, DEFAULT_RATES);
        assert_eq!(d.stragglers, vec![4]);
        assert_eq!(d.t_target, 80.0);
        assert!(d.rates.iter().all(|r| r.is_finite()));

        let lat = [62.0, f64::INFINITY, 72.0, 80.0, 100.0, f64::NEG_INFINITY];
        let d = detect_stragglers(&lat, 0.25, 0.02, DEFAULT_RATES);
        assert_eq!(d.stragglers, vec![4]);
        assert!(d.speedups.iter().all(|s| s.is_finite()));

        // the all-garbage fleet degrades to "no stragglers", not a panic
        let d = detect_stragglers(&[f64::NAN, f64::INFINITY, f64::NAN], 0.5, 0.02, DEFAULT_RATES);
        assert!(d.stragglers.is_empty());
        assert_eq!(d.t_target, 0.0);
    }
}
