//! Device profiles — the five Table-1 phones plus synthetic fleets.
//!
//! Base per-epoch training times are calibrated to Figure 2a's shape:
//! up to ~2x spread between 2018 and 2020 devices, with std deviations of
//! ~0.5 s (FEMNIST), ~22 s (CIFAR10) and ~21 s (Shakespeare). The
//! slowest device (Pixel 3) sits 10-32% above the next slowest, matching
//! §6.1 "the straggler's training time is typically 10% to 32% longer
//! than the target time".

use crate::util::prng::Pcg32;

/// Static description of one client device.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    pub year: u32,
    /// seconds per local epoch at r = 1.0, per model family
    pub base_femnist: f64,
    pub base_cifar: f64,
    pub base_shakespeare: f64,
    /// network bandwidth in MB/s (up + down combined model)
    pub bandwidth_mbps: f64,
}

impl DeviceProfile {
    /// Base epoch time for a model name (manifest names).
    pub fn base_time(&self, model: &str) -> f64 {
        match model {
            "femnist_cnn" => self.base_femnist,
            "cifar_vgg9" => self.base_cifar,
            "cifar_resnet18" => self.base_cifar * 1.6, // deeper model
            "shakespeare_lstm" => self.base_shakespeare,
            _ => self.base_cifar,
        }
    }
}

/// The five real phones of Table 1.
pub fn mobile_fleet() -> Vec<DeviceProfile> {
    let mk = |name: &str, year, f, c, s, bw| DeviceProfile {
        name: name.to_string(),
        year,
        base_femnist: f,
        base_cifar: c,
        base_shakespeare: s,
        bandwidth_mbps: bw,
    };
    vec![
        mk("LG Velvet 5G", 2020, 2.0, 55.0, 60.0, 12.0),
        mk("Google Pixel 4", 2019, 2.2, 60.0, 65.0, 11.0),
        mk("Samsung Galaxy S10", 2019, 2.4, 66.0, 72.0, 10.0),
        mk("Samsung Galaxy S9", 2018, 2.8, 80.0, 90.0, 9.0),
        mk("Google Pixel 3", 2018, 3.2, 100.0, 112.0, 8.0),
    ]
}

/// A synthetic heterogeneous fleet of `n` devices for the scalability
/// studies (§6.1 "simulated clients ranging from 50 to 100", A.6 1000).
/// Speeds follow a lognormal spread around the mobile fleet's mid-range;
/// the slowest tail plays the straggler role.
pub fn synthetic_fleet(n: usize, seed: u64) -> Vec<DeviceProfile> {
    let mut rng = Pcg32::new(seed, 0xDE5);
    (0..n)
        .map(|i| {
            let slow = rng.lognormal(0.35) as f64; // median 1.0
            DeviceProfile {
                name: format!("sim-{i:04}"),
                year: 2018 + (i % 3) as u32,
                base_femnist: 2.4 * slow,
                base_cifar: 68.0 * slow,
                base_shakespeare: 75.0 * slow,
                bandwidth_mbps: (10.0 / slow).clamp(2.0, 20.0),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn fleet_matches_fig2a_shape() {
        let fleet = mobile_fleet();
        assert_eq!(fleet.len(), 5);
        let fem: Vec<f64> = fleet.iter().map(|d| d.base_femnist).collect();
        let cif: Vec<f64> = fleet.iter().map(|d| d.base_cifar).collect();
        let shk: Vec<f64> = fleet.iter().map(|d| d.base_shakespeare).collect();
        // paper: std 0.5 / 22 / 21 s (FEMNIST / CIFAR10 / Shakespeare)
        assert!((stats::std_dev(&fem) - 0.5).abs() < 0.15, "{}", stats::std_dev(&fem));
        assert!((stats::std_dev(&cif) - 22.0).abs() < 8.0, "{}", stats::std_dev(&cif));
        assert!((stats::std_dev(&shk) - 21.0).abs() < 8.0, "{}", stats::std_dev(&shk));
        // straggler 10-32% slower than next-slowest
        for xs in [&fem, &cif, &shk] {
            let mut v = (*xs).clone();
            v.sort_by(|a, b| a.total_cmp(b));
            let ratio = v[4] / v[3];
            assert!((1.10..=1.35).contains(&ratio), "ratio {ratio}");
        }
    }

    #[test]
    fn base_time_dispatch() {
        let d = &mobile_fleet()[0];
        assert_eq!(d.base_time("femnist_cnn"), 2.0);
        assert_eq!(d.base_time("cifar_vgg9"), 55.0);
        assert!(d.base_time("cifar_resnet18") > d.base_time("cifar_vgg9"));
        assert_eq!(d.base_time("shakespeare_lstm"), 60.0);
    }

    #[test]
    fn synthetic_fleet_is_heterogeneous_and_deterministic() {
        let a = synthetic_fleet(50, 3);
        let b = synthetic_fleet(50, 3);
        assert_eq!(a.len(), 50);
        assert_eq!(a[17].base_cifar, b[17].base_cifar);
        let times: Vec<f64> = a.iter().map(|d| d.base_cifar).collect();
        let spread = stats::max(&times) / stats::min(&times);
        assert!(spread > 1.5, "fleet too homogeneous: {spread}");
    }
}
