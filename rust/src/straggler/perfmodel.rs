//! Virtual-time performance model.
//!
//! End-to-end client latency per round (paper §5: "End-to-end training
//! includes upload/download latency and communication time"):
//!
//! ```text
//! latency = compute + communication
//! compute = base_epoch_time(model) · (α + (1-α)·r) · load(t) · jitter
//! communication = 2 · model_bytes · comm_fraction(r) / bandwidth
//! ```
//!
//! The `(α + (1-α)·r)` term encodes Appendix A.3's measurement that
//! training time decreases *linearly* with sub-model size and stays
//! within 10% of proportionality — α is the fixed overhead share
//! (default 0.05, keeping the fit inside the paper's 10% envelope).

use super::device::DeviceProfile;
use super::fluctuate::FluctuationSchedule;
use crate::util::prng::Pcg32;

/// Latency model over a device fleet.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub model: String,
    /// fixed-overhead fraction of compute (A.3 linearity intercept)
    pub alpha: f64,
    /// lognormal jitter sigma on compute time (run-to-run variation)
    pub jitter_sigma: f32,
    /// bytes of the full global model (from the manifest)
    pub model_bytes: usize,
    /// local epochs per round
    pub local_epochs: usize,
}

impl PerfModel {
    pub fn new(model: &str, model_bytes: usize) -> Self {
        Self {
            model: model.to_string(),
            alpha: 0.05,
            jitter_sigma: 0.03,
            model_bytes,
            local_epochs: 1,
        }
    }

    /// Compute seconds for one round on `dev` with keep-rate `r` at
    /// progress `t_frac` ∈ [0,1] (for fluctuation lookup).
    pub fn compute_time(
        &self,
        dev: &DeviceProfile,
        client: usize,
        r: f64,
        t_frac: f64,
        sched: &FluctuationSchedule,
        rng: &mut Pcg32,
    ) -> f64 {
        let base = dev.base_time(&self.model) * self.local_epochs as f64;
        let shape = self.alpha + (1.0 - self.alpha) * r.clamp(0.0, 1.0);
        let load = sched.load_multiplier(client, t_frac);
        let jitter = rng.lognormal(self.jitter_sigma) as f64;
        base * shape * load * jitter
    }

    /// Up+down transfer seconds for a sub-model of comm fraction `f`.
    pub fn comm_time(&self, dev: &DeviceProfile, comm_fraction: f64) -> f64 {
        let bytes = 2.0 * self.model_bytes as f64 * comm_fraction.clamp(0.0, 1.0);
        bytes / (dev.bandwidth_mbps * 1e6)
    }

    /// Total end-to-end round latency.
    #[allow(clippy::too_many_arguments)]
    pub fn round_latency(
        &self,
        dev: &DeviceProfile,
        client: usize,
        r: f64,
        comm_fraction: f64,
        t_frac: f64,
        sched: &FluctuationSchedule,
        rng: &mut Pcg32,
    ) -> f64 {
        self.compute_time(dev, client, r, t_frac, sched, rng) + self.comm_time(dev, comm_fraction)
    }

    /// One client's arrival timing for a round, as the engine's event
    /// scheduler consumes it: the *actual* end-to-end latency under the
    /// client's assigned keep-rate, plus the same latency normalized to
    /// `r = 1.0` (what the client would take on the full model).
    ///
    /// Straggler detection must see the normalized number — a straggler
    /// that got a sub-model looks fast the next round and would flap in
    /// and out of the straggler set otherwise. Both draws share the same
    /// jitter stream (cloned PRNG seeded from `round_seed` and the client
    /// id), so the pair differs only by the sub-model terms.
    #[allow(clippy::too_many_arguments)]
    pub fn client_timing(
        &self,
        dev: &DeviceProfile,
        client: usize,
        r: f64,
        comm_fraction: f64,
        t_frac: f64,
        sched: &FluctuationSchedule,
        round_seed: u64,
    ) -> ClientTiming {
        let mut rng = Pcg32::new(round_seed ^ 0x7A7, client as u64);
        let mut rng_full = rng.clone(); // same jitter draw for both
        ClientTiming {
            latency: self.round_latency(dev, client, r, comm_fraction, t_frac, sched, &mut rng),
            full_latency: self
                .round_latency(dev, client, 1.0, 1.0, t_frac, sched, &mut rng_full),
        }
    }
}

/// Per-client round timing: when the update arrives (round-relative
/// virtual seconds) and the full-model-normalized latency that straggler
/// detection profiles.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientTiming {
    /// end-to-end latency under the assigned sub-model
    pub latency: f64,
    /// the same draw normalized to the full model (r = 1, full comm)
    pub full_latency: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::device::mobile_fleet;
    use crate::util::stats;

    fn quiet() -> FluctuationSchedule {
        FluctuationSchedule::none()
    }

    #[test]
    fn linear_in_r_within_10_percent() {
        // Appendix A.3: time(r)/time(1.0) within 10% of r itself
        let pm = PerfModel {
            jitter_sigma: 0.0,
            ..PerfModel::new("cifar_vgg9", 4_000_000)
        };
        let dev = &mobile_fleet()[0];
        let mut rng = Pcg32::new(1, 1);
        let t_full = pm.compute_time(dev, 0, 1.0, 0.0, &quiet(), &mut rng);
        for &r in &[0.5, 0.65, 0.75, 0.85, 0.95] {
            let t = pm.compute_time(dev, 0, r, 0.0, &quiet(), &mut rng);
            let frac = t / t_full;
            assert!((frac - r).abs() <= 0.10, "r={r} frac={frac}");
        }
        // and a strict linear fit
        let rs = [0.5, 0.65, 0.75, 0.85, 1.0];
        let ts: Vec<f64> = rs
            .iter()
            .map(|&r| pm.compute_time(dev, 0, r, 0.0, &quiet(), &mut rng))
            .collect();
        let (_, slope, r2) = stats::linear_fit(&rs, &ts);
        assert!(slope > 0.0);
        assert!(r2 > 0.999, "not linear: r2={r2}");
    }

    #[test]
    fn comm_time_scales_with_fraction_and_bandwidth() {
        let pm = PerfModel::new("femnist_cnn", 1_640_088);
        let fast = &mobile_fleet()[0];
        let slow = &mobile_fleet()[4];
        let full = pm.comm_time(fast, 1.0);
        let half = pm.comm_time(fast, 0.5);
        assert!((half - full / 2.0).abs() < 1e-12);
        assert!(pm.comm_time(slow, 1.0) > full);
    }

    #[test]
    fn straggler_is_slowest_end_to_end() {
        let pm = PerfModel::new("cifar_vgg9", 5_879_976);
        let fleet = mobile_fleet();
        let mut rng = Pcg32::new(2, 2);
        let lat: Vec<f64> = fleet
            .iter()
            .enumerate()
            .map(|(i, d)| pm.round_latency(d, i, 1.0, 1.0, 0.0, &quiet(), &mut rng))
            .collect();
        let max_idx = lat
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(max_idx, 4, "Pixel 3 must be the straggler: {lat:?}");
    }

    #[test]
    fn client_timing_pair_shares_jitter() {
        let pm = PerfModel::new("cifar_vgg9", 5_879_976);
        let dev = &mobile_fleet()[4];
        // at r = 1 and full comm, the pair must be bit-identical — the
        // clone-the-stream protocol guarantees the same jitter draw
        let t = pm.client_timing(dev, 3, 1.0, 1.0, 0.0, &quiet(), 99);
        assert_eq!(t.latency.to_bits(), t.full_latency.to_bits());
        // a sub-model strictly reduces actual latency but never the
        // normalized one
        let s = pm.client_timing(dev, 3, 0.5, 0.5, 0.0, &quiet(), 99);
        assert!(s.latency < s.full_latency);
        assert_eq!(s.full_latency.to_bits(), t.full_latency.to_bits());
    }

    #[test]
    fn jitter_is_modest() {
        let pm = PerfModel::new("femnist_cnn", 1_000_000);
        let dev = &mobile_fleet()[2];
        let mut rng = Pcg32::new(3, 3);
        let xs: Vec<f64> = (0..500)
            .map(|_| pm.compute_time(dev, 0, 1.0, 0.0, &quiet(), &mut rng))
            .collect();
        let cv = stats::std_dev(&xs) / stats::mean(&xs);
        assert!(cv < 0.06, "cv {cv}");
    }
}
