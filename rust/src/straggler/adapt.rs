//! Closed-loop adaptive sub-model sizing.
//!
//! The paper concedes (§7) that FLuID "currently only uses pre-defined
//! sub-model sizes": every `recalibrate_every` rounds the server snaps a
//! one-shot `1/speedup` to a static menu with no feedback, no smoothing,
//! and no memory of whether the last assignment actually hit `T_target`.
//! [`RateController`] closes that loop (Helios-style soft training
//! toward a per-device compute budget, FedDHAD-style adaptive rates):
//!
//! * **EWMA latency profiles** — per-client smoothed full-model
//!   latencies drive promotion/demotion, so one jittery round cannot
//!   flap a client in or out of the straggler set.
//! * **Proportional feedback** — each straggler's keep-rate steps on the
//!   measured miss `latency / T_target` of the *assigned* sub-model
//!   ([`RateController::step_rate`]), targeting a setpoint just under
//!   `T_target` so jitter rarely pushes an arrival past the barrier.
//! * **Hysteresis deadband** — misses inside the band leave the
//!   assignment untouched; the measured-latency EWMA is reset whenever a
//!   rate changes so stale-rate measurements never drive a step.
//! * **Continuous rates** in `[rate_min, 1.0]` — no menu quantization;
//!   [`AdaptMode::Paper`] keeps the historical menu-snap behavior
//!   bit-for-bit for paper-fidelity runs (it routes through the same
//!   seam but delegates to [`detect_stragglers`]).
//!
//! The engine feeds arrivals back through [`RateController::observe`]
//! and consumes assignments as a [`Detection`] from
//! [`RateController::recalibrate`]; controller state persists in the
//! snapshot's `CTRL` section (DESIGN.md §9) so resumed runs stay
//! bit-identical.

use super::detect::{detect_stragglers, Detection};

/// Ceiling on feedback-stepped keep-rates. Growth caps just *below* the
/// full model: leaving the straggler set (rate = 1.0) is the
/// profile-based demotion rule's call — with its hysteresis — never a
/// noisy feedback step's. A step that reached 1.0 would silently drop
/// the client from the set while its full-model profile still exceeds
/// the target, and the next recalibration would flap it straight back.
const MAX_ADAPTIVE_RATE: f64 = 0.99;

/// Which sub-model sizing law the server runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AdaptMode {
    /// The paper's one-shot `1/speedup` snapped to the static menu
    /// (§7 "pre-defined sub-model sizes") — the historical behavior,
    /// bit-identical to the regression pin.
    #[default]
    Paper,
    /// The closed feedback loop over EWMA-smoothed latency profiles.
    Ewma,
}

impl AdaptMode {
    pub fn parse(s: &str) -> Option<AdaptMode> {
        Some(match s.to_ascii_lowercase().as_str() {
            "paper" | "static" | "menu" => AdaptMode::Paper,
            "ewma" | "adaptive" | "controller" => AdaptMode::Ewma,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdaptMode::Paper => "paper",
            AdaptMode::Ewma => "ewma",
        }
    }
}

/// Controller parameters (see `ExperimentConfig::{adapt, adapt_gain,
/// adapt_deadband, rate_min}` and the `--adapt*` CLI flags).
#[derive(Clone, Copy, Debug)]
pub struct AdaptConfig {
    pub mode: AdaptMode,
    /// proportional gain on the measured miss (rate step per unit error)
    pub gain: f64,
    /// hysteresis half-width around the latency setpoint `1 - deadband`
    pub deadband: f64,
    /// floor on adaptive keep-rates (the menu floors `paper` mode)
    pub rate_min: f64,
    /// smoothing factor of the latency EWMAs (weight of the newest draw)
    pub ewma_alpha: f64,
}

impl Default for AdaptConfig {
    fn default() -> Self {
        Self {
            mode: AdaptMode::Paper,
            gain: 0.5,
            deadband: 0.05,
            rate_min: 0.1,
            ewma_alpha: 0.7,
        }
    }
}

/// The controller's resumable state — everything the snapshot `CTRL`
/// section persists (floats round-trip as raw bit patterns).
#[derive(Clone, Debug, PartialEq)]
pub struct CtrlState {
    /// per-client EWMA of full-model-normalized latency (0 = unmeasured)
    pub profile: Vec<f64>,
    /// per-client EWMA of the latency measured under the *assigned*
    /// rate, reset whenever the assignment changes (0 = unmeasured)
    pub measured: Vec<f64>,
    /// per-client assigned keep-rate (1.0 = full model, not a straggler)
    pub rates: Vec<f64>,
    /// the controller's current target time (0 = no calibration yet)
    pub t_target: f64,
}

/// Per-client closed-loop sub-model sizing (see module docs).
#[derive(Clone, Debug)]
pub struct RateController {
    cfg: AdaptConfig,
    profile: Vec<f64>,
    measured: Vec<f64>,
    rates: Vec<f64>,
    t_target: f64,
}

impl RateController {
    pub fn new(n: usize, cfg: AdaptConfig) -> Self {
        Self {
            cfg,
            profile: vec![0.0; n],
            measured: vec![0.0; n],
            rates: vec![1.0; n],
            t_target: 0.0,
        }
    }

    pub fn mode(&self) -> AdaptMode {
        self.cfg.mode
    }

    /// The keep-rate currently assigned to `client` (1.0 = full model).
    pub fn rate_of(&self, client: usize) -> f64 {
        self.rates[client]
    }

    /// The controller's current target time (0 before any calibration).
    pub fn t_target(&self) -> f64 {
        self.t_target
    }

    /// Feed one arrival back into the loop: `latency` is the end-to-end
    /// time under the keep-rate the engine *actually applied* this round
    /// (`applied_rate` — the policy may have fallen back to the full
    /// model, and the None/Exclude policies never cut masks at all),
    /// `full_latency` the same draw normalized to the full model.
    ///
    /// The full-model profile always updates (it is rate-independent and
    /// drives promotion/demotion). The assigned-rate EWMA only updates
    /// when `applied_rate` matches the controller's assignment —
    /// evidence measured under a rate the controller did not assign
    /// must never drive a feedback step. Non-finite or non-positive
    /// measurements (a NaN propagated from a broken client clock) are
    /// ignored rather than poisoning the EWMAs. No-op in `paper` mode,
    /// which profiles through the engine's latency tables.
    pub fn observe(&mut self, client: usize, latency: f64, full_latency: f64, applied_rate: f64) {
        if self.cfg.mode != AdaptMode::Ewma || client >= self.profile.len() {
            return;
        }
        let a = self.cfg.ewma_alpha;
        if full_latency.is_finite() && full_latency > 0.0 {
            self.profile[client] = if self.profile[client] > 0.0 {
                a * full_latency + (1.0 - a) * self.profile[client]
            } else {
                full_latency
            };
        }
        if latency.is_finite() && latency > 0.0 && applied_rate == self.rates[client] {
            self.measured[client] = if self.measured[client] > 0.0 {
                a * latency + (1.0 - a) * self.measured[client]
            } else {
                latency
            };
        }
    }

    /// One proportional step of the feedback law: given the current
    /// `rate` and the measured miss `latency / T_target`, return the
    /// next rate. The setpoint is `1 - deadband` (aim slightly *under*
    /// the target so jitter rarely pushes an arrival past the barrier);
    /// misses within `deadband` of it leave the rate unchanged, and the
    /// result clamps to `[rate_min, MAX_ADAPTIVE_RATE]` — a step never
    /// exits the straggler set (see [`MAX_ADAPTIVE_RATE`]). Monotone: a
    /// slower measured latency never yields a larger rate
    /// (property-tested).
    pub fn step_rate(&self, rate: f64, miss: f64) -> f64 {
        if !miss.is_finite() || miss <= 0.0 {
            return rate;
        }
        let err = miss - (1.0 - self.cfg.deadband);
        if err.abs() <= self.cfg.deadband {
            return rate;
        }
        let next = rate * (1.0 - self.cfg.gain * err);
        // growth clips at the ceiling but never *below* the current
        // rate, so the law stays monotone even for a caller-supplied
        // rate above the ceiling
        next.max(self.cfg.rate_min).min(MAX_ADAPTIVE_RATE.max(rate))
    }

    fn set_rate(&mut self, client: usize, rate: f64) {
        if self.rates[client] != rate {
            self.rates[client] = rate;
            // the assigned sub-model changed: measurements taken under
            // the old rate must not drive the next step
            self.measured[client] = 0.0;
        }
    }

    /// Recalibrate over `pool` (the measured cohort) and return the
    /// current assignments as a [`Detection`], or `None` when there is
    /// nothing to calibrate from (the engine then keeps its previous
    /// detection, as the pre-controller loop did).
    ///
    /// `paper` mode reproduces the historical one-shot snap bit-for-bit:
    /// `detect_stragglers` over `full_latencies[pool]`, sample-local ids
    /// mapped back. `ewma` mode runs the closed loop over the smoothed
    /// profiles; `menu` is unused there (rates are continuous).
    pub fn recalibrate(
        &mut self,
        pool: &[usize],
        full_latencies: &[f64],
        straggler_fraction: f64,
        margin: f64,
        menu: &[f64],
    ) -> Option<Detection> {
        match self.cfg.mode {
            AdaptMode::Paper => {
                if pool.is_empty() {
                    return None;
                }
                let lat: Vec<f64> = pool.iter().map(|&c| full_latencies[c]).collect();
                let det = detect_stragglers(&lat, straggler_fraction, margin, menu);
                Some(Detection {
                    stragglers: det.stragglers.iter().map(|&i| pool[i]).collect(),
                    ..det
                })
            }
            AdaptMode::Ewma => self.recalibrate_ewma(pool, straggler_fraction, margin),
        }
    }

    fn recalibrate_ewma(
        &mut self,
        pool: &[usize],
        straggler_fraction: f64,
        margin: f64,
    ) -> Option<Detection> {
        // only clients with a real smoothed profile participate — a
        // fresh cohort is mostly unmeasured at fleet scale
        let measured: Vec<usize> = pool
            .iter()
            .copied()
            .filter(|&c| {
                c < self.profile.len()
                    && self.profile[c].is_finite()
                    && self.profile[c] > 0.0
            })
            .collect();
        if measured.is_empty() {
            return None;
        }

        // T_target over the smoothed profiles, like detect_stragglers:
        // the slowest client outside the straggler candidate set
        let max_s = ((measured.len() as f64 * straggler_fraction).floor() as usize)
            .min(measured.len() - 1);
        let mut order = measured.clone();
        order.sort_by(|&a, &b| self.profile[b].total_cmp(&self.profile[a]).then(a.cmp(&b)));
        let tt = self.profile[order[max_s.min(order.len() - 1)]];
        if !tt.is_finite() || tt <= 0.0 {
            return None;
        }
        self.t_target = tt;

        // promotion: only the `straggler_fraction` slowest measured
        // clients are eligible; a client clearly past the target (margin
        // + deadband of hysteresis) enters at the paper's 1/speedup
        for &c in order.iter().take(max_s) {
            let ratio = self.profile[c] / tt;
            if self.rates[c] >= 1.0 && ratio > 1.0 + margin + self.cfg.deadband {
                self.set_rate(c, (1.0 / ratio).clamp(self.cfg.rate_min, 1.0));
            }
        }

        // demotion + feedback for current stragglers with fresh
        // measurements (drift/flux scenarios shift load mid-run: a
        // straggler whose smoothed full-model profile is back at the
        // target no longer needs a sub-model at all)
        for &c in &measured {
            if self.rates[c] >= 1.0 {
                continue;
            }
            let ratio = self.profile[c] / tt;
            if ratio <= 1.0 + margin {
                self.set_rate(c, 1.0);
                continue;
            }
            if self.measured[c] > 0.0 {
                let next = self.step_rate(self.rates[c], self.measured[c] / tt);
                self.set_rate(c, next);
            }
        }

        // assignments over the whole population (stragglers keep their
        // rate across cohorts — the controller's memory), slowest first
        let mut ids: Vec<usize> =
            (0..self.rates.len()).filter(|&c| self.rates[c] < 1.0).collect();
        ids.sort_by(|&a, &b| self.profile[b].total_cmp(&self.profile[a]).then(a.cmp(&b)));
        let speedups: Vec<f64> = ids.iter().map(|&c| self.profile[c] / tt).collect();
        let rates: Vec<f64> = ids.iter().map(|&c| self.rates[c]).collect();
        Some(Detection {
            stragglers: ids,
            t_target: tt,
            speedups,
            rates,
        })
    }

    /// Resumable state for the snapshot `CTRL` section. `paper` mode
    /// carries no controller state (its detection lives in `SCHED`).
    pub fn export_state(&self) -> Option<CtrlState> {
        if self.cfg.mode != AdaptMode::Ewma {
            return None;
        }
        Some(CtrlState {
            profile: self.profile.clone(),
            measured: self.measured.clone(),
            rates: self.rates.clone(),
            t_target: self.t_target,
        })
    }

    /// Install snapshotted state. The caller (engine restore) validates
    /// table lengths and rate ranges before this is reached.
    pub fn import_state(&mut self, st: CtrlState) {
        self.profile = st.profile;
        self.measured = st.measured;
        self.rates = st.rates;
        self.t_target = st.t_target;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::detect::DEFAULT_RATES;

    fn ewma_cfg() -> AdaptConfig {
        AdaptConfig {
            mode: AdaptMode::Ewma,
            ..AdaptConfig::default()
        }
    }

    #[test]
    fn mode_parse_round_trips() {
        for m in [AdaptMode::Paper, AdaptMode::Ewma] {
            assert_eq!(AdaptMode::parse(m.name()), Some(m));
        }
        assert_eq!(AdaptMode::parse("EWMA"), Some(AdaptMode::Ewma));
        assert_eq!(AdaptMode::parse("bogus"), None);
        assert_eq!(AdaptMode::default(), AdaptMode::Paper);
    }

    #[test]
    fn paper_mode_matches_one_shot_detection() {
        let mut ctl = RateController::new(6, AdaptConfig::default());
        let full = [0.0, 62.0, 66.0, 72.0, 80.0, 100.0];
        let pool = [1usize, 2, 3, 4, 5];
        let det = ctl
            .recalibrate(&pool, &full, 0.2, 0.02, DEFAULT_RATES)
            .unwrap();
        let lat: Vec<f64> = pool.iter().map(|&c| full[c]).collect();
        let reference = detect_stragglers(&lat, 0.2, 0.02, DEFAULT_RATES);
        assert_eq!(det.stragglers, vec![5], "sample-local ids mapped back");
        assert_eq!(det.t_target, reference.t_target);
        assert_eq!(det.rates, reference.rates);
        assert!(ctl.recalibrate(&[], &full, 0.2, 0.02, DEFAULT_RATES).is_none());
        // paper mode carries no CTRL state and ignores observe()
        ctl.observe(1, 9.0, 9.0, 1.0);
        assert!(ctl.export_state().is_none());
        assert_eq!(ctl.rate_of(5), 1.0);
    }

    #[test]
    fn step_rate_band_and_clamps() {
        let ctl = RateController::new(1, ewma_cfg());
        let (db, gain) = (0.05, 0.5);
        // inside the band [1-2db, 1]: no change
        assert_eq!(ctl.step_rate(0.6, 1.0 - db), 0.6);
        assert_eq!(ctl.step_rate(0.6, 1.0), 0.6);
        assert_eq!(ctl.step_rate(0.6, 1.0 - 2.0 * db), 0.6);
        // above: shrink proportionally to the excess over the setpoint
        let next = ctl.step_rate(0.6, 1.25);
        assert!((next - 0.6 * (1.0 - gain * (1.25 - (1.0 - db)))).abs() < 1e-12);
        // below: grow
        assert!(ctl.step_rate(0.6, 0.7) > 0.6);
        // clamps: growth caps below 1.0 — only the profile demotion
        // rule may take a client out of the straggler set
        assert_eq!(ctl.step_rate(0.95, 0.2), MAX_ADAPTIVE_RATE);
        assert!(ctl.step_rate(0.95, 0.2) < 1.0);
        // ... but a growth step never moves a rate *down* to the ceiling
        assert_eq!(ctl.step_rate(1.0, 0.2), 1.0);
        assert_eq!(ctl.step_rate(0.12, 5.0), 0.1);
        // garbage misses are ignored
        assert_eq!(ctl.step_rate(0.6, f64::NAN), 0.6);
        assert_eq!(ctl.step_rate(0.6, -1.0), 0.6);
    }

    #[test]
    fn promotes_steps_and_demotes() {
        let mut ctl = RateController::new(4, ewma_cfg());
        let pool = [0usize, 1, 2, 3];
        // client 3 is 2x slower than the rest
        for _ in 0..3 {
            for c in 0..3 {
                ctl.observe(c, 10.0, 10.0, 1.0);
            }
            ctl.observe(3, 20.0, 20.0, 1.0);
        }
        let det = ctl.recalibrate(&pool, &[], 0.25, 0.02, &[]).unwrap();
        assert_eq!(det.stragglers, vec![3]);
        assert_eq!(det.t_target, 10.0);
        assert!((ctl.rate_of(3) - 0.5).abs() < 1e-9, "promoted at 1/speedup");

        // sub-model still misses by 30%: the rate steps down
        let r = ctl.rate_of(3);
        ctl.observe(3, 13.0, 20.0, r);
        ctl.recalibrate(&pool, &[], 0.25, 0.02, &[]).unwrap();
        assert!(ctl.rate_of(3) < 0.5, "rate must shrink on a miss");

        // evidence from a rate the controller did not assign (the
        // policy fell back to the full model) must never drive a step
        let r = ctl.rate_of(3);
        ctl.observe(3, 20.0, 20.0, 1.0);
        ctl.recalibrate(&pool, &[], 0.25, 0.02, &[]).unwrap();
        assert_eq!(ctl.rate_of(3), r, "full-model fallback drove a step");

        // load lifts: the smoothed profile returns to target, demote
        for _ in 0..12 {
            let r = ctl.rate_of(3);
            ctl.observe(3, 9.0, 10.0, r);
        }
        let det = ctl.recalibrate(&pool, &[], 0.25, 0.02, &[]).unwrap();
        assert!(det.stragglers.is_empty(), "recovered client stays flagged");
        assert_eq!(ctl.rate_of(3), 1.0);
    }

    #[test]
    fn deadband_holds_assignments_against_jitter() {
        let mut ctl = RateController::new(3, ewma_cfg());
        let pool = [0usize, 1, 2];
        for _ in 0..4 {
            ctl.observe(0, 10.0, 10.0, 1.0);
            ctl.observe(1, 10.0, 10.0, 1.0);
            ctl.observe(2, 20.0, 20.0, 1.0);
        }
        ctl.recalibrate(&pool, &[], 0.34, 0.02, &[]).unwrap();
        let r = ctl.rate_of(2);
        assert!(r < 1.0);
        // arrivals jittering inside the band never move the assignment
        for miss in [0.92, 0.95, 0.985, 1.0] {
            ctl.observe(2, miss * 10.0, 20.0, r);
            ctl.recalibrate(&pool, &[], 0.34, 0.02, &[]).unwrap();
            assert_eq!(ctl.rate_of(2), r, "assignment flapped at miss {miss}");
        }
    }

    #[test]
    fn nan_measurements_never_poison_the_loop() {
        let mut ctl = RateController::new(2, ewma_cfg());
        ctl.observe(0, 10.0, 10.0, 1.0);
        ctl.observe(1, 30.0, 30.0, 1.0);
        ctl.observe(1, f64::NAN, f64::NAN, 1.0);
        ctl.observe(0, f64::INFINITY, -5.0, 1.0);
        let det = ctl.recalibrate(&[0, 1], &[], 0.5, 0.02, &[]).unwrap();
        assert_eq!(det.stragglers, vec![1]);
        assert!(det.t_target == 10.0);
        assert!(det.rates.iter().all(|r| r.is_finite()));
    }

    #[test]
    fn state_round_trips() {
        let mut ctl = RateController::new(3, ewma_cfg());
        ctl.observe(0, 5.0, 5.0, 1.0);
        ctl.observe(2, 12.0, 12.0, 1.0);
        ctl.recalibrate(&[0, 2], &[], 0.5, 0.02, &[]).unwrap();
        let st = ctl.export_state().unwrap();
        let mut other = RateController::new(3, ewma_cfg());
        other.import_state(st.clone());
        assert_eq!(other.export_state().unwrap(), st);
        assert_eq!(other.rate_of(2), ctl.rate_of(2));
        assert_eq!(other.t_target(), ctl.t_target());
    }
}
