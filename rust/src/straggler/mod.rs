//! Straggler subsystem: the heterogeneous device fleet, its performance
//! model, and FLuID's straggler detection / sub-model sizing.
//!
//! The paper measures five Android phones (Table 1); we reproduce their
//! *relative* performance as device profiles (DESIGN.md §2) and drive all
//! timing off a virtual clock — wall-clock results in the paper are a
//! function of device heterogeneity, which the model preserves.

pub mod adapt;
pub mod cluster;
pub mod detect;
pub mod device;
pub mod fluctuate;
pub mod perfmodel;

pub use adapt::{AdaptConfig, AdaptMode, CtrlState, RateController};
pub use cluster::cluster_stragglers;
pub use detect::{detect_stragglers, snap_rate, Detection};
pub use device::{mobile_fleet, synthetic_fleet, DeviceProfile};
pub use fluctuate::{FluctuationSchedule, LoadEvent, ProceduralChurn, ProceduralLoad, ProceduralPhase};
pub use perfmodel::{ClientTiming, PerfModel};
