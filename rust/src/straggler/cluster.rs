//! Straggler clustering (Appendix A.4): when many stragglers have
//! different capabilities, FLuID groups them into a small number of
//! sub-model-size clusters instead of sizing each individually or
//! punishing everyone with the slowest device's sub-model.

use super::detect::snap_rate;

/// Assign each straggler (by desired keep-rate 1/speedup) to one of the
/// `cluster_rates` — the A.4 experiment uses {0.65, 0.75, 0.85, 0.95}.
/// Returns the per-straggler cluster rate (aligned with input order).
pub fn cluster_stragglers(speedups: &[f64], cluster_rates: &[f64]) -> Vec<f64> {
    speedups
        .iter()
        .map(|&s| snap_rate(1.0 / s.max(1.0), cluster_rates))
        .collect()
}

/// Quantize into k equal-occupancy clusters by speedup rank, then map
/// each cluster to a rate (slowest cluster -> smallest rate). The A.4
/// "4 equal-sized clusters" protocol.
pub fn equal_size_clusters(speedups: &[f64], cluster_rates: &[f64]) -> Vec<f64> {
    let n = speedups.len();
    if n == 0 {
        return vec![];
    }
    let k = cluster_rates.len().max(1);
    let mut order: Vec<usize> = (0..n).collect();
    // slowest (largest speedup needed) first; total_cmp so a NaN
    // speedup cannot panic the sort (NaN ranks slowest and lands in the
    // smallest-rate cluster like any other still-unmeasured client)
    order.sort_by(|&a, &b| speedups[b].total_cmp(&speedups[a]));
    let mut rates_sorted = cluster_rates.to_vec();
    rates_sorted.sort_by(|a, b| a.total_cmp(b)); // smallest first
    let mut out = vec![1.0; n];
    for (rank, &idx) in order.iter().enumerate() {
        let cluster = (rank * k) / n;
        out[idx] = rates_sorted[cluster.min(k - 1)];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const A4_RATES: &[f64] = &[0.65, 0.75, 0.85, 0.95];

    #[test]
    fn capability_based_assignment() {
        // speedups 1.05 (barely slow) .. 1.6 (very slow)
        let rates = cluster_stragglers(&[1.05, 1.18, 1.35, 1.6], A4_RATES);
        assert_eq!(rates, vec![0.95, 0.85, 0.75, 0.65]);
    }

    #[test]
    fn faster_than_target_gets_largest_rate() {
        let rates = cluster_stragglers(&[0.9], A4_RATES);
        assert_eq!(rates, vec![0.95]);
    }

    #[test]
    fn equal_clusters_are_balanced() {
        let speedups: Vec<f64> = (0..8).map(|i| 1.1 + i as f64 * 0.1).collect();
        let rates = equal_size_clusters(&speedups, A4_RATES);
        // 8 stragglers, 4 clusters -> 2 each
        for &r in A4_RATES {
            assert_eq!(rates.iter().filter(|&&x| x == r).count(), 2);
        }
        // slowest straggler gets the smallest sub-model
        assert_eq!(rates[7], 0.65);
        assert_eq!(rates[0], 0.95);
    }

    #[test]
    fn empty() {
        assert!(equal_size_clusters(&[], A4_RATES).is_empty());
        assert!(cluster_stragglers(&[], A4_RATES).is_empty());
    }
}
