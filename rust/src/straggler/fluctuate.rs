//! Runtime condition fluctuation (§6.1 "Varying stragglers at runtime").
//!
//! The paper emulates shifting runtime conditions by starting a
//! background process on random clients at the 25%, 50% and 75% marks of
//! training. A [`LoadEvent`] is exactly that: a client, an active window
//! in training-progress fractions, and a compute multiplier.
//!
//! At fleet scale (10k–100k clients) explicit per-client events stop
//! being viable: a 10%-of-fleet load phase would mean tens of thousands
//! of events, and `load_multiplier` is on the per-arrival hot path. The
//! [`ProceduralLoad`] component covers that regime: phase membership is
//! decided by a seeded per-(phase, client) hash, so lookups are
//! O(phases) with zero per-client storage and the whole schedule replays
//! bit-identically from its seed. `engine::scenario` compiles scenario
//! configs down to procedural phases.

use crate::util::prng::Pcg32;

/// One background-load episode on one client.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadEvent {
    pub client: usize,
    /// active window in training progress fractions [start, end)
    pub start_frac: f64,
    pub end_frac: f64,
    /// compute-time multiplier while active (> 1 slows the client)
    pub multiplier: f64,
}

/// One procedural fleet-dynamics phase: during `[start_frac, end_frac)`
/// a seeded `slow_fraction` of the fleet runs under a background load in
/// `[multiplier_lo, multiplier_hi]`, and every client's speed wobbles by
/// a lognormal factor of shape `jitter` (0 disables). Which clients are
/// slow is decided per phase, so consecutive phases *drift* the straggler
/// population.
#[derive(Clone, Debug, PartialEq)]
pub struct ProceduralPhase {
    pub start_frac: f64,
    pub end_frac: f64,
    pub slow_fraction: f64,
    pub multiplier_lo: f64,
    pub multiplier_hi: f64,
    pub jitter: f64,
}

/// Hash-based fleet-scale load: membership and multipliers derive from
/// `(seed, phase index, client)`, so lookups are O(phases) and the whole
/// schedule is replayable from the seed alone.
#[derive(Clone, Debug, PartialEq)]
pub struct ProceduralLoad {
    pub seed: u64,
    pub phases: Vec<ProceduralPhase>,
}

impl ProceduralLoad {
    /// Compute multiplier for `client` at training progress `t_frac`.
    ///
    /// Slow-set membership (and its load multiplier) is stable for the
    /// whole phase — that is what makes the straggler *population* drift
    /// phase by phase rather than flicker. The jitter component draws
    /// from a stream salted with `t_frac`, so device speed genuinely
    /// wobbles round to round while staying a pure replayable function
    /// of `(seed, phase, client, t_frac)`.
    pub fn multiplier(&self, client: usize, t_frac: f64) -> f64 {
        let mut m = 1.0;
        for (i, p) in self.phases.iter().enumerate() {
            if t_frac >= p.start_frac && t_frac < p.end_frac {
                let phase_salt = (i as u64 + 1) << 40;
                let mut rng = Pcg32::new(self.seed ^ phase_salt, client as u64);
                if rng.next_f64() < p.slow_fraction {
                    m *= p.multiplier_lo
                        + (p.multiplier_hi - p.multiplier_lo) * rng.next_f64();
                }
                if p.jitter > 0.0 {
                    let mut jrng = Pcg32::new(
                        self.seed ^ phase_salt ^ t_frac.to_bits(),
                        client as u64,
                    );
                    m *= jrng.lognormal(p.jitter as f32) as f64;
                }
            }
        }
        m
    }
}

/// Lazy procedural join/leave churn: per-round Bernoulli rates plus the
/// seed of the per-round RNG stream. This is a *description* — nothing is
/// swept here. The fleet applies it as sparse deltas (geometric
/// skip-sampling over the available/unavailable populations, see
/// `fl::sampling::bernoulli_ranks_into`), so a round's churn costs
/// O(expected flips), not O(fleet).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ProceduralChurn {
    pub seed: u64,
    /// per-round probability that an available client churns out
    pub churn_out: f64,
    /// per-round probability that a churned-out client rejoins
    pub rejoin: f64,
}

impl ProceduralChurn {
    /// Does this schedule ever move the population? (NaN rates count as
    /// inactive — the delta sampler treats them as rate 0.)
    pub fn is_active(&self) -> bool {
        self.churn_out > 0.0 || self.rejoin > 0.0
    }

    /// The round's churn RNG — one stream per `(seed, round)`, so a
    /// replay of the same experiment seed replays the exact population
    /// trajectory without any cross-round state.
    pub fn round_rng(&self, round: usize) -> Pcg32 {
        Pcg32::new(self.seed, round as u64)
    }
}

/// The set of load events for one run.
#[derive(Clone, Debug, Default)]
pub struct FluctuationSchedule {
    pub events: Vec<LoadEvent>,
    /// fleet-scale procedural component (None for the paper protocols)
    pub procedural: Option<ProceduralLoad>,
}

impl FluctuationSchedule {
    /// No fluctuation — stable devices (Table 2 experiments).
    pub fn none() -> Self {
        Self::default()
    }

    /// Purely procedural schedule (fleet-scale scenarios).
    pub fn procedural(load: ProceduralLoad) -> Self {
        Self {
            events: vec![],
            procedural: Some(load),
        }
    }

    /// The paper's protocol: at each of the 25/50/75% marks, pick a
    /// random client (excluding `exclude`, the natural straggler, so the
    /// straggler *changes*) and run a background load until the next mark.
    pub fn paper_marks(num_clients: usize, exclude: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0xF1C);
        let mut events = Vec::new();
        for (i, start) in [0.25, 0.5, 0.75].into_iter().enumerate() {
            let mut client = rng.below_usize(num_clients);
            if num_clients > 1 {
                while client == exclude {
                    client = rng.below_usize(num_clients);
                }
            }
            events.push(LoadEvent {
                client,
                start_frac: start,
                end_frac: if i == 2 { 1.0 } else { start + 0.25 },
                multiplier: 1.5 + rng.next_f64() * 1.0, // 1.5x – 2.5x
            });
        }
        Self {
            events,
            procedural: None,
        }
    }

    /// Compute multiplier for `client` at training progress `t_frac`.
    pub fn load_multiplier(&self, client: usize, t_frac: f64) -> f64 {
        let mut m = 1.0;
        for e in &self.events {
            if e.client == client && t_frac >= e.start_frac && t_frac < e.end_frac {
                m *= e.multiplier;
            }
        }
        if let Some(p) = &self.procedural {
            m *= p.multiplier(client, t_frac);
        }
        m
    }

    /// Does any event change the straggler set during the run?
    pub fn is_dynamic(&self) -> bool {
        !self.events.is_empty()
            || self
                .procedural
                .as_ref()
                .is_some_and(|p| !p.phases.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn procedural_churn_activity_and_round_streams() {
        let quiet = ProceduralChurn { seed: 1, churn_out: 0.0, rejoin: 0.0 };
        assert!(!quiet.is_active());
        let nan = ProceduralChurn { seed: 1, churn_out: f64::NAN, rejoin: 0.0 };
        assert!(!nan.is_active());
        let live = ProceduralChurn { seed: 1, churn_out: 0.05, rejoin: 0.3 };
        assert!(live.is_active());
        // per-round streams are replayable and distinct round to round
        assert_eq!(live.round_rng(4).next_u32(), live.round_rng(4).next_u32());
        assert_ne!(live.round_rng(4).next_u32(), live.round_rng(5).next_u32());
    }

    #[test]
    fn none_is_identity() {
        let s = FluctuationSchedule::none();
        assert_eq!(s.load_multiplier(0, 0.3), 1.0);
        assert!(!s.is_dynamic());
    }

    #[test]
    fn window_semantics() {
        let s = FluctuationSchedule {
            events: vec![LoadEvent {
                client: 2,
                start_frac: 0.25,
                end_frac: 0.5,
                multiplier: 2.0,
            }],
            procedural: None,
        };
        assert_eq!(s.load_multiplier(2, 0.2), 1.0);
        assert_eq!(s.load_multiplier(2, 0.25), 2.0);
        assert_eq!(s.load_multiplier(2, 0.49), 2.0);
        assert_eq!(s.load_multiplier(2, 0.5), 1.0);
        assert_eq!(s.load_multiplier(1, 0.3), 1.0); // other client untouched
    }

    #[test]
    fn paper_marks_cover_quarters() {
        let s = FluctuationSchedule::paper_marks(5, 4, 7);
        assert_eq!(s.events.len(), 3);
        assert!(s.is_dynamic());
        for e in &s.events {
            assert_ne!(e.client, 4, "natural straggler excluded");
            assert!(e.multiplier >= 1.5 && e.multiplier <= 2.5);
        }
        assert_eq!(s.events[0].start_frac, 0.25);
        assert_eq!(s.events[2].end_frac, 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(
            FluctuationSchedule::paper_marks(5, 0, 9).events,
            FluctuationSchedule::paper_marks(5, 0, 9).events
        );
    }

    #[test]
    fn overlapping_events_multiply() {
        let s = FluctuationSchedule {
            events: vec![
                LoadEvent { client: 0, start_frac: 0.0, end_frac: 1.0, multiplier: 1.5 },
                LoadEvent { client: 0, start_frac: 0.4, end_frac: 0.6, multiplier: 2.0 },
            ],
            procedural: None,
        };
        assert_eq!(s.load_multiplier(0, 0.5), 3.0);
        assert_eq!(s.load_multiplier(0, 0.1), 1.5);
    }

    fn drift_load() -> ProceduralLoad {
        ProceduralLoad {
            seed: 9,
            phases: vec![
                ProceduralPhase {
                    start_frac: 0.0,
                    end_frac: 0.5,
                    slow_fraction: 0.2,
                    multiplier_lo: 1.5,
                    multiplier_hi: 2.5,
                    jitter: 0.0,
                },
                ProceduralPhase {
                    start_frac: 0.5,
                    end_frac: 1.0,
                    slow_fraction: 0.2,
                    multiplier_lo: 1.5,
                    multiplier_hi: 2.5,
                    jitter: 0.0,
                },
            ],
        }
    }

    #[test]
    fn procedural_is_deterministic_and_bounded() {
        let p = drift_load();
        for c in 0..200 {
            let a = p.multiplier(c, 0.25);
            assert_eq!(a.to_bits(), p.multiplier(c, 0.25).to_bits());
            assert!(a == 1.0 || (1.5..=2.5).contains(&a), "client {c}: {a}");
        }
    }

    #[test]
    fn procedural_hits_roughly_slow_fraction() {
        let p = drift_load();
        let slow = (0..5000).filter(|&c| p.multiplier(c, 0.25) > 1.0).count();
        assert!((700..=1300).contains(&slow), "slow count {slow} of 5000");
    }

    #[test]
    fn procedural_population_drifts_between_phases() {
        let p = drift_load();
        // the slow sets of phase 1 and phase 2 must not coincide
        let a: Vec<usize> =
            (0..2000).filter(|&c| p.multiplier(c, 0.25) > 1.0).collect();
        let b: Vec<usize> =
            (0..2000).filter(|&c| p.multiplier(c, 0.75) > 1.0).collect();
        assert_ne!(a, b, "straggler population did not drift");
    }

    #[test]
    fn procedural_membership_is_stable_within_a_phase() {
        // with jitter off, a client's multiplier is constant across the
        // whole phase: the slow *population* only moves at phase edges
        let p = drift_load();
        for c in 0..100 {
            assert_eq!(
                p.multiplier(c, 0.1).to_bits(),
                p.multiplier(c, 0.3).to_bits(),
                "client {c} flickered inside the phase"
            );
        }
    }

    #[test]
    fn procedural_jitter_wobbles_round_to_round() {
        // a jitter-only phase (the `flux` scenario shape) must vary with
        // training progress — speed fluctuation, not a static rescale
        let p = ProceduralLoad {
            seed: 5,
            phases: vec![ProceduralPhase {
                start_frac: 0.0,
                end_frac: 1.0,
                slow_fraction: 0.0,
                multiplier_lo: 1.0,
                multiplier_hi: 1.0,
                jitter: 0.25,
            }],
        };
        let varies = (0..50)
            .filter(|&c| p.multiplier(c, 0.1).to_bits() != p.multiplier(c, 0.3).to_bits())
            .count();
        assert!(varies >= 45, "jitter is static within the phase ({varies}/50 vary)");
        // and each (client, t_frac) pair replays bit-identically
        assert_eq!(p.multiplier(3, 0.1).to_bits(), p.multiplier(3, 0.1).to_bits());
        assert!(p.multiplier(3, 0.1) > 0.0);
    }

    #[test]
    fn procedural_folds_into_schedule() {
        let s = FluctuationSchedule::procedural(drift_load());
        assert!(s.is_dynamic());
        // out-of-phase progress is quiet
        let p = ProceduralLoad { seed: 9, phases: vec![] };
        assert_eq!(p.multiplier(3, 0.4), 1.0);
        assert!(!FluctuationSchedule::procedural(p).is_dynamic());
    }
}
