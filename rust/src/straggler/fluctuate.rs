//! Runtime condition fluctuation (§6.1 "Varying stragglers at runtime").
//!
//! The paper emulates shifting runtime conditions by starting a
//! background process on random clients at the 25%, 50% and 75% marks of
//! training. A [`LoadEvent`] is exactly that: a client, an active window
//! in training-progress fractions, and a compute multiplier.

use crate::util::prng::Pcg32;

/// One background-load episode on one client.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadEvent {
    pub client: usize,
    /// active window in training progress fractions [start, end)
    pub start_frac: f64,
    pub end_frac: f64,
    /// compute-time multiplier while active (> 1 slows the client)
    pub multiplier: f64,
}

/// The set of load events for one run.
#[derive(Clone, Debug, Default)]
pub struct FluctuationSchedule {
    pub events: Vec<LoadEvent>,
}

impl FluctuationSchedule {
    /// No fluctuation — stable devices (Table 2 experiments).
    pub fn none() -> Self {
        Self { events: vec![] }
    }

    /// The paper's protocol: at each of the 25/50/75% marks, pick a
    /// random client (excluding `exclude`, the natural straggler, so the
    /// straggler *changes*) and run a background load until the next mark.
    pub fn paper_marks(num_clients: usize, exclude: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0xF1C);
        let mut events = Vec::new();
        for (i, start) in [0.25, 0.5, 0.75].into_iter().enumerate() {
            let mut client = rng.below_usize(num_clients);
            if num_clients > 1 {
                while client == exclude {
                    client = rng.below_usize(num_clients);
                }
            }
            events.push(LoadEvent {
                client,
                start_frac: start,
                end_frac: if i == 2 { 1.0 } else { start + 0.25 },
                multiplier: 1.5 + rng.next_f64() * 1.0, // 1.5x – 2.5x
            });
        }
        Self { events }
    }

    /// Compute multiplier for `client` at training progress `t_frac`.
    pub fn load_multiplier(&self, client: usize, t_frac: f64) -> f64 {
        let mut m = 1.0;
        for e in &self.events {
            if e.client == client && t_frac >= e.start_frac && t_frac < e.end_frac {
                m *= e.multiplier;
            }
        }
        m
    }

    /// Does any event change the straggler set during the run?
    pub fn is_dynamic(&self) -> bool {
        !self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_identity() {
        let s = FluctuationSchedule::none();
        assert_eq!(s.load_multiplier(0, 0.3), 1.0);
        assert!(!s.is_dynamic());
    }

    #[test]
    fn window_semantics() {
        let s = FluctuationSchedule {
            events: vec![LoadEvent {
                client: 2,
                start_frac: 0.25,
                end_frac: 0.5,
                multiplier: 2.0,
            }],
        };
        assert_eq!(s.load_multiplier(2, 0.2), 1.0);
        assert_eq!(s.load_multiplier(2, 0.25), 2.0);
        assert_eq!(s.load_multiplier(2, 0.49), 2.0);
        assert_eq!(s.load_multiplier(2, 0.5), 1.0);
        assert_eq!(s.load_multiplier(1, 0.3), 1.0); // other client untouched
    }

    #[test]
    fn paper_marks_cover_quarters() {
        let s = FluctuationSchedule::paper_marks(5, 4, 7);
        assert_eq!(s.events.len(), 3);
        assert!(s.is_dynamic());
        for e in &s.events {
            assert_ne!(e.client, 4, "natural straggler excluded");
            assert!(e.multiplier >= 1.5 && e.multiplier <= 2.5);
        }
        assert_eq!(s.events[0].start_frac, 0.25);
        assert_eq!(s.events[2].end_frac, 1.0);
    }

    #[test]
    fn deterministic_given_seed() {
        assert_eq!(
            FluctuationSchedule::paper_marks(5, 0, 9).events,
            FluctuationSchedule::paper_marks(5, 0, 9).events
        );
    }

    #[test]
    fn overlapping_events_multiply() {
        let s = FluctuationSchedule {
            events: vec![
                LoadEvent { client: 0, start_frac: 0.0, end_frac: 1.0, multiplier: 1.5 },
                LoadEvent { client: 0, start_frac: 0.4, end_frac: 0.6, multiplier: 2.0 },
            ],
        };
        assert_eq!(s.load_multiplier(0, 0.5), 3.0);
        assert_eq!(s.load_multiplier(0, 0.1), 1.5);
    }
}
