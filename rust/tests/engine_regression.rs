//! Pins the engine's `SyncMode::FullBarrier` against the pre-engine
//! monolithic round loop, preserved below verbatim (modulo `fluid::`
//! paths) as the reference implementation. For a fixed seed the two must
//! produce **bit-identical** `ExperimentResult` histories — virtual
//! times, straggler sets, losses, accuracies — across policies.
//!
//! Wall-clock fields (`calibration_secs`, `train_wall_total`) are
//! excluded: they measure the host, not the algorithm.
//!
//! Requires `make artifacts`; skips gracefully otherwise. A second test
//! exercises the Deadline/Buffered modes end-to-end and checks the
//! virtual-time dominance argument: with per-client latency draws
//! independent of the barrier policy, both relaxed modes can never be
//! slower than the full barrier.

use fluid::coordinator::{ExperimentConfig, ExperimentResult, RoundRecord};
use fluid::data::FlData;
use fluid::dropout::{InvariantConfig, MaskSet, Policy, PolicyKind};
use fluid::engine::SyncMode;
use fluid::fl::{self, fedavg, Client, ClientUpdate, DeltaPayload};
use fluid::runtime::Session;
use fluid::straggler::{
    detect_stragglers, mobile_fleet, snap_rate, synthetic_fleet, Detection,
    FluctuationSchedule, PerfModel,
};
use fluid::util::pool::scope_map;
use fluid::util::prng::Pcg32;
use fluid::util::stats;
use std::time::Instant;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have(model: &str) -> bool {
    // without the xla feature the runtime is a stub: Session::new always
    // fails, so artifact presence alone is not enough to run
    cfg!(feature = "xla") && artifacts_dir().join(format!("{model}_manifest.json")).exists()
}

const MAX_DELTA_VOTERS: usize = 16;

/// The pre-engine round loop, kept as the regression reference.
fn reference_run(sess: &Session, cfg: &ExperimentConfig) -> fluid::Result<ExperimentResult> {
    let runner = sess.runner(&cfg.model)?;
    let spec = runner.spec.clone();

    let fleet = if cfg.mobile_fleet {
        let base = mobile_fleet();
        (0..cfg.clients).map(|i| base[i % base.len()].clone()).collect::<Vec<_>>()
    } else {
        synthetic_fleet(cfg.clients, cfg.seed ^ 0xF1EE7)
    };
    let data = FlData::for_model(&cfg.model, cfg.clients, cfg.samples_per_client, cfg.seed);
    let clients: Vec<Client> = data
        .clients
        .iter()
        .enumerate()
        .map(|(i, split)| Client::new(i, i % fleet.len(), split.clone()))
        .collect();

    let perf = PerfModel::new(&cfg.model, spec.size_bytes());
    let natural_straggler = (0..cfg.clients)
        .max_by(|&a, &b| {
            fleet[a % fleet.len()]
                .base_time(&cfg.model)
                .partial_cmp(&fleet[b % fleet.len()].base_time(&cfg.model))
                .unwrap()
        })
        .unwrap_or(0);
    let sched = if cfg.fluctuation {
        FluctuationSchedule::paper_marks(cfg.clients, natural_straggler, cfg.seed ^ 0xF1C)
    } else {
        FluctuationSchedule::none()
    };

    let inv_cfg = InvariantConfig {
        th_override: cfg.invariant_th_override,
        ..Default::default()
    };
    let mut policy = Policy::new_with(cfg.policy, &spec, cfg.seed ^ 0xD20, inv_cfg);
    let mut params = spec.init_params(cfg.seed);
    let full_mask = MaskSet::full(&spec);

    let mut records: Vec<RoundRecord> = Vec::with_capacity(cfg.rounds);
    let mut vtime = 0.0f64;
    let mut calib_total = 0.0f64;
    let mut train_wall = 0.0f64;
    let mut detection: Option<Detection> = None;
    let mut last_latencies: Vec<f64> = vec![0.0; cfg.clients];
    let mut last_full_latencies: Vec<f64> = vec![0.0; cfg.clients];

    for round in 0..cfg.rounds {
        let t_frac = round as f64 / cfg.rounds.max(1) as f64;
        let mut rng = Pcg32::new(cfg.seed ^ 0xA0_0000, round as u64);

        let selected: Vec<usize> = if cfg.sample_fraction >= 1.0 {
            (0..cfg.clients).collect()
        } else {
            let k = ((cfg.clients as f64 * cfg.sample_fraction).ceil() as usize)
                .clamp(1, cfg.clients);
            let mut s = rng.sample_indices(cfg.clients, k);
            s.sort_unstable();
            s
        };

        let recalibrate = round > 0
            && round % cfg.recalibrate_every == 0
            && !(cfg.static_stragglers && detection.is_some());
        if recalibrate {
            let lat: Vec<f64> = selected.iter().map(|&c| last_full_latencies[c]).collect();
            let det = detect_stragglers(&lat, cfg.straggler_fraction, 0.02, &cfg.rates_menu);
            detection = Some(Detection {
                stragglers: det.stragglers.iter().map(|&i| selected[i]).collect(),
                ..det
            });
        }

        let calib_start = Instant::now();
        let mut masks: Vec<MaskSet> = vec![full_mask.clone(); cfg.clients];
        let mut rates: Vec<f64> = vec![1.0; cfg.clients];
        let mut straggler_ids: Vec<usize> = Vec::new();
        if let Some(det) = &detection {
            for (k, &c) in det.stragglers.iter().enumerate() {
                let desired = cfg.fixed_rate.unwrap_or(det.rates[k]);
                let r = match &cfg.cluster_rates {
                    Some(menu) => snap_rate(desired, menu),
                    None => desired,
                };
                if cfg.policy != PolicyKind::None && cfg.policy != PolicyKind::Exclude {
                    let m = policy.make_mask(&spec, r);
                    if !m.is_full() {
                        rates[c] = r;
                        masks[c] = m;
                    }
                }
                straggler_ids.push(c);
            }
        }
        let mut calib_secs = calib_start.elapsed().as_secs_f64();

        let participants: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|c| cfg.policy != PolicyKind::Exclude || !straggler_ids.contains(c))
            .collect();
        let round_seed = cfg.seed ^ ((round as u64) << 32);
        let t0 = Instant::now();
        let results: Vec<fluid::Result<fl::LocalResult>> =
            scope_map(&participants, cfg.threads, |_, &c| {
                clients[c].local_train(
                    &runner,
                    &params,
                    masks[c].tensors(),
                    cfg.local_steps,
                    cfg.lr,
                    round_seed,
                    cfg.use_fused_steps,
                )
            });
        train_wall += t0.elapsed().as_secs_f64();
        let mut updates: Vec<(usize, fl::LocalResult)> = Vec::with_capacity(results.len());
        for (i, r) in results.into_iter().enumerate() {
            updates.push((participants[i], r?));
        }

        for &c in &selected {
            let dev = &fleet[clients[c].device];
            let mut lrng = Pcg32::new(round_seed ^ 0x7A7, c as u64);
            let mut lrng_full = lrng.clone();
            last_latencies[c] = perf.round_latency(
                dev,
                c,
                rates[c],
                masks[c].comm_fraction(),
                t_frac,
                &sched,
                &mut lrng,
            );
            last_full_latencies[c] =
                perf.round_latency(dev, c, 1.0, 1.0, t_frac, &sched, &mut lrng_full);
        }
        let timed: &[usize] = if cfg.policy == PolicyKind::Exclude {
            &participants
        } else {
            &selected
        };
        let round_time = timed
            .iter()
            .map(|&c| last_latencies[c])
            .fold(0.0f64, f64::max);
        vtime += round_time;

        let straggler_time = straggler_ids
            .iter()
            .map(|&c| last_latencies[c])
            .fold(0.0f64, f64::max);
        let t_target = detection.as_ref().map(|d| d.t_target).unwrap_or(round_time);
        let straggler_wait = (straggler_time - t_target).max(0.0);

        let mean_loss = stats::mean(
            &updates.iter().map(|(_, u)| u.mean_loss).collect::<Vec<_>>(),
        );
        let mean_acc = stats::mean(
            &updates.iter().map(|(_, u)| u.mean_acc).collect::<Vec<_>>(),
        );
        let client_updates: Vec<ClientUpdate> = updates
            .iter()
            .map(|(c, u)| ClientUpdate {
                payload: DeltaPayload::DenseF32(u.params.clone()),
                weight: u.weight,
                mask: masks[*c].clone(),
                staleness: 0,
            })
            .collect();
        let new_params = fedavg(&spec, &params, &client_updates, cfg.aggregate);

        let is_calib_round = round % cfg.recalibrate_every == 0;
        if is_calib_round && matches!(policy, Policy::Invariant(_)) {
            let t0 = Instant::now();
            let voters: Vec<&(usize, fl::LocalResult)> = updates
                .iter()
                .filter(|(c, _)| !straggler_ids.contains(c))
                .take(MAX_DELTA_VOTERS)
                .collect();
            let per_client: Vec<fluid::Result<Vec<fluid::tensor::Tensor>>> =
                scope_map(&voters, cfg.threads, |_, (_, u)| {
                    runner.delta_step(&params, &u.params)
                });
            let per_client = per_client
                .into_iter()
                .collect::<fluid::Result<Vec<_>>>()?;
            policy.observe_deltas(&per_client);
            calib_secs += t0.elapsed().as_secs_f64();
        }
        params = new_params;
        calib_total += calib_secs;

        let (test_loss, test_acc) = if round % cfg.eval_every == 0 || round + 1 == cfg.rounds
        {
            fl::evaluate_split(&runner, &params, full_mask.tensors(), &data.test)?
        } else {
            (f64::NAN, f64::NAN)
        };

        let invariant_fraction = match &policy {
            Policy::Invariant(p) => p.invariant_fraction(),
            _ => 0.0,
        };

        records.push(RoundRecord {
            round,
            round_time,
            vtime,
            // the engine reports the sampled cohort (a field added with
            // the fleet refactor); for the classic loop it is `selected`
            cohort: selected.clone(),
            straggler_ids: straggler_ids.clone(),
            straggler_rates: straggler_ids.iter().map(|&c| rates[c]).collect(),
            t_target,
            straggler_time,
            train_loss: mean_loss,
            train_acc: mean_acc,
            test_loss,
            test_acc,
            invariant_fraction,
            calibration_secs: calib_secs,
            aggregated: updates.len(),
            dropped_updates: 0,
            stale_folded: 0,
            // wire accounting and the chaos plane postdate this
            // reference loop; neither is part of the bit-identity pin
            update_bytes: 0,
            vanished: 0,
            quarantined: 0,
            shard_retries: 0,
            quorum_fraction: 1.0,
            straggler_wait,
            admitted_stale: 0,
            // no soft-training in the fluid family: full local epochs
            soft_fraction: 1.0,
        });
    }

    let last_eval = records
        .iter()
        .rev()
        .find(|r| !r.test_acc.is_nan())
        .map(|r| (r.test_loss, r.test_acc))
        .unwrap_or((f64::NAN, f64::NAN));

    Ok(ExperimentResult {
        model: cfg.model.clone(),
        policy: cfg.policy,
        mitigation: cfg.mitigation,
        records,
        final_test_acc: last_eval.1,
        final_test_loss: last_eval.0,
        total_vtime: vtime,
        calibration_total: calib_total,
        seed: cfg.seed,
        train_wall_total: train_wall,
    })
}

/// NaN-aware bitwise equality.
fn eq_f64(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
}

fn assert_history_identical(reference: &ExperimentResult, engine: &ExperimentResult) {
    assert_eq!(reference.records.len(), engine.records.len());
    for (r, e) in reference.records.iter().zip(&engine.records) {
        let ctx = format!("round {}", r.round);
        assert_eq!(r.round, e.round, "{ctx}");
        assert_eq!(r.cohort, e.cohort, "{ctx}: cohort");
        assert!(
            eq_f64(r.round_time, e.round_time),
            "{ctx}: round_time {} vs {}",
            r.round_time,
            e.round_time
        );
        assert!(eq_f64(r.vtime, e.vtime), "{ctx}: vtime {} vs {}", r.vtime, e.vtime);
        assert_eq!(r.straggler_ids, e.straggler_ids, "{ctx}");
        assert_eq!(r.straggler_rates, e.straggler_rates, "{ctx}");
        assert!(
            eq_f64(r.t_target, e.t_target),
            "{ctx}: t_target {} vs {}",
            r.t_target,
            e.t_target
        );
        assert!(eq_f64(r.straggler_time, e.straggler_time), "{ctx}: straggler_time");
        assert!(
            eq_f64(r.train_loss, e.train_loss),
            "{ctx}: train_loss {} vs {}",
            r.train_loss,
            e.train_loss
        );
        assert!(eq_f64(r.train_acc, e.train_acc), "{ctx}: train_acc");
        assert!(
            eq_f64(r.test_loss, e.test_loss),
            "{ctx}: test_loss {} vs {}",
            r.test_loss,
            e.test_loss
        );
        assert!(eq_f64(r.test_acc, e.test_acc), "{ctx}: test_acc");
        assert!(
            eq_f64(r.invariant_fraction, e.invariant_fraction),
            "{ctx}: invariant_fraction"
        );
        assert_eq!(r.aggregated, e.aggregated, "{ctx}: aggregated");
        assert_eq!(r.dropped_updates, e.dropped_updates, "{ctx}");
        assert_eq!(r.stale_folded, e.stale_folded, "{ctx}");
        assert!(
            eq_f64(r.straggler_wait, e.straggler_wait),
            "{ctx}: straggler_wait {} vs {}",
            r.straggler_wait,
            e.straggler_wait
        );
        assert_eq!(r.admitted_stale, e.admitted_stale, "{ctx}");
        assert!(eq_f64(r.soft_fraction, e.soft_fraction), "{ctx}: soft_fraction");
    }
    assert!(eq_f64(reference.final_test_acc, engine.final_test_acc));
    assert!(eq_f64(reference.final_test_loss, engine.final_test_loss));
    assert!(eq_f64(reference.total_vtime, engine.total_vtime));
    assert_eq!(reference.seed, engine.seed);
}

fn quick_cfg(policy: PolicyKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mobile("femnist_cnn", policy);
    cfg.rounds = 6;
    cfg.samples_per_client = 30;
    cfg.local_steps = 2;
    cfg.eval_every = 3;
    cfg.lr = 0.01;
    cfg
}

#[test]
fn full_barrier_is_bit_identical_to_the_pre_engine_loop() {
    if !have("femnist_cnn") {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    // a sampled config pins the path where stragglers sit out rounds and
    // straggler_time must read their last-known latency
    let mut sampled = quick_cfg(PolicyKind::Invariant);
    sampled.clients = 8;
    sampled.sample_fraction = 0.6;
    sampled.recalibrate_every = 2;
    let configs = [
        quick_cfg(PolicyKind::Invariant),
        quick_cfg(PolicyKind::Exclude),
        quick_cfg(PolicyKind::Random),
        quick_cfg(PolicyKind::None),
        sampled,
    ];
    for mut cfg in configs {
        cfg.sync_mode = SyncMode::FullBarrier;
        let reference = reference_run(&sess, &cfg).unwrap();
        let engine = fluid::coordinator::run(&sess, &cfg).unwrap();
        assert_history_identical(&reference, &engine);
    }
}

#[test]
fn deadline_and_buffered_run_and_never_exceed_barrier_vtime() {
    if !have("femnist_cnn") {
        return;
    }
    let sess = Session::new(artifacts_dir()).unwrap();
    // vanilla policy (full masks everywhere) keeps per-client latency
    // draws identical across modes, making vtime dominance exact
    let mut base = ExperimentConfig::scale("femnist_cnn", PolicyKind::None, 10);
    base.rounds = 6;
    base.samples_per_client = 16;
    base.local_steps = 1;
    base.eval_every = base.rounds;
    base.recalibrate_every = 2;

    let barrier = fluid::coordinator::run(&sess, &base).unwrap();

    let mut deadline_cfg = base.clone();
    deadline_cfg.sync_mode = SyncMode::Deadline { multiple_of_t_target: 1.0 };
    let deadline = fluid::coordinator::run(&sess, &deadline_cfg).unwrap();
    assert_eq!(deadline.records.len(), base.rounds);
    assert!(
        deadline.total_vtime <= barrier.total_vtime + 1e-9,
        "deadline {:.2} > barrier {:.2}",
        deadline.total_vtime,
        barrier.total_vtime
    );
    let dropped: usize = deadline.records.iter().map(|r| r.dropped_updates).sum();
    assert!(dropped > 0, "a t_target-level cutoff must drop some straggler update");
    assert!(deadline.final_test_acc.is_finite());

    let mut buffered_cfg = base.clone();
    buffered_cfg.sync_mode = SyncMode::Buffered { k: 8 };
    let buffered = fluid::coordinator::run(&sess, &buffered_cfg).unwrap();
    assert_eq!(buffered.records.len(), base.rounds);
    assert!(
        buffered.total_vtime <= barrier.total_vtime + 1e-9,
        "buffered {:.2} > barrier {:.2}",
        buffered.total_vtime,
        barrier.total_vtime
    );
    let stale: usize = buffered.records.iter().map(|r| r.stale_folded).sum();
    assert!(stale > 0, "k=8 of 10 must buffer and later fold some update");
    assert!(buffered.final_test_acc.is_finite());
    // every update is eventually aggregated or still buffered — never
    // silently dropped in Buffered mode
    let dropped_b: usize = buffered.records.iter().map(|r| r.dropped_updates).sum();
    assert_eq!(dropped_b, 0);
}
